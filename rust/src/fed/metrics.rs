//! [`FedMetrics`] — what one federated simulation is judged by.

use crate::fleet::jain_index;
use crate::util::stats::{QuantileSketch, SKETCH_EXACT_LIMIT};

/// Per-client accounting, ascending client id in
/// [`FedMetrics::per_client`].
#[derive(Debug, Clone, PartialEq)]
pub struct ClientStat {
    pub id: usize,
    /// Rounds this client was selected for.
    pub selected: usize,
    /// Rounds whose aggregate actually included this client's update.
    pub aggregated: usize,
    /// Selections that ended dropped (availability dropout, deadline
    /// cutoff, or losing the over-selection race).
    pub dropped: usize,
    /// Adapter-delta bytes this client uploaded (aggregated rounds).
    pub up_bytes: u64,
    /// Global-adapter bytes this client downloaded (selected rounds).
    pub down_bytes: u64,
}

/// Raw tallies the round engine hands to [`FedMetrics::assemble`].
pub(crate) struct RawFed {
    /// Duration of every completed round, in round order.
    pub round_times: Vec<f64>,
    /// One entry per client, ascending id.
    pub per_client: Vec<ClientStat>,
    /// Virtual time at which the simulation ended, seconds.
    pub makespan: f64,
    /// Times the engine had to sleep until the next availability toggle
    /// because no (or no selectable) client was online.
    pub stalls: usize,
    /// Clients whose own device cannot host the model at all.
    pub infeasible: usize,
    /// Seconds spent in the aggregation collective across all rounds.
    pub agg_time: f64,
    /// Participation-weighted progress accumulated (Σ aggregated/K).
    pub effective_rounds: f64,
    /// First round index (1-based) at which `effective_rounds` crossed
    /// the configured target, if it ever did.
    pub rounds_to_target: Option<usize>,
    /// Virtual time of that crossing.
    pub time_to_target: Option<f64>,
    /// Strategy-oracle memo hits / misses while quoting client compute.
    pub oracle_hits: usize,
    pub oracle_misses: usize,
    /// Per-delta staleness percentiles (global rounds advanced between
    /// a delta's dispatch and its fold); `None` in sync mode, where a
    /// delta can never be stale.
    pub staleness_p50: Option<f64>,
    pub staleness_p95: Option<f64>,
}

/// Aggregate outcome of one federated run. All fields are deterministic
/// functions of the options (clients, traces and per-round randomness
/// all derive from the seed): the determinism property test compares
/// whole values with `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct FedMetrics {
    /// Rounds fully completed within the horizon (in async mode:
    /// logical buffer closes).
    pub rounds: usize,
    /// Virtual time at which the simulation ended, seconds.
    pub makespan: f64,
    /// Round-duration percentiles over the completed rounds, seconds
    /// (in async mode these are buffer-close intervals: virtual time
    /// between consecutive logical-round closes).
    pub round_p50: Option<f64>,
    pub round_p95: Option<f64>,
    pub round_p99: Option<f64>,
    /// Client-rounds selected across the run.
    pub selected_total: usize,
    /// Client-rounds whose update made it into an aggregate.
    pub aggregated_total: usize,
    /// Client-rounds dropped (dropout, cutoff, over-selection loss).
    pub dropped_total: usize,
    /// Total adapter-delta bytes uploaded / global bytes downloaded.
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Jain fairness index over per-client aggregated-round counts
    /// (every client counts, never-selected ones as zero).
    pub participation_fairness: f64,
    /// Participation-weighted progress: Σ over rounds of aggregated/K.
    pub effective_rounds: f64,
    /// Convergence proxy: first round (1-based) / virtual time at which
    /// `effective_rounds` reached the configured target (`None` when no
    /// target was set or it was never reached).
    pub rounds_to_target: Option<usize>,
    pub time_to_target: Option<f64>,
    /// Idle waits for the next availability toggle.
    pub stalls: usize,
    /// Clients excluded up front (model infeasible on their device).
    pub infeasible_clients: usize,
    /// Seconds spent in the aggregation collective across all rounds.
    pub agg_time_total: f64,
    /// Strategy-oracle memo hits while quoting per-client compute
    /// (observe counter: how much the plan memoisation saved).
    pub oracle_hits: usize,
    /// Strategy-oracle memo misses — distinct plans actually computed.
    pub oracle_misses: usize,
    /// Per-delta staleness percentiles: how many logical rounds the
    /// global adapter advanced between a delta's dispatch and its fold.
    /// Always `None` in sync mode (a cohort's deltas fold into the
    /// round they were dispatched for).
    pub staleness_p50: Option<f64>,
    pub staleness_p95: Option<f64>,
    /// Effective aggregation throughput: `effective_rounds` per virtual
    /// hour of makespan (`0` for an empty run). The headline async-vs-
    /// sync comparison number.
    pub rounds_per_hour: f64,
    /// Per-client accounting, ascending client id.
    pub per_client: Vec<ClientStat>,
}

impl FedMetrics {
    pub(crate) fn assemble(raw: RawFed) -> FedMetrics {
        // Stream the round durations through the quantile sketch: exact
        // (sorted once, not once per query) below the threshold,
        // fixed-state P² beyond it — no O(rounds log rounds) per query.
        let mut sketch = QuantileSketch::new(&[0.50, 0.95, 0.99], SKETCH_EXACT_LIMIT);
        for &t in &raw.round_times {
            sketch.add(t);
        }
        let pcts = sketch.quantile_many(&[0.50, 0.95, 0.99]);
        let selected_total = raw.per_client.iter().map(|c| c.selected).sum();
        let aggregated_total = raw.per_client.iter().map(|c| c.aggregated).sum();
        let dropped_total = raw.per_client.iter().map(|c| c.dropped).sum();
        let counts: Vec<f64> =
            raw.per_client.iter().map(|c| c.aggregated as f64).collect();
        FedMetrics {
            rounds: raw.round_times.len(),
            makespan: raw.makespan,
            round_p50: pcts[0],
            round_p95: pcts[1],
            round_p99: pcts[2],
            selected_total,
            aggregated_total,
            dropped_total,
            bytes_up: raw.per_client.iter().map(|c| c.up_bytes).sum(),
            bytes_down: raw.per_client.iter().map(|c| c.down_bytes).sum(),
            participation_fairness: jain_index(&counts),
            effective_rounds: raw.effective_rounds,
            rounds_to_target: raw.rounds_to_target,
            time_to_target: raw.time_to_target,
            stalls: raw.stalls,
            infeasible_clients: raw.infeasible,
            agg_time_total: raw.agg_time,
            oracle_hits: raw.oracle_hits,
            oracle_misses: raw.oracle_misses,
            staleness_p50: raw.staleness_p50,
            staleness_p95: raw.staleness_p95,
            rounds_per_hour: if raw.makespan > 0.0 {
                raw.effective_rounds / (raw.makespan / 3600.0)
            } else {
                0.0
            },
            per_client: raw.per_client,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(id: usize, selected: usize, aggregated: usize) -> ClientStat {
        ClientStat {
            id,
            selected,
            aggregated,
            dropped: selected - aggregated,
            up_bytes: aggregated as u64 * 100,
            down_bytes: selected as u64 * 100,
        }
    }

    fn raw(round_times: Vec<f64>, per_client: Vec<ClientStat>) -> RawFed {
        RawFed {
            round_times,
            per_client,
            makespan: 1000.0,
            stalls: 0,
            infeasible: 0,
            agg_time: 0.0,
            effective_rounds: 0.0,
            rounds_to_target: None,
            time_to_target: None,
            oracle_hits: 0,
            oracle_misses: 0,
            staleness_p50: None,
            staleness_p95: None,
        }
    }

    #[test]
    fn assemble_totals_and_percentiles() {
        let m = FedMetrics::assemble(raw(
            vec![10.0, 20.0, 30.0],
            vec![stat(0, 3, 3), stat(1, 2, 1), stat(2, 0, 0)],
        ));
        assert_eq!(m.rounds, 3);
        assert_eq!((m.selected_total, m.aggregated_total, m.dropped_total), (5, 4, 1));
        assert_eq!((m.bytes_up, m.bytes_down), (400, 500));
        assert_eq!(m.round_p50, Some(20.0));
        assert!(m.round_p99.unwrap() <= 30.0);
        // shares (3, 1, 0): unfair but within (0, 1]
        assert!(m.participation_fairness > 0.0 && m.participation_fairness < 1.0);
    }

    #[test]
    fn empty_run_has_no_nans() {
        let m = FedMetrics::assemble(raw(vec![], vec![]));
        assert_eq!(m.rounds, 0);
        assert_eq!(m.round_p50, None);
        assert_eq!(m.participation_fairness, 1.0, "vacuous fairness is perfect");
        assert_eq!(m.rounds_to_target, None);
        assert_eq!(m.staleness_p50, None);
        assert_eq!(m.rounds_per_hour, 0.0, "empty effective progress, zero throughput");
    }

    #[test]
    fn rounds_per_hour_follows_effective_progress_over_makespan() {
        let mut r = raw(vec![100.0; 4], vec![stat(0, 4, 4)]);
        r.effective_rounds = 4.0;
        r.makespan = 7200.0; // two virtual hours
        let m = FedMetrics::assemble(r);
        assert!((m.rounds_per_hour - 2.0).abs() < 1e-12, "{}", m.rounds_per_hour);
        // a zero-makespan run divides by nothing
        let m = FedMetrics::assemble(RawFed { makespan: 0.0, ..raw(vec![], vec![]) });
        assert_eq!(m.rounds_per_hour, 0.0);
    }

    #[test]
    fn uniform_participation_is_perfectly_fair() {
        let m = FedMetrics::assemble(raw(
            vec![5.0],
            vec![stat(0, 1, 1), stat(1, 1, 1), stat(2, 1, 1)],
        ));
        assert!((m.participation_fairness - 1.0).abs() < 1e-12);
    }
}
