//! The open client-selection layer: *which* available clients join a
//! federated round.
//!
//! Mirrors the fleet layer's open design
//! ([`crate::fleet::PolicyRegistry`], [`crate::fleet::QueuePolicyRegistry`]):
//! a scheme is one [`ClientSelection`] impl plus one
//! [`SelectionRegistry::register`] call, and the `fed` experiments and
//! `pacpp fed` CLI resolve policies by name. Selection never costs
//! training itself — every [`Candidate`] carries the round-time
//! estimate the engine derived through the shared
//! [`crate::fleet::StrategyOracle`], plus the availability-trace
//! signals (remaining up-time, long-run availability fraction) and the
//! client's participation history.
//!
//! Built-ins:
//!
//! * [`UniformRandom`] — the classic FedAvg sampler: K uniform picks
//!   from the available set;
//! * [`PowerOfD`] — power-of-d-choices: sample `d·K` random candidates
//!   and keep the K fastest by oracle estimate (low round time without
//!   scanning the whole population);
//! * [`AvailabilityAware`] — prefer clients whose current availability
//!   window outlasts their estimated round completion (they are the
//!   ones that will not drop out mid-round), breaking ties toward
//!   historically-available clients;
//! * [`FairShare`] — participation balancing: least-aggregated-first,
//!   driving the per-client participation Jain index toward 1;
//! * [`UtilityAware`] — Oort-style utility selection: rank by a
//!   deterministic statistical-utility proxy (√samples decayed by
//!   participation) × the availability estimate, with a seeded
//!   exploration fraction so under-observed clients still get tried.

use std::sync::Arc;

use crate::util::rng::Rng;

/// One selectable client as the selection layer sees it.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub id: usize,
    /// Estimated round time (dissemination + local epochs + upload),
    /// seconds, from the engine's oracle quotes.
    pub est: f64,
    /// Seconds until the client's current availability window closes
    /// (`f64::INFINITY` when no departure is scheduled).
    pub up_remaining: f64,
    /// Long-run fraction of the trace this client is available.
    pub avail_frac: f64,
    /// Rounds whose aggregate included this client so far.
    pub participation: usize,
    /// Training samples in the client's private dataset (the
    /// statistical-utility signal: more unseen data, more useful
    /// delta).
    pub samples: usize,
}

/// What a selection decision sees. `candidates` holds every available,
/// feasible client, ascending id.
pub struct SelectCtx<'a> {
    pub round: usize,
    pub now: f64,
    /// How many clients to pick (K plus any straggler over-selection),
    /// already capped at `candidates.len()`.
    pub want: usize,
    pub candidates: &'a [Candidate],
}

/// A pluggable client-selection scheme. Implementations must be
/// stateless (or internally synchronized): the registry hands out
/// shared references and the fed experiments run policies from worker
/// threads. All randomness must come from the provided `rng` (seeded
/// per round by the engine) — that is what makes same-seed runs
/// bit-identical under every policy.
pub trait ClientSelection: Send + Sync {
    /// Canonical display name (stable: used in tables, JSON, the CLI).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`SelectionRegistry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `pacpp fed` docs.
    fn description(&self) -> &str {
        ""
    }

    /// Pick up to `ctx.want` client ids from `ctx.candidates`. The
    /// engine sanitizes the result (drops non-candidates and
    /// duplicates, truncates to `want`), so a sloppy policy degrades
    /// gracefully instead of corrupting the round.
    fn select(&self, ctx: &SelectCtx, rng: &mut Rng) -> Vec<usize>;
}

/// K uniform random picks from the available set (FedAvg's sampler).
#[derive(Debug, Clone, Copy, Default)]
pub struct UniformRandom;

impl ClientSelection for UniformRandom {
    fn name(&self) -> &str {
        "Uniform"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["uniform", "random", "uniform-random"]
    }

    fn description(&self) -> &str {
        "K uniform random picks from the available clients (FedAvg)"
    }

    fn select(&self, ctx: &SelectCtx, rng: &mut Rng) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..ctx.candidates.len()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(ctx.want);
        idx.into_iter().map(|i| ctx.candidates[i].id).collect()
    }
}

/// How many random candidates [`PowerOfD`] samples per selected slot.
pub const POWER_OF_D: usize = 3;

/// Power-of-d-choices: sample `d·K` random candidates, keep the K with
/// the smallest round-time estimates — most of uniform sampling's
/// fairness, most of fastest-first's round time.
#[derive(Debug, Clone, Copy, Default)]
pub struct PowerOfD;

impl ClientSelection for PowerOfD {
    fn name(&self) -> &str {
        "Power-of-d"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["power-of-d", "pod", "fastest", "power"]
    }

    fn description(&self) -> &str {
        "sample d*K random candidates, keep the K fastest by oracle estimate"
    }

    fn select(&self, ctx: &SelectCtx, rng: &mut Rng) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..ctx.candidates.len()).collect();
        rng.shuffle(&mut idx);
        idx.truncate((ctx.want * POWER_OF_D).min(ctx.candidates.len()));
        idx.sort_by(|&a, &b| {
            let (ca, cb) = (&ctx.candidates[a], &ctx.candidates[b]);
            ca.est.total_cmp(&cb.est).then(ca.id.cmp(&cb.id))
        });
        idx.truncate(ctx.want);
        idx.into_iter().map(|i| ctx.candidates[i].id).collect()
    }
}

/// Safety margin [`AvailabilityAware`] demands between a candidate's
/// remaining up-time and its round estimate.
pub const AVAIL_SAFETY: f64 = 1.5;

/// Availability-aware selection over the churn traces: prefer clients
/// whose current up-window comfortably outlasts their estimated round
/// (`up_remaining >= 1.5 × est`), ranked by survival headroom and then
/// long-run availability — the clients least likely to drop mid-round.
#[derive(Debug, Clone, Copy, Default)]
pub struct AvailabilityAware;

impl ClientSelection for AvailabilityAware {
    fn name(&self) -> &str {
        "Availability-aware"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["availability", "avail", "availability-aware"]
    }

    fn description(&self) -> &str {
        "prefer clients whose availability window outlasts their estimated round"
    }

    fn select(&self, ctx: &SelectCtx, _rng: &mut Rng) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..ctx.candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            let (ca, cb) = (&ctx.candidates[a], &ctx.candidates[b]);
            // headroom ratio, capped so every "safe enough" client ties
            // and the historically-available ones win among them
            let ha = (ca.up_remaining / ca.est.max(1e-9)).min(AVAIL_SAFETY * 4.0);
            let hb = (cb.up_remaining / cb.est.max(1e-9)).min(AVAIL_SAFETY * 4.0);
            hb.total_cmp(&ha)
                .then(cb.avail_frac.total_cmp(&ca.avail_frac))
                .then(ca.est.total_cmp(&cb.est))
                .then(ca.id.cmp(&cb.id))
        });
        idx.truncate(ctx.want);
        idx.into_iter().map(|i| ctx.candidates[i].id).collect()
    }
}

/// Participation-fairness balancing: least-aggregated clients first, so
/// every client's adapter gets a voice in the global aggregate.
#[derive(Debug, Clone, Copy, Default)]
pub struct FairShare;

impl ClientSelection for FairShare {
    fn name(&self) -> &str {
        "Fair-share"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fair", "fairness", "fair-share", "least-participated"]
    }

    fn description(&self) -> &str {
        "least-participated clients first, balancing per-client aggregation counts"
    }

    fn select(&self, ctx: &SelectCtx, _rng: &mut Rng) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..ctx.candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            let (ca, cb) = (&ctx.candidates[a], &ctx.candidates[b]);
            ca.participation.cmp(&cb.participation).then(ca.id.cmp(&cb.id))
        });
        idx.truncate(ctx.want);
        idx.into_iter().map(|i| ctx.candidates[i].id).collect()
    }
}

/// Fraction of each [`UtilityAware`] cohort filled by exploration —
/// uniform picks from outside the top-utility set.
pub const UTILITY_EXPLORE: f64 = 0.2;

/// Per-participation decay of the statistical-utility proxy: each
/// aggregated round shrinks a client's expected marginal contribution
/// (its gradient news has already been folded in).
pub const UTILITY_DECAY: f64 = 0.8;

/// Oort-style utility-aware selection: score every candidate by a
/// statistical-utility proxy — `√samples` (diminishing returns in data
/// volume) decayed by [`UTILITY_DECAY`]^participation (already-heard
/// clients carry less news) — times the long-run availability estimate
/// (a delta that never arrives has no utility). The top scorers fill
/// `1 − UTILITY_EXPLORE` of the cohort; the rest is uniform exploration
/// from the remaining candidates, drawn from the engine's per-round
/// seeded RNG so runs stay bit-identical.
#[derive(Debug, Clone, Copy, Default)]
pub struct UtilityAware;

impl ClientSelection for UtilityAware {
    fn name(&self) -> &str {
        "Utility"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["utility", "oort", "utility-aware"]
    }

    fn description(&self) -> &str {
        "Oort-style: statistical utility x availability, with seeded exploration"
    }

    fn select(&self, ctx: &SelectCtx, rng: &mut Rng) -> Vec<usize> {
        let score = |c: &Candidate| {
            (c.samples as f64).sqrt()
                * UTILITY_DECAY.powi(c.participation.min(512) as i32)
                * c.avail_frac.max(1e-6)
        };
        let scores: Vec<f64> = ctx.candidates.iter().map(score).collect();
        let mut idx: Vec<usize> = (0..ctx.candidates.len()).collect();
        idx.sort_by(|&a, &b| {
            scores[b]
                .total_cmp(&scores[a])
                .then(ctx.candidates[a].id.cmp(&ctx.candidates[b].id))
        });
        let explore = ((ctx.want as f64) * UTILITY_EXPLORE).floor() as usize;
        let exploit = ctx.want - explore;
        let mut picked: Vec<usize> = idx[..exploit.min(idx.len())].to_vec();
        let mut rest: Vec<usize> = idx[picked.len()..].to_vec();
        rng.shuffle(&mut rest);
        picked.extend(rest.into_iter().take(ctx.want - picked.len()));
        picked.into_iter().map(|i| ctx.candidates[i].id).collect()
    }
}

impl crate::util::registry::Registered for dyn ClientSelection {
    fn name(&self) -> &str {
        ClientSelection::name(self)
    }
    fn aliases(&self) -> &'static [&'static str] {
        ClientSelection::aliases(self)
    }
    fn describe(&self) -> &str {
        self.description()
    }
}

/// An ordered, name-addressed collection of selection policies — a
/// [`crate::util::registry::Registry`] instantiation (uniform
/// resolution semantics; see [`crate::util::registry`]). Mirrors
/// [`crate::fleet::QueuePolicyRegistry`].
pub type SelectionRegistry = crate::util::registry::Registry<dyn ClientSelection>;

impl SelectionRegistry {
    /// An empty registry (build-your-own line-ups).
    pub fn empty() -> SelectionRegistry {
        crate::util::registry::Registry::new("selection policy")
    }

    /// The five built-ins: uniform, power-of-d, availability-aware,
    /// fair-share, utility.
    pub fn with_defaults() -> SelectionRegistry {
        let mut r = SelectionRegistry::empty();
        r.register(Arc::new(UniformRandom));
        r.register(Arc::new(PowerOfD));
        r.register(Arc::new(AvailabilityAware));
        r.register(Arc::new(FairShare));
        r.register(Arc::new(UtilityAware));
        r
    }
}

impl Default for SelectionRegistry {
    fn default() -> Self {
        SelectionRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(id: usize, est: f64, up: f64, frac: f64, part: usize) -> Candidate {
        Candidate { id, est, up_remaining: up, avail_frac: frac, participation: part, samples: 256 }
    }

    fn ctx(candidates: &[Candidate], want: usize) -> SelectCtx {
        SelectCtx { round: 0, now: 0.0, want, candidates }
    }

    #[test]
    fn uniform_is_seed_deterministic_and_covers() {
        let cands: Vec<Candidate> =
            (0..10).map(|i| cand(i, 100.0, f64::INFINITY, 1.0, 0)).collect();
        let a = UniformRandom.select(&ctx(&cands, 4), &mut Rng::new(7));
        let b = UniformRandom.select(&ctx(&cands, 4), &mut Rng::new(7));
        assert_eq!(a, b, "same rng seed, same picks");
        assert_eq!(a.len(), 4);
        let mut dedup = a.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 4, "picks are distinct");
        let c = UniformRandom.select(&ctx(&cands, 4), &mut Rng::new(8));
        assert_ne!(a, c, "different seeds explore");
    }

    #[test]
    fn power_of_d_prefers_fast_clients() {
        // client est grows with id: the fastest K must dominate picks
        let cands: Vec<Candidate> =
            (0..12).map(|i| cand(i, 100.0 * (i + 1) as f64, f64::INFINITY, 1.0, 0)).collect();
        let picked = PowerOfD.select(&ctx(&cands, 3), &mut Rng::new(3));
        assert_eq!(picked.len(), 3);
        // with d=3 the sample holds 9 of 12 candidates; the 3 fastest of
        // the sample always beat the population median
        let worst = picked.iter().copied().max().unwrap();
        assert!(worst < 10, "picked a near-slowest client: {picked:?}");
    }

    #[test]
    fn availability_aware_prefers_surviving_clients() {
        let cands = vec![
            cand(0, 100.0, 50.0, 0.9, 0),           // dies mid-round
            cand(1, 100.0, f64::INFINITY, 0.5, 0),  // survives
            cand(2, 100.0, 120.0, 0.9, 0),          // tight window
            cand(3, 100.0, f64::INFINITY, 0.8, 0),  // survives, more available
        ];
        let picked = AvailabilityAware.select(&ctx(&cands, 2), &mut Rng::new(1));
        assert_eq!(picked, vec![3, 1], "survivors first, higher avail_frac breaking ties");
    }

    #[test]
    fn fair_share_picks_least_participated() {
        let cands = vec![
            cand(0, 100.0, f64::INFINITY, 1.0, 5),
            cand(1, 100.0, f64::INFINITY, 1.0, 0),
            cand(2, 100.0, f64::INFINITY, 1.0, 2),
            cand(3, 100.0, f64::INFINITY, 1.0, 0),
        ];
        let picked = FairShare.select(&ctx(&cands, 3), &mut Rng::new(1));
        assert_eq!(picked, vec![1, 3, 2]);
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        let r = SelectionRegistry::with_defaults();
        assert_eq!(
            r.names(),
            vec!["Uniform", "Power-of-d", "Availability-aware", "Fair-share", "Utility"]
        );
        for (query, want) in [
            ("uniform", "Uniform"),
            ("RANDOM", "Uniform"),
            ("pod", "Power-of-d"),
            ("fastest", "Power-of-d"),
            ("avail", "Availability-aware"),
            ("fair", "Fair-share"),
            ("least-participated", "Fair-share"),
            ("oort", "Utility"),
            ("utility-aware", "Utility"),
        ] {
            assert_eq!(r.get(query).map(|p| p.name()), Some(want), "query {query:?}");
        }
        assert!(r.get("oracle").is_none());
    }

    /// Pure-exploit cohorts (want too small for an exploration slot)
    /// rank by the utility score: data volume up, participation and
    /// absence down.
    #[test]
    fn utility_prefers_rich_unheard_available_clients() {
        let base = |id: usize, samples: usize, part: usize, frac: f64| Candidate {
            id,
            est: 100.0,
            up_remaining: f64::INFINITY,
            avail_frac: frac,
            participation: part,
            samples,
        };
        let cands = vec![
            base(0, 1024, 0, 1.0), // the full-utility client
            base(1, 128, 0, 1.0),  // little data
            base(2, 1024, 10, 1.0), // already heard ten times
            base(3, 1024, 0, 0.2), // rarely reachable
        ];
        // want = 2 → explore = floor(0.4) = 0: deterministic exploit
        let picked = UtilityAware.select(&ctx(&cands, 2), &mut Rng::new(1));
        assert_eq!(picked, vec![0, 1], "sqrt(1024) beats decay^10 and 0.2 availability");
    }

    /// With an exploration slot in play the exploit prefix is still the
    /// top of the utility ranking, the explore tail comes from outside
    /// it via the seeded RNG, and equal seeds reproduce the cohort.
    #[test]
    fn utility_exploration_is_seeded_and_fills_the_cohort() {
        let cands: Vec<Candidate> =
            (0..10).map(|i| cand(i, 100.0, f64::INFINITY, 1.0, 0)).collect();
        // want = 5 → explore = 1, exploit = 4; equal scores tie-break by id
        let a = UtilityAware.select(&ctx(&cands, 5), &mut Rng::new(7));
        let b = UtilityAware.select(&ctx(&cands, 5), &mut Rng::new(7));
        assert_eq!(a, b, "same rng seed, same cohort");
        assert_eq!(a.len(), 5);
        assert_eq!(&a[..4], &[0, 1, 2, 3], "exploit prefix follows the ranking");
        assert!(a[4] >= 4, "the explore slot comes from outside the exploit set: {a:?}");
        let mut dedup = a.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 5, "picks are distinct");
        // some seed disagrees on the explore slot — it is a real draw
        let varied = (0..20u64)
            .map(|s| UtilityAware.select(&ctx(&cands, 5), &mut Rng::new(s))[4])
            .collect::<std::collections::BTreeSet<usize>>();
        assert!(varied.len() > 1, "exploration never varied across 20 seeds");
    }

    #[test]
    fn register_replaces_by_name() {
        struct Shadow;
        impl ClientSelection for Shadow {
            fn name(&self) -> &str {
                "Uniform"
            }
            fn select(&self, _ctx: &SelectCtx, _rng: &mut Rng) -> Vec<usize> {
                Vec::new()
            }
        }
        let mut r = SelectionRegistry::with_defaults();
        let n = r.len();
        r.register(Arc::new(Shadow));
        assert_eq!(r.len(), n, "replace, not append");
    }
}
