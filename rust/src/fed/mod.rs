//! `fed` — a deterministic round-based **federated adapter-aggregation
//! simulator**: many users' Parallel-Adapter deltas combined across a
//! churning population of personal edge devices.
//!
//! The paper fine-tunes one personal model per user on a private edge
//! pool. Scaling toward the ROADMAP's millions-of-users north star
//! means those users' adapters must also be *combined* across devices
//! — cross-device federated fine-tuning, instantiated here for
//! adapter-only exchange (only the tiny deltas ever leave a device,
//! preserving the paper's privacy premise). This module composes
//! ingredients the repo already has into federated rounds:
//!
//! * **local epochs** — each selected client's adapter training is
//!   costed through the existing [`crate::fleet::StrategyOracle`]
//!   (the paper's planner + cached-epoch model) on the client's own
//!   device ([`round`]);
//! * **selection** — which available clients join a round is a
//!   pluggable [`ClientSelection`] resolved by name through
//!   [`SelectionRegistry`] ([`select`]: uniform-random, power-of-d
//!   fastest by oracle estimate, availability-aware over the churn
//!   traces, participation-fairness balancing, Oort-style utility —
//!   statistical-utility proxy × availability with seeded
//!   exploration);
//! * **communication** — dissemination, adapter-delta uploads and the
//!   aggregation collective (ring AllReduce / all-gather / a
//!   parameter-server star) are timed through [`crate::cluster::Network`],
//!   with optional secure-aggregation and DP-noise cost knobs;
//! * **stragglers** — when a round closes and whose updates count is a
//!   pluggable [`StragglerPolicy`] ([`straggler`]: wait-all, deadline
//!   cutoff with partial aggregation, over-select K+s);
//! * **aggregation mode** — cohort-synchronous rounds or FedBuff-style
//!   asynchronous buffered folding ([`AggregationMode`]): in async
//!   mode deltas fold as they arrive, a logical round closes every
//!   [`FedOptions::buffer_k`] folds, there is no straggler barrier,
//!   and per-delta staleness is tracked;
//! * **churn** — every client has a seeded availability trace
//!   ([`ClientTrace`]); a window closing mid-round is a dropout the
//!   server only detects by timeout;
//! * **accounting** — [`FedMetrics`]: round-time p50/p95/p99 (buffer-
//!   close intervals in async mode), bytes up/down per client,
//!   stragglers dropped, per-client participation with a Jain fairness
//!   index, staleness p50/p95, effective rounds per hour, and a
//!   participation-weighted rounds-to-target convergence proxy.
//!
//! Entry points: [`simulate_fed`] / [`simulate_fed_with`] (library),
//! the `fed` / `fed_select` experiments in
//! [`crate::exp::ExperimentRegistry::with_defaults`], and the
//! `pacpp fed` CLI subcommand (`--rounds`, `--clients`, `--select`,
//! `--straggler`, `--agg`, `--agg-mode`, `--buffer-k`, `--seed`,
//! `--trace`, `--strategy`, `--shards`). The round engine keeps per-client state in compact
//! structure-of-arrays form and shards the per-client quoting/trace
//! passes across cores at ≥ [`PAR_CLIENT_THRESHOLD`] clients
//! ([`FedOptions::shards`], property-tested shard-invariant), so 100k
//! client populations are routine. Same
//! options produce bit-identical metrics (property-tested across every
//! selection × straggler combination, like `fleet`). See the crate
//! docs ("Adding a client-selection policy") for how to register your
//! own.

pub mod metrics;
pub mod round;
pub mod select;
pub mod straggler;

pub use metrics::{ClientStat, FedMetrics};
pub use round::{
    generate_availability, generate_clients, simulate_fed, simulate_fed_observed,
    simulate_fed_with, simulate_fed_with_observed, traces_from_churn, AggMode, AggregationMode,
    ClientTrace, FedClient, FedOptions, FedTraceKind, PAR_CLIENT_THRESHOLD, SECURE_KEY_BYTES,
};
pub use select::{
    AvailabilityAware, Candidate, ClientSelection, FairShare, PowerOfD, SelectCtx,
    SelectionRegistry, UniformRandom, UtilityAware, AVAIL_SAFETY, POWER_OF_D, UTILITY_DECAY,
    UTILITY_EXPLORE,
};
pub use straggler::{
    ClientRoundResult, DeadlineCutoff, OverSelect, RoundDecision, SelectedOutcome,
    StragglerCtx, StragglerPolicy, StragglerRegistry, WaitAll, DROPOUT_DETECT_MULT,
};
