//! The straggler layer: *when* a federated round ends and *whose*
//! updates make the aggregate.
//!
//! A selected client either finishes its local epochs + upload, or
//! drops out when its availability window closes mid-round. The server
//! cannot see a dropout directly — it gives up on an unresponsive
//! client after [`DROPOUT_DETECT_MULT`] times that client's estimated
//! round time — so dropouts under a wait-everyone discipline are
//! expensive, which is exactly the cost the cutoff and over-selection
//! disciplines (and availability-aware selection) exist to avoid.
//!
//! Built-ins:
//!
//! * [`WaitAll`] — synchronous FedAvg: the round ends when every
//!   selected client has finished or been given up on;
//! * [`DeadlineCutoff`] — the round is cut at `deadline_mult ×` the
//!   median estimated round time; whatever arrived by then is
//!   aggregated (partial aggregation), the rest is dropped;
//! * [`OverSelect`] — select `K + s` clients and aggregate the first K
//!   finishers; the stragglers' uploads are discarded.

use std::sync::Arc;

/// How long the server waits for an unresponsive client, as a multiple
/// of that client's estimated round time, before giving up on it.
pub const DROPOUT_DETECT_MULT: f64 = 3.0;

/// How one selected client's round attempt resolved, offsets measured
/// from the round start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClientRoundResult {
    /// Local epochs + upload completed at this offset.
    Finished { offset: f64 },
    /// The client's availability window closed mid-round; the server
    /// notices at `detect_offset` (its give-up timeout).
    Dropped { detect_offset: f64 },
}

/// One selected client's predicted and actual round behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectedOutcome {
    pub client: usize,
    /// The engine's estimate of this client's round time (what the
    /// server schedules against).
    pub est: f64,
    pub result: ClientRoundResult,
}

impl SelectedOutcome {
    /// When the server is done with this client: its finish, or the
    /// instant the server gives up on it.
    pub fn resolved_at(&self) -> f64 {
        match self.result {
            ClientRoundResult::Finished { offset } => offset,
            ClientRoundResult::Dropped { detect_offset } => detect_offset,
        }
    }

    pub fn finished_at(&self) -> Option<f64> {
        match self.result {
            ClientRoundResult::Finished { offset } => Some(offset),
            ClientRoundResult::Dropped { .. } => None,
        }
    }
}

/// What a round-end decision sees.
pub struct StragglerCtx<'a> {
    /// The aggregation target K (over-selection selects more).
    pub k: usize,
    /// The `deadline_mult` knob from the run options.
    pub deadline_mult: f64,
    /// One outcome per selected client.
    pub outcomes: &'a [SelectedOutcome],
}

impl StragglerCtx<'_> {
    /// The round-end offset of full synchronization: every client
    /// finished or given up on.
    pub fn resolved_all(&self) -> f64 {
        self.outcomes.iter().map(|o| o.resolved_at()).fold(0.0, f64::max)
    }

    /// Median of the selected clients' estimates (lower median for even
    /// counts — deterministic).
    pub fn median_est(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let mut ests: Vec<f64> = self.outcomes.iter().map(|o| o.est).collect();
        ests.sort_by(|a, b| a.total_cmp(b));
        ests[(ests.len() - 1) / 2]
    }
}

/// A round-end decision: when the round closes and which outcome
/// indices (into [`StragglerCtx::outcomes`]) are aggregated.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundDecision {
    /// Offset from the round start at which the server starts
    /// aggregating (the collective's own time is added by the engine).
    pub end_offset: f64,
    /// Indices of the aggregated clients, ascending.
    pub aggregated: Vec<usize>,
}

/// A pluggable straggler-mitigation discipline. Implementations must be
/// stateless (or internally synchronized): the registry hands out
/// shared references and the fed experiments run policies from worker
/// threads.
pub trait StragglerPolicy: Send + Sync {
    /// Canonical display name (stable: used in tables, JSON, the CLI).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`StragglerRegistry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `pacpp fed` docs.
    fn description(&self) -> &str {
        ""
    }

    /// Extra clients to select beyond K. `configured` is the run's
    /// `over_select` knob; policies that do not over-select ignore it.
    fn extra(&self, _configured: usize) -> usize {
        0
    }

    /// Close the round: pick the end offset and the aggregated set.
    fn decide(&self, ctx: &StragglerCtx) -> RoundDecision;
}

fn finished_indices(ctx: &StragglerCtx) -> Vec<usize> {
    (0..ctx.outcomes.len())
        .filter(|&i| ctx.outcomes[i].finished_at().is_some())
        .collect()
}

/// Synchronous FedAvg: wait for every selected client (dropouts stall
/// the round until the server's give-up timeout).
#[derive(Debug, Clone, Copy, Default)]
pub struct WaitAll;

impl StragglerPolicy for WaitAll {
    fn name(&self) -> &str {
        "Wait-all"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["wait-all", "waitall", "sync", "all"]
    }

    fn description(&self) -> &str {
        "synchronous FedAvg: the round waits for every selected client"
    }

    fn decide(&self, ctx: &StragglerCtx) -> RoundDecision {
        RoundDecision { end_offset: ctx.resolved_all(), aggregated: finished_indices(ctx) }
    }
}

/// Deadline cutoff with partial aggregation: the round closes at
/// `deadline_mult × median estimate`; late clients are dropped.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeadlineCutoff;

impl StragglerPolicy for DeadlineCutoff {
    fn name(&self) -> &str {
        "Deadline"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["deadline", "cutoff", "deadline-cutoff", "partial"]
    }

    fn description(&self) -> &str {
        "cut the round at deadline_mult x the median estimate; aggregate what arrived"
    }

    fn decide(&self, ctx: &StragglerCtx) -> RoundDecision {
        let deadline = ctx.deadline_mult * ctx.median_est();
        // everyone resolving early closes the round early; otherwise the
        // deadline does
        let end = ctx.resolved_all().min(deadline);
        let aggregated: Vec<usize> = (0..ctx.outcomes.len())
            .filter(|&i| {
                ctx.outcomes[i].finished_at().map(|f| f <= end + 1e-9).unwrap_or(false)
            })
            .collect();
        // degenerate cohort: nobody beat the deadline (every selected
        // client dropped out, or finished only past the cutoff). The
        // deadline anchored on give-up *estimates*, so closing at it
        // would end an empty round earlier than the dropouts actually
        // resolved — fall back to waiting them out, and return the
        // empty aggregate explicitly
        if aggregated.is_empty() {
            return RoundDecision { end_offset: ctx.resolved_all(), aggregated };
        }
        RoundDecision { end_offset: end, aggregated }
    }
}

/// Over-selection: select `K + s`, aggregate the first K finishers and
/// discard the stragglers.
#[derive(Debug, Clone, Copy, Default)]
pub struct OverSelect;

impl StragglerPolicy for OverSelect {
    fn name(&self) -> &str {
        "Over-select"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["over-select", "overselect", "over", "k+s"]
    }

    fn description(&self) -> &str {
        "select K+s clients, aggregate the first K finishers"
    }

    fn extra(&self, configured: usize) -> usize {
        configured.max(1)
    }

    fn decide(&self, ctx: &StragglerCtx) -> RoundDecision {
        let mut fin = finished_indices(ctx);
        fin.sort_by(|&a, &b| {
            ctx.outcomes[a]
                .resolved_at()
                .total_cmp(&ctx.outcomes[b].resolved_at())
                .then(a.cmp(&b))
        });
        if fin.len() >= ctx.k && ctx.k > 0 {
            let mut aggregated: Vec<usize> = fin[..ctx.k].to_vec();
            let end = aggregated
                .iter()
                .map(|&i| ctx.outcomes[i].resolved_at())
                .fold(0.0, f64::max);
            aggregated.sort_unstable();
            RoundDecision { end_offset: end, aggregated }
        } else {
            // not enough finishers to fill K: degenerate to wait-all
            fin.sort_unstable();
            RoundDecision { end_offset: ctx.resolved_all(), aggregated: fin }
        }
    }
}

impl crate::util::registry::Registered for dyn StragglerPolicy {
    fn name(&self) -> &str {
        StragglerPolicy::name(self)
    }
    fn aliases(&self) -> &'static [&'static str] {
        StragglerPolicy::aliases(self)
    }
    fn describe(&self) -> &str {
        self.description()
    }
}

/// An ordered, name-addressed collection of straggler policies — a
/// [`crate::util::registry::Registry`] instantiation (uniform
/// resolution semantics; see [`crate::util::registry`]). Mirrors
/// [`crate::fleet::QueuePolicyRegistry`].
pub type StragglerRegistry = crate::util::registry::Registry<dyn StragglerPolicy>;

impl StragglerRegistry {
    /// An empty registry (build-your-own line-ups).
    pub fn empty() -> StragglerRegistry {
        crate::util::registry::Registry::new("straggler policy")
    }

    /// The three built-ins: wait-all, deadline cutoff, over-select.
    pub fn with_defaults() -> StragglerRegistry {
        let mut r = StragglerRegistry::empty();
        r.register(Arc::new(WaitAll));
        r.register(Arc::new(DeadlineCutoff));
        r.register(Arc::new(OverSelect));
        r
    }
}

impl Default for StragglerRegistry {
    fn default() -> Self {
        StragglerRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fin(client: usize, est: f64, offset: f64) -> SelectedOutcome {
        SelectedOutcome { client, est, result: ClientRoundResult::Finished { offset } }
    }

    fn drop_(client: usize, est: f64) -> SelectedOutcome {
        SelectedOutcome {
            client,
            est,
            result: ClientRoundResult::Dropped {
                detect_offset: DROPOUT_DETECT_MULT * est,
            },
        }
    }

    fn ctx(k: usize, outcomes: &[SelectedOutcome]) -> StragglerCtx<'_> {
        StragglerCtx { k, deadline_mult: 2.0, outcomes }
    }

    #[test]
    fn wait_all_waits_for_the_slowest_and_for_dropout_detection() {
        let outcomes = vec![fin(0, 100.0, 110.0), fin(1, 200.0, 190.0)];
        let d = WaitAll.decide(&ctx(2, &outcomes));
        assert_eq!(d.end_offset, 190.0);
        assert_eq!(d.aggregated, vec![0, 1]);

        // a dropout stalls the round until the give-up timeout
        let outcomes = vec![fin(0, 100.0, 110.0), drop_(1, 200.0)];
        let d = WaitAll.decide(&ctx(2, &outcomes));
        assert_eq!(d.end_offset, 600.0, "3x the dropped client's estimate");
        assert_eq!(d.aggregated, vec![0]);
    }

    #[test]
    fn deadline_cuts_late_clients_but_closes_early_when_everyone_arrives() {
        // median est = 100 (lower median of [100, 300]); deadline = 200
        let outcomes = vec![fin(0, 100.0, 110.0), fin(1, 300.0, 310.0)];
        let d = DeadlineCutoff.decide(&ctx(2, &outcomes));
        assert_eq!(d.end_offset, 200.0);
        assert_eq!(d.aggregated, vec![0], "the 310 s finisher missed the cut");

        // everyone early: the round closes at the last arrival
        let outcomes = vec![fin(0, 100.0, 90.0), fin(1, 100.0, 95.0)];
        let d = DeadlineCutoff.decide(&ctx(2, &outcomes));
        assert_eq!(d.end_offset, 95.0);
        assert_eq!(d.aggregated, vec![0, 1]);

        // a dropout cannot stall past the deadline
        let outcomes = vec![fin(0, 100.0, 110.0), drop_(1, 100.0)];
        let d = DeadlineCutoff.decide(&ctx(2, &outcomes));
        assert_eq!(d.end_offset, 200.0);
        assert_eq!(d.aggregated, vec![0]);
    }

    /// ISSUE-9 satellite: the degenerate cohort. When *every* selected
    /// client drops out, the deadline (anchored on give-up estimates)
    /// must not close an empty round before the dropouts actually
    /// resolved — the cutoff falls back to `resolved_all()` and returns
    /// the empty aggregate explicitly.
    #[test]
    fn deadline_all_dropped_cohort_waits_out_the_dropouts() {
        let outcomes = vec![drop_(0, 100.0), drop_(1, 200.0), drop_(2, 150.0)];
        let d = DeadlineCutoff.decide(&ctx(3, &outcomes));
        assert!(d.aggregated.is_empty(), "nothing arrived, nothing aggregates");
        assert_eq!(d.end_offset, 600.0, "waits for the slowest give-up, not 2 x median");

        // same fallback when the only finisher lands past the cutoff
        let outcomes = vec![fin(0, 100.0, 250.0), drop_(1, 100.0)];
        let d = DeadlineCutoff.decide(&ctx(2, &outcomes));
        assert!(d.aggregated.is_empty(), "the 250 s arrival missed the 200 s deadline");
        assert_eq!(d.end_offset, 300.0, "resolves at the dropout detection");
    }

    #[test]
    fn over_select_takes_the_first_k_finishers() {
        let outcomes = vec![
            fin(0, 100.0, 150.0),
            fin(1, 100.0, 90.0),
            fin(2, 100.0, 120.0),
            drop_(3, 100.0),
        ];
        let d = OverSelect.decide(&ctx(2, &outcomes));
        assert_eq!(d.end_offset, 120.0, "round closes at the K-th finisher");
        assert_eq!(d.aggregated, vec![1, 2]);

        // fewer finishers than K: degenerate to wait-all over finishers
        let outcomes = vec![fin(0, 100.0, 150.0), drop_(1, 100.0), drop_(2, 100.0)];
        let d = OverSelect.decide(&ctx(2, &outcomes));
        assert_eq!(d.aggregated, vec![0]);
        assert_eq!(d.end_offset, 300.0, "stalls to the dropout detections");
        assert_eq!(OverSelect.extra(3), 3);
        assert_eq!(OverSelect.extra(0), 1, "over-select always selects at least one spare");
        assert_eq!(WaitAll.extra(3), 0);
    }

    #[test]
    fn empty_round_is_a_zero_decision() {
        for p in [&WaitAll as &dyn StragglerPolicy, &DeadlineCutoff, &OverSelect] {
            let d = p.decide(&ctx(2, &[]));
            assert_eq!(d.end_offset, 0.0, "{}", p.name());
            assert!(d.aggregated.is_empty(), "{}", p.name());
        }
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        let r = StragglerRegistry::with_defaults();
        assert_eq!(r.names(), vec!["Wait-all", "Deadline", "Over-select"]);
        for (query, want) in [
            ("wait-all", "Wait-all"),
            ("SYNC", "Wait-all"),
            ("deadline", "Deadline"),
            ("partial", "Deadline"),
            ("k+s", "Over-select"),
            ("overselect", "Over-select"),
        ] {
            assert_eq!(r.get(query).map(|p| p.name()), Some(want), "query {query:?}");
        }
        assert!(r.get("async").is_none());
    }
}
