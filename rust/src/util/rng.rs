//! Deterministic PRNG (xoshiro256**) — the offline image has no `rand`.
//!
//! Used by the property-test harness, synthetic data generators, and the
//! quantization tests. Seeded explicitly everywhere for reproducibility.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the full state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — hi exclusive, panics if empty.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform i64 in [lo, hi).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range(0, i + 1);
            v.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.range(0, v.len())]
    }

    /// Weibull variate by inversion: `scale · (−ln(1−U))^{1/shape}`.
    /// `shape < 1` gives heavy-tailed, bursty gaps (many tiny values,
    /// rare huge ones); `shape = 1` is exponential; `shape > 1`
    /// concentrates around the scale — the knob the `learn` training
    /// grids turn to diversify inter-arrival patterns beyond the three
    /// built-in trace kinds.
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0, "weibull needs positive shape/scale");
        let u = self.f64();
        scale * (-(1.0 - u).max(1e-300).ln()).powf(1.0 / shape)
    }

    /// UUniFast (Bini & Buttazzo): split `total` into `n` non-negative
    /// parts whose sum is exactly re-normalized to `total`, uniformly
    /// over the simplex of such splits. The classic way to spread a
    /// utilization (or deadline-slack) budget across tasks without the
    /// bias of independent draws.
    pub fn uunifast(&mut self, n: usize, total: f64) -> Vec<f64> {
        assert!(total >= 0.0, "uunifast needs a non-negative total");
        if n == 0 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(n);
        let mut sum = total;
        for i in 1..n {
            let next = sum * self.f64().powf(1.0 / (n - i) as f64);
            out.push(sum - next);
            sum = next;
        }
        out.push(sum);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    /// Weibull moments: shape 1 is exponential (mean = scale); shape 2
    /// has mean `scale·√π/2`. Checked against sample means, plus
    /// determinism and positivity.
    #[test]
    fn weibull_moments_and_determinism() {
        let n = 50_000;
        let sample = |shape: f64, scale: f64| -> Vec<f64> {
            let mut r = Rng::new(23);
            (0..n).map(|_| r.weibull(shape, scale)).collect()
        };
        let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;

        let exp_like = sample(1.0, 120.0);
        assert!(exp_like.iter().all(|&x| x >= 0.0));
        assert!((mean(&exp_like) - 120.0).abs() / 120.0 < 0.03, "shape-1 mean");

        let concentrated = sample(2.0, 100.0);
        let expect = 100.0 * (std::f64::consts::PI).sqrt() / 2.0;
        assert!((mean(&concentrated) - expect).abs() / expect < 0.03, "shape-2 mean");

        // heavy tail: shape < 1 has a larger max/median ratio
        let heavy = sample(0.5, 100.0);
        let max_of = |xs: &[f64]| xs.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max_of(&heavy) > max_of(&concentrated));

        // bit-identical under the same seed
        assert_eq!(
            sample(0.7, 33.0).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            sample(0.7, 33.0).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    /// UUniFast: every part non-negative, the parts sum to the total
    /// (within float tolerance), edge cases n=0/n=1 behave, and the same
    /// seed reproduces the same partition bit-for-bit.
    #[test]
    fn uunifast_partitions_the_total() {
        let mut r = Rng::new(31);
        for &(n, total) in &[(1usize, 5.0f64), (2, 1.0), (8, 3.5), (64, 10.0)] {
            let parts = r.uunifast(n, total);
            assert_eq!(parts.len(), n);
            assert!(parts.iter().all(|&p| p >= 0.0), "negative part in {parts:?}");
            let sum: f64 = parts.iter().sum();
            assert!((sum - total).abs() < 1e-9 * total.max(1.0), "sum {sum} != {total}");
        }
        assert!(Rng::new(1).uunifast(0, 4.0).is_empty());
        assert_eq!(Rng::new(2).uunifast(1, 4.0), vec![4.0]);
        let a = Rng::new(77).uunifast(16, 8.0);
        let b = Rng::new(77).uunifast(16, 8.0);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
