//! Minimal JSON parser/writer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar needed by the AOT manifest and the
//! experiment-report outputs: objects, arrays, strings (with escapes),
//! numbers, booleans, null. Numbers are stored as f64 (the manifest's
//! integers are all well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys for objects.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Dotted key-path access with array indices: `"meta.goodput"`,
    /// `"rows[0][3]"`, `"otherData.metrics.counters.events"`. Keys
    /// select object members, `[N]` selects array elements; the empty
    /// path is the value itself. `None` on any miss or malformed
    /// segment — the declarative extractor in `obs::regress` turns
    /// that into a diagnostic naming the path.
    pub fn path_str(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        if path.is_empty() {
            return Some(cur);
        }
        for seg in path.split('.') {
            let (key, mut rest) = match seg.find('[') {
                Some(i) => (&seg[..i], &seg[i..]),
                None => (seg, ""),
            };
            if !key.is_empty() {
                cur = cur.get(key)?;
            } else if rest.is_empty() {
                return None; // empty segment: "a..b"
            }
            while let Some(r) = rest.strip_prefix('[') {
                let end = r.find(']')?;
                let idx: usize = r[..end].parse().ok()?;
                cur = cur.as_arr()?.get(idx)?;
                rest = &r[end + 1..];
            }
            if !rest.is_empty() {
                return None; // trailing junk after the last ']'
            }
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 1-space indentation (matches Python's json.dump indent=1).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

// Convenience constructors.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
// Integer conversions go through f64 (the only JSON number type here):
// exact below ~9e15 (2^53); larger magnitudes silently lose precision.
// Producers that must round-trip integers exactly (the experiment
// reports) bound their values accordingly — see exp::report.
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}

/// Collect an iterator of values into a `Json::Arr` (the experiment
/// reports serialize column schemas and rows this way).
impl FromIterator<Json> for Json {
    fn from_iter<I: IntoIterator<Item = Json>>(iter: I) -> Json {
        Json::Arr(iter.into_iter().collect())
    }
}

/// Build a `Json::Obj` from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // bulk-copy the contiguous run of plain characters
                    // (validating UTF-8 once per run, not per char — the
                    // per-char version made manifest parsing O(n^2))
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    let run = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(run);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr": [1, 2.5, "s", null, true], "nested": {"k": [[]]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn path_str_walks_keys_and_indices() {
        let v = Json::parse(
            r#"{"meta": {"goodput": "0.9"}, "rows": [[1, "a", 2.5], [3]], "n": 7}"#,
        )
        .unwrap();
        assert_eq!(v.path_str("n").unwrap().as_f64(), Some(7.0));
        assert_eq!(v.path_str("meta.goodput").unwrap().as_str(), Some("0.9"));
        assert_eq!(v.path_str("rows[0][2]").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.path_str("rows[1][0]").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.path_str(""), Some(&v), "empty path is the value itself");
        for miss in ["absent", "meta.absent", "rows[9]", "rows[0][9]", "n[0]", "rows[x]",
            "rows[0]junk", "meta..goodput"]
        {
            assert!(v.path_str(miss).is_none(), "{miss} should miss");
        }
    }

    #[test]
    fn collect_into_array_and_int_conversions() {
        let a: Json = (0..3i64).map(Json::from).collect();
        assert_eq!(a, Json::Arr(vec![Json::Num(0.0), Json::Num(1.0), Json::Num(2.0)]));
        assert_eq!(Json::from(7u64), Json::Num(7.0));
    }
}
