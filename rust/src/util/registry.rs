//! The one generic name/alias registry behind every pluggable layer.
//!
//! Six subsystems resolve implementations by name — parallelism
//! strategies, experiments, fleet placement and queue policies, fed
//! client selection and straggler handling — and each used to carry its
//! own copy of the same ~60-line registry. [`Registry<T>`] is that
//! registry written once: an ordered, name-addressed collection of
//! `Arc<T>` entries over the [`Registered`] trait.
//!
//! Semantics (uniform across every instantiation):
//!
//! * registration order is preserved — it is the table/CLI listing
//!   order of every layer;
//! * [`register`](Registry::register) replaces an existing entry with
//!   the same canonical name, matched **case-insensitively**, so a
//!   differently-cased registration shadows a built-in instead of
//!   appending an unreachable twin;
//! * [`get`](Registry::get) matches canonical names case-insensitively
//!   first, then lowercase aliases — canonical names win, so an entry
//!   whose name collides with another entry's alias stays reachable;
//! * [`get_or_err`](Registry::get_or_err) turns an unknown name into
//!   the one diagnostic every layer shows: `unknown <kind> <name>`,
//!   a "did you mean …" suggestion when a registered name or alias is
//!   within edit distance 2, and the registered alternatives.
//!
//! A layer opts in by implementing [`Registered`] for its trait object
//! (delegating to the trait's own `name`/`aliases`/`description`) and
//! exposing `pub type FooRegistry = Registry<dyn Foo>;` plus inherent
//! `empty()`/`with_defaults()` constructors — see
//! [`crate::fleet::QueuePolicyRegistry`] for the pattern.

use std::sync::Arc;

use anyhow::{bail, Result};

/// What [`Registry<T>`] needs from an entry: a canonical display name,
/// optional lowercase lookup aliases, and a one-line description for
/// listings. Implemented for each pluggable layer's trait object,
/// delegating to the layer trait's own methods.
pub trait Registered {
    /// Canonical display name (stable: used in tables, JSON, the CLI).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`Registry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for listings and docs.
    fn describe(&self) -> &str {
        ""
    }
}

/// An ordered, name-addressed collection of `Arc<T>` entries. See the
/// [module docs](self) for the shared resolution semantics.
pub struct Registry<T: ?Sized + Registered> {
    kind: &'static str,
    entries: Vec<Arc<T>>,
}

impl<T: ?Sized + Registered> Registry<T> {
    /// An empty registry. `kind` is the human noun used in error
    /// messages (`"strategy"`, `"queue policy"`, ...).
    pub fn new(kind: &'static str) -> Registry<T> {
        Registry { kind, entries: Vec::new() }
    }

    /// The noun this registry's diagnostics use.
    pub fn kind(&self) -> &'static str {
        self.kind
    }

    /// Add an entry; replaces an existing entry with the same canonical
    /// name (so callers can shadow a built-in). Matching is
    /// case-insensitive, like [`get`](Registry::get) — a
    /// differently-cased registration must shadow, not append an
    /// unreachable twin.
    pub fn register(&mut self, e: Arc<T>) {
        let name = e.name().to_ascii_lowercase();
        if let Some(slot) =
            self.entries.iter_mut().find(|x| x.name().to_ascii_lowercase() == name)
        {
            *slot = e;
        } else {
            self.entries.push(e);
        }
    }

    /// Look up by canonical name (case-insensitive) or alias. Canonical
    /// names win over aliases, so an entry registered under a name that
    /// collides with an earlier entry's alias is still reachable.
    pub fn get(&self, name: &str) -> Option<&Arc<T>> {
        let q = name.to_ascii_lowercase();
        self.entries
            .iter()
            .find(|e| e.name().to_ascii_lowercase() == q)
            .or_else(|| self.entries.iter().find(|e| e.aliases().contains(&q.as_str())))
    }

    /// Like [`get`](Registry::get), but an unknown name is an error of
    /// the shape `unknown <kind> "<name>" (did you mean "…"?);
    /// registered: …` — the one diagnostic the CLI and library both
    /// show. The suggestion appears when a registered name or alias is
    /// within edit distance 2.
    pub fn get_or_err(&self, name: &str) -> Result<&Arc<T>> {
        match self.get(name) {
            Some(e) => Ok(e),
            None => {
                let hint = match self.closest(name) {
                    Some(s) => format!(" (did you mean {s:?}?)"),
                    None => String::new(),
                };
                bail!(
                    "unknown {} {name:?}{hint}; registered: {}",
                    self.kind,
                    self.names().join(", ")
                )
            }
        }
    }

    /// The registered name or alias closest to `name`, if any is within
    /// edit distance 2 (and closer than replacing the whole query).
    fn closest(&self, name: &str) -> Option<&str> {
        let q = name.to_ascii_lowercase();
        let mut best: Option<(usize, &str)> = None;
        for e in &self.entries {
            for cand in std::iter::once(e.name()).chain(e.aliases().iter().copied()) {
                let d = levenshtein(&q, &cand.to_ascii_lowercase());
                if d <= 2 && d < q.chars().count() && best.map(|(b, _)| d < b).unwrap_or(true) {
                    best = Some((d, cand));
                }
            }
        }
        best.map(|(_, s)| s)
    }

    /// Canonical names in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Arc<T>> {
        self.entries.iter()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Classic two-row Levenshtein edit distance over chars — small inputs
/// only (names and aliases), so O(|a|·|b|) is fine.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Named {
        name: &'static str,
        aliases: &'static [&'static str],
    }

    impl Registered for Named {
        fn name(&self) -> &str {
            self.name
        }
        fn aliases(&self) -> &'static [&'static str] {
            self.aliases
        }
        fn describe(&self) -> &str {
            "a test entry"
        }
    }

    fn registry() -> Registry<Named> {
        let mut r = Registry::new("widget");
        r.register(Arc::new(Named { name: "Alpha", aliases: &["a", "first"] }));
        r.register(Arc::new(Named { name: "Beta", aliases: &["b"] }));
        r
    }

    #[test]
    fn canonical_beats_alias_and_lookup_is_case_insensitive() {
        let mut r = registry();
        assert_eq!(r.get("ALPHA").map(|e| e.name()), Some("Alpha"));
        assert_eq!(r.get("first").map(|e| e.name()), Some("Alpha"));
        // an entry *named* like an earlier alias is reachable: canonical
        // match is tried across all entries before any alias
        r.register(Arc::new(Named { name: "first", aliases: &[] }));
        assert_eq!(r.get("first").map(|e| e.name()), Some("first"));
    }

    #[test]
    fn register_replaces_case_insensitively() {
        let mut r = registry();
        let n = r.len();
        r.register(Arc::new(Named { name: "ALPHA", aliases: &[] }));
        assert_eq!(r.len(), n, "replace, not append");
        assert_eq!(r.get("alpha").map(|e| e.name()), Some("ALPHA"));
    }

    #[test]
    fn unknown_names_suggest_and_list() {
        let r = registry();
        let err = r.get_or_err("alpa").unwrap_err().to_string();
        assert!(err.contains("unknown widget \"alpa\""), "{err}");
        assert!(err.contains("(did you mean \"Alpha\"?)"), "{err}");
        assert!(err.contains("registered: Alpha, Beta"), "{err}");
        // far-off queries get no suggestion, just the list
        let err = r.get_or_err("zzzzzz").unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
        assert!(err.contains("registered: Alpha, Beta"), "{err}");
        // a 1-char query is never "2 edits from" everything: the hint
        // must not fire when the whole query would be replaced
        let err = r.get_or_err("x").unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn alias_typos_suggest_the_alias() {
        let r = registry();
        let err = r.get_or_err("firts").unwrap_err().to_string();
        assert!(err.contains("did you mean \"first\"?"), "{err}");
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", "abd"), 1);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("fifo", "FIFO".to_ascii_lowercase().as_str()), 0);
    }
}
