//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bench`] with `harness = false`. It performs
//! warmup, adaptively picks an iteration count targeting a measurement
//! window, and reports mean/p50/p99.
//!
//! Two environment knobs:
//!
//! * `BENCH_FILTER=<substring>` — run only matching cases (the
//!   reliable spelling; argv filtering also works but cargo's own
//!   `--bench` injection makes argv ambiguous across cargo versions);
//! * `BENCH_OUT=<file>` — on drop, write the suite's results as JSON
//!   (`{"suite": ..., "cases": [{name, iters, mean, p50, p99, min,
//!   max}]}`), the machine-readable feed for `pacpp bench record` /
//!   `obs::regress::BenchHistory`.

use std::time::{Duration, Instant};

use super::json::{obj, Json};
use super::stats::Summary;

/// One registered benchmark's result line.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            crate::util::fmt_secs(s.mean),
            crate::util::fmt_secs(s.p50),
            crate::util::fmt_secs(s.p99),
            self.iters,
        )
    }
}

/// Bench runner: `Bench::new("suite").run("case", || work())`.
pub struct Bench {
    suite: String,
    target: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
    filter: Option<String>,
    /// `BENCH_OUT` destination, captured at construction so a
    /// mid-suite env change cannot split the output.
    out: Option<String>,
}

/// The case filter from a bench binary's argv: the first token that is
/// neither an option (`-...`) nor the value cargo attaches to its own
/// `--bench` injection. The old "first non-`-` token" rule grabbed
/// that `--bench` value (and test-harness positional filters) as a
/// case filter, silently skipping every case.
fn cli_filter<I: IntoIterator<Item = String>>(argv: I) -> Option<String> {
    let mut after_bench = false;
    for a in argv {
        if after_bench {
            after_bench = false;
            continue;
        }
        if a == "--bench" {
            after_bench = true;
            continue;
        }
        if a.starts_with('-') {
            continue;
        }
        return Some(a);
    }
    None
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        // `BENCH_FILTER=substr cargo bench` filters by substring;
        // `cargo bench -- <filter>` works too where cargo passes the
        // filter as a standalone token.
        let filter = std::env::var("BENCH_FILTER")
            .ok()
            .filter(|s| !s.is_empty())
            .or_else(|| cli_filter(std::env::args().skip(1)));
        println!("\n== bench suite: {suite} ==");
        println!(
            "{:<48} {:>12} {:>12} {:>12}",
            "case", "mean", "p50", "p99"
        );
        Bench {
            suite: suite.to_string(),
            target: Duration::from_millis(
                std::env::var("BENCH_TARGET_MS")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(300),
            ),
            warmup: Duration::from_millis(50),
            results: Vec::new(),
            filter,
            out: std::env::var("BENCH_OUT").ok().filter(|s| !s.is_empty()),
        }
    }

    /// Whether a case name passes the CLI filter.
    pub fn enabled(&self, name: &str) -> bool {
        self.filter
            .as_ref()
            .map(|f| name.contains(f.as_str()) || self.suite.contains(f.as_str()))
            .unwrap_or(true)
    }

    /// Time `f` repeatedly; prints and records a result line.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Option<&BenchResult> {
        if !self.enabled(name) {
            return None;
        }
        // Warmup + calibration.
        let start = Instant::now();
        let mut calib_iters = 0usize;
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.target.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(5, 10_000);

        // Measure in batches of up to 20 samples.
        let samples = iters.min(20);
        let per_sample = (iters / samples).max(1);
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_secs_f64() / per_sample as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples * per_sample,
            summary: Summary::of(&times),
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last()
    }

    /// Run a harness that prints a full table (used for the paper-table
    /// regeneration targets, which are reports rather than timings).
    pub fn table<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        println!("\n-- {name} --");
        f();
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// The `BENCH_OUT` JSON document for the results so far (also
    /// written automatically on drop when the env var is set).
    pub fn to_json(&self) -> Json {
        let cases: Json = self
            .results
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", Json::from(r.name.as_str())),
                    ("iters", Json::from(r.iters)),
                    ("mean", Json::from(r.summary.mean)),
                    ("p50", Json::from(r.summary.p50)),
                    ("p99", Json::from(r.summary.p99)),
                    ("min", Json::from(r.summary.min)),
                    ("max", Json::from(r.summary.max)),
                ])
            })
            .collect();
        obj(vec![("suite", Json::from(self.suite.as_str())), ("cases", cases)])
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        let Some(path) = self.out.clone() else { return };
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        match crate::util::write_creating_dirs(&path, &text) {
            Ok(()) => eprintln!("wrote {path} ({} case(s), bench json)", self.results.len()),
            Err(e) => eprintln!("BENCH_OUT: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `BENCH_OUT`/`BENCH_TARGET_MS` are process-global: tests that
    /// construct a [`Bench`] serialize on this lock so one test's env
    /// setup cannot leak into another's construction.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn bench_measures_something() {
        let _env = ENV_LOCK.lock().unwrap();
        std::env::set_var("BENCH_TARGET_MS", "20");
        let mut b = Bench::new("test");
        let r = b
            .run("spin", || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(i);
                }
                s
            })
            .cloned();
        let r = r.unwrap();
        assert!(r.summary.mean > 0.0);
        assert!(r.iters >= 5);
    }

    #[test]
    fn cli_filter_skips_options_and_cargos_bench_value() {
        let f = |toks: &[&str]| cli_filter(toks.iter().map(|s| s.to_string()));
        assert_eq!(f(&[]), None);
        assert_eq!(f(&["--bench"]), None, "cargo's bare injection");
        assert_eq!(f(&["--bench", "bench_fleet"]), None, "cargo's --bench value");
        assert_eq!(f(&["--bench", "bench_fleet", "oracle"]), Some("oracle".into()));
        assert_eq!(f(&["oracle"]), Some("oracle".into()));
        assert_eq!(f(&["-q", "--exact", "oracle"]), Some("oracle".into()));
    }

    #[test]
    fn bench_out_writes_machine_readable_results_on_drop() {
        let _env = ENV_LOCK.lock().unwrap();
        let base = std::env::temp_dir().join(format!("pacpp_bo_{}", std::process::id()));
        let path = base.join("bench.json");
        std::env::set_var("BENCH_TARGET_MS", "20");
        std::env::set_var("BENCH_OUT", path.to_str().unwrap());
        {
            let mut b = Bench::new("out_suite");
            b.run("spin", || std::hint::black_box((0..100u64).sum::<u64>()));
        } // drop writes the file
        std::env::remove_var("BENCH_OUT");
        let text = std::fs::read_to_string(&path).expect("BENCH_OUT file written on drop");
        let json = Json::parse(&text).expect("bench json parses");
        assert_eq!(json.get("suite").unwrap().as_str(), Some("out_suite"));
        let cases = json.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        let case = &cases[0];
        assert_eq!(case.get("name").unwrap().as_str(), Some("spin"));
        for field in ["iters", "mean", "p50", "p99", "min", "max"] {
            assert!(
                case.get(field).and_then(Json::as_f64).is_some_and(|v| v >= 0.0),
                "{field} missing or negative"
            );
        }
        std::fs::remove_dir_all(&base).unwrap();
    }
}
