//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bench`] with `harness = false`. It performs
//! warmup, adaptively picks an iteration count targeting a measurement
//! window, and reports mean/p50/p99.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One registered benchmark's result line.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            crate::util::fmt_secs(s.mean),
            crate::util::fmt_secs(s.p50),
            crate::util::fmt_secs(s.p99),
            self.iters,
        )
    }
}

/// Bench runner: `Bench::new("suite").run("case", || work())`.
pub struct Bench {
    suite: String,
    target: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        // `cargo bench -- <filter>` filters by substring.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        println!("\n== bench suite: {suite} ==");
        println!(
            "{:<48} {:>12} {:>12} {:>12}",
            "case", "mean", "p50", "p99"
        );
        Bench {
            suite: suite.to_string(),
            target: Duration::from_millis(
                std::env::var("BENCH_TARGET_MS")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(300),
            ),
            warmup: Duration::from_millis(50),
            results: Vec::new(),
            filter,
        }
    }

    /// Whether a case name passes the CLI filter.
    pub fn enabled(&self, name: &str) -> bool {
        self.filter
            .as_ref()
            .map(|f| name.contains(f.as_str()) || self.suite.contains(f.as_str()))
            .unwrap_or(true)
    }

    /// Time `f` repeatedly; prints and records a result line.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Option<&BenchResult> {
        if !self.enabled(name) {
            return None;
        }
        // Warmup + calibration.
        let start = Instant::now();
        let mut calib_iters = 0usize;
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.target.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(5, 10_000);

        // Measure in batches of up to 20 samples.
        let samples = iters.min(20);
        let per_sample = (iters / samples).max(1);
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_secs_f64() / per_sample as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples * per_sample,
            summary: Summary::of(&times),
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last()
    }

    /// Run a harness that prints a full table (used for the paper-table
    /// regeneration targets, which are reports rather than timings).
    pub fn table<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        println!("\n-- {name} --");
        f();
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("BENCH_TARGET_MS", "20");
        let mut b = Bench::new("test");
        let r = b
            .run("spin", || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(i);
                }
                s
            })
            .cloned();
        let r = r.unwrap();
        assert!(r.summary.mean > 0.0);
        assert!(r.iters >= 5);
    }
}
