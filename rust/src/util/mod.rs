//! Infrastructure stand-ins for crates unavailable in the offline image
//! (serde/clap/criterion/proptest — see DESIGN.md §1) plus shared helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a byte count as a human-readable string.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds adaptively (µs → hours).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0 µs");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(3.0), "3.00 s");
        assert_eq!(fmt_secs(7200.0), "2.00 h");
    }
}
