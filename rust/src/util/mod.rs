//! Infrastructure stand-ins for crates unavailable in the offline image
//! (serde/clap/criterion/proptest — see DESIGN.md §1) plus shared helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod registry;
pub mod rng;
pub mod stats;

/// Format a byte count as a human-readable string.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Map `f` over `0..n` on scoped worker threads (one per available
/// core, capped at `n`), preserving index order in the returned vector.
///
/// The experiment harnesses use this to evaluate candidate strategies /
/// table cells concurrently — each cell is an independent plan+simulate.
/// Falls back to a plain serial map when only one core is available.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(n)
        .max(1);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|sc| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                sc.spawn(move || {
                    (w..n).step_by(threads).map(|i| (i, f(i))).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("par_map worker panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("par_map: unfilled slot")).collect()
}

/// Create `path`'s missing parent directories, if any. The error names
/// the directory that could not be created — the one copy of this
/// logic, shared by [`write_creating_dirs`] and the CLI's up-front
/// `--out` validation.
pub fn ensure_parent_dirs(path: &str) -> crate::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() && !dir.is_dir() {
            std::fs::create_dir_all(dir).map_err(|e| {
                anyhow::anyhow!("cannot create directory {}: {e}", dir.display())
            })?;
        }
    }
    Ok(())
}

/// Write `contents` to `path`, creating any missing parent directories
/// first (`--out results/deep/file.json` must not die on a raw io
/// error). Failures carry the directory or file that could not be
/// created.
pub fn write_creating_dirs(path: &str, contents: &str) -> crate::Result<()> {
    ensure_parent_dirs(path)?;
    std::fs::write(path, contents).map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))
}

/// Append `contents` to `path`, creating the file and any missing
/// parent directories first. The bench history (`obs::regress`) is an
/// append-only JSONL file: every `pacpp bench record` adds lines and
/// never rewrites what earlier commits recorded.
pub fn append_creating_dirs(path: &str, contents: &str) -> crate::Result<()> {
    use std::io::Write;
    ensure_parent_dirs(path)?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| anyhow::anyhow!("cannot open {path}: {e}"))?;
    f.write_all(contents.as_bytes())
        .map_err(|e| anyhow::anyhow!("cannot write {path}: {e}"))
}

/// Format a duration in seconds adaptively (µs → hours).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0 µs");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(3.0), "3.00 s");
        assert_eq!(fmt_secs(7200.0), "2.00 h");
    }

    #[test]
    fn write_creating_dirs_makes_parents() {
        let base = std::env::temp_dir().join(format!("pacpp_wcd_{}", std::process::id()));
        let nested = base.join("a/b/out.json");
        let path = nested.to_str().unwrap();
        write_creating_dirs(path, "{}\n").unwrap();
        assert_eq!(std::fs::read_to_string(&nested).unwrap(), "{}\n");
        // bare filenames (no parent) and existing directories also work
        write_creating_dirs(path, "[]\n").unwrap();
        assert_eq!(std::fs::read_to_string(&nested).unwrap(), "[]\n");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn write_creating_dirs_names_the_obstacle() {
        let base = std::env::temp_dir().join(format!("pacpp_wcd_err_{}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        // a *file* where a parent directory is needed
        let file = base.join("blocker");
        std::fs::write(&file, "x").unwrap();
        let target = file.join("deeper/out.json");
        let err = write_creating_dirs(target.to_str().unwrap(), "{}")
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot create directory"), "{err}");
        assert!(err.contains("blocker"), "{err}");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn append_creating_dirs_accumulates() {
        let base = std::env::temp_dir().join(format!("pacpp_acd_{}", std::process::id()));
        let nested = base.join("h/history.jsonl");
        let path = nested.to_str().unwrap();
        append_creating_dirs(path, "{\"a\": 1}\n").unwrap();
        append_creating_dirs(path, "{\"a\": 2}\n").unwrap();
        assert_eq!(std::fs::read_to_string(&nested).unwrap(), "{\"a\": 1}\n{\"a\": 2}\n");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn par_map_preserves_order() {
        let got = par_map(37, |i| i * i);
        let want: Vec<usize> = (0..37).map(|i| i * i).collect();
        assert_eq!(got, want);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 1), vec![1]);
    }
}
