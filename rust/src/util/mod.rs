//! Infrastructure stand-ins for crates unavailable in the offline image
//! (serde/clap/criterion/proptest — see DESIGN.md §1) plus shared helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a byte count as a human-readable string.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Map `f` over `0..n` on scoped worker threads (one per available
/// core, capped at `n`), preserving index order in the returned vector.
///
/// The experiment harnesses use this to evaluate candidate strategies /
/// table cells concurrently — each cell is an independent plan+simulate.
/// Falls back to a plain serial map when only one core is available.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(n)
        .max(1);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|sc| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                sc.spawn(move || {
                    (w..n).step_by(threads).map(|i| (i, f(i))).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("par_map worker panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("par_map: unfilled slot")).collect()
}

/// Format a duration in seconds adaptively (µs → hours).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-4), "50.0 µs");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(3.0), "3.00 s");
        assert_eq!(fmt_secs(7200.0), "2.00 h");
    }

    #[test]
    fn par_map_preserves_order() {
        let got = par_map(37, |i| i * i);
        let want: Vec<usize> = (0..37).map(|i| i * i).collect();
        assert_eq!(got, want);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 1), vec![1]);
    }
}
