//! Small statistics helpers shared by the bench harness and experiments.
//!
//! Besides the exact [`Summary`]/[`percentile`] helpers this module
//! hosts [`QuantileSketch`], the deterministic streaming quantile
//! estimator the metric assemblers use at fleet scale: below
//! [`SKETCH_EXACT_LIMIT`] observations it answers with the exact
//! sorted interpolation (bit-identical to collect-and-sort), above it
//! it switches to fixed-state P² estimation so a million-sample run
//! never materialises or sorts the full sample vector.

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        // Moments come straight off the input; only the order
        // statistics need the sorted copy.
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50).expect("non-empty"),
            p95: percentile(&sorted, 0.95).expect("non-empty"),
            p99: percentile(&sorted, 0.99).expect("non-empty"),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a **sorted** slice; `None` on
/// empty input.
pub fn percentile(sorted: &[f64], q: f64) -> Option<f64> {
    assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if sorted.is_empty() {
        return None;
    }
    if sorted.len() == 1 {
        return Some(sorted[0]);
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    Some(sorted[lo] * (1.0 - frac) + sorted[hi] * frac)
}

/// Geometric mean (used for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Exact-mode capacity of [`QuantileSketch`]: runs with at most this
/// many observations keep the raw samples and report sorted-exact
/// percentiles (bit-identical to the historical collect-and-sort
/// path), so every golden/grid test — all far below this — is
/// unaffected by the streaming estimator. This is the "sketch
/// threshold" scaling knob: raise it for more exactness, lower it for
/// a smaller memory ceiling.
pub const SKETCH_EXACT_LIMIT: usize = 4096;

/// One P² estimator (Jain & Chlamtac, 1985): five markers tracking a
/// single quantile with O(1) state and no randomness.
#[derive(Debug, Clone)]
struct P2Cell {
    q: f64,
    /// Marker heights `q_0..q_4` (estimates of the 0, q/2, q, (1+q)/2
    /// and 1 quantiles).
    heights: [f64; 5],
    /// Marker positions, 1-based as in the paper.
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Per-observation desired-position increments.
    incr: [f64; 5],
}

impl P2Cell {
    /// Seed the five markers from an already-sorted sample (the spilled
    /// exact buffer), placing each marker on the order statistic
    /// nearest its ideal position.
    fn seed(q: f64, sorted: &[f64]) -> P2Cell {
        let m = sorted.len();
        debug_assert!(m >= 5, "seed needs at least 5 samples");
        let incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0];
        let mut pos = [0.0; 5];
        for i in 0..5 {
            let ideal = (1.0 + (m - 1) as f64 * incr[i]).round();
            // keep marker i inside [i+1, m-(4-i)] so the cascade below
            // can always restore strict monotonicity
            pos[i] = ideal.clamp((i + 1) as f64, (m - (4 - i)) as f64);
        }
        for i in 1..5 {
            if pos[i] <= pos[i - 1] {
                pos[i] = pos[i - 1] + 1.0;
            }
        }
        let mut heights = [0.0; 5];
        for i in 0..5 {
            heights[i] = sorted[pos[i] as usize - 1];
        }
        let mut desired = [0.0; 5];
        for i in 0..5 {
            desired[i] = 1.0 + (m - 1) as f64 * incr[i];
        }
        P2Cell { q, heights, pos, desired, incr }
    }

    fn add(&mut self, x: f64) {
        // Locate the marker interval containing x, extending the
        // extremes when it falls outside them.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 1..4 {
                if x >= self.heights[i] {
                    k = i;
                }
            }
            k
        };
        for p in self.pos[k + 1..].iter_mut() {
            *p += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.incr[i];
        }
        // Nudge interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let d = d.signum();
                let candidate = self.parabolic(i, d);
                self.heights[i] =
                    if self.heights[i - 1] < candidate && candidate < self.heights[i + 1] {
                        candidate
                    } else {
                        self.linear(i, d)
                    };
                self.pos[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let h = &self.heights;
        let n = &self.pos;
        h[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.heights[i] + d * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    fn estimate(&self) -> f64 {
        self.heights[2]
    }
}

/// Deterministic streaming quantile estimator over a fixed set of
/// tracked quantiles.
///
/// Up to `exact_limit` observations the raw samples are buffered and
/// queries answer with the exact [`percentile`] interpolation — the
/// same values (to the bit) as the historical collect-and-sort code.
/// Past the limit the buffer is spilled once into one [`P2Cell`] per
/// tracked quantile and subsequent observations stream through in O(1)
/// per tracked quantile with no further allocation. The whole state is
/// a pure function of the input sequence: no RNG, no hashing, no
/// platform-dependent iteration order.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    quantiles: Vec<f64>,
    exact_limit: usize,
    exact: Vec<f64>,
    count: usize,
    min: f64,
    max: f64,
    cells: Vec<P2Cell>,
}

impl QuantileSketch {
    /// A sketch tracking `quantiles` (each in `[0, 1]`) that stays
    /// exact up to `exact_limit` observations (clamped to at least 8 so
    /// the P² seeding always has enough samples).
    pub fn new(quantiles: &[f64], exact_limit: usize) -> QuantileSketch {
        for &q in quantiles {
            assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        }
        QuantileSketch {
            quantiles: quantiles.to_vec(),
            exact_limit: exact_limit.max(8),
            exact: Vec::new(),
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            cells: Vec::new(),
        }
    }

    /// Observe one sample.
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.cells.is_empty() {
            self.exact.push(x);
            if self.exact.len() > self.exact_limit {
                self.spill();
            }
        } else {
            for cell in &mut self.cells {
                cell.add(x);
            }
        }
    }

    fn spill(&mut self) {
        let mut sorted = std::mem::take(&mut self.exact);
        sorted.sort_by(|a, b| a.total_cmp(b));
        self.cells = self
            .quantiles
            .iter()
            .map(|&q| P2Cell::seed(q, &sorted))
            .collect();
    }

    /// Number of observations so far.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Whether queries still come from the exact buffer.
    pub fn is_exact(&self) -> bool {
        self.cells.is_empty()
    }

    /// Estimate several quantiles at once, sorting the exact buffer at
    /// most once (callers should prefer this over repeated
    /// [`QuantileSketch::quantile`] calls). `None` entries mean the
    /// sketch saw no observations.
    ///
    /// Panics if a requested quantile is not one of the tracked set and
    /// the sketch has already spilled to streaming mode.
    pub fn quantile_many(&self, qs: &[f64]) -> Vec<Option<f64>> {
        if self.count == 0 {
            return qs.iter().map(|_| None).collect();
        }
        if self.cells.is_empty() {
            let mut sorted = self.exact.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            return qs.iter().map(|&q| percentile(&sorted, q)).collect();
        }
        qs.iter()
            .map(|&q| {
                let cell = self
                    .quantiles
                    .iter()
                    .position(|&t| (t - q).abs() < 1e-9)
                    .map(|i| &self.cells[i])
                    .unwrap_or_else(|| panic!("quantile {q} not tracked by this sketch"));
                Some(cell.estimate().clamp(self.min, self.max))
            })
            .collect()
    }

    /// Estimate one quantile; see [`QuantileSketch::quantile_many`].
    pub fn quantile(&self, q: f64) -> Option<f64> {
        self.quantile_many(&[q]).pop().expect("one query, one answer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 0.5).unwrap() - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), Some(0.0));
        assert_eq!(percentile(&v, 1.0), Some(10.0));
    }

    #[test]
    fn percentile_of_empty_is_none() {
        assert_eq!(percentile(&[], 0.5), None);
    }

    #[test]
    fn geomean_of_equal() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn sketch_below_limit_matches_exact_sort_bitwise() {
        let mut sketch = QuantileSketch::new(&[0.5, 0.95, 0.99], 4096);
        let mut vals = Vec::new();
        let mut x = 17u64;
        for _ in 0..1000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (x >> 11) as f64 / (1u64 << 53) as f64 * 500.0;
            vals.push(v);
            sketch.add(v);
        }
        assert!(sketch.is_exact());
        vals.sort_by(|a, b| a.total_cmp(b));
        let got = sketch.quantile_many(&[0.5, 0.95, 0.99]);
        for (g, q) in got.iter().zip([0.5, 0.95, 0.99]) {
            assert_eq!(*g, percentile(&vals, q), "q={q} must be bit-identical");
        }
    }

    #[test]
    fn sketch_streams_accurately_past_limit() {
        // 100k samples from a deterministic LCG, limit 256: the P²
        // estimate of the uniform's quantiles should land within a few
        // percent of the exact value.
        let mut sketch = QuantileSketch::new(&[0.5, 0.95, 0.99], 256);
        let mut vals = Vec::new();
        let mut x = 99u64;
        for _ in 0..100_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (x >> 11) as f64 / (1u64 << 53) as f64;
            vals.push(v);
            sketch.add(v);
        }
        assert!(!sketch.is_exact());
        assert_eq!(sketch.len(), 100_000);
        vals.sort_by(|a, b| a.total_cmp(b));
        for q in [0.5, 0.95, 0.99] {
            let est = sketch.quantile(q).unwrap();
            let exact = percentile(&vals, q).unwrap();
            assert!(
                (est - exact).abs() < 0.02,
                "q={q}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn sketch_hands_off_exactly_past_the_exact_limit() {
        // Pins the spill boundary: `SKETCH_EXACT_LIMIT` observations
        // still answer bit-identically to the sorted-exact path, the
        // very next observation flips the sketch into P² streaming
        // mode (estimates only), and an untouched sketch keeps
        // answering `None`.
        assert_eq!(QuantileSketch::new(&[0.5], SKETCH_EXACT_LIMIT).quantile(0.5), None);

        let mut sketch = QuantileSketch::new(&[0.5, 0.95], SKETCH_EXACT_LIMIT);
        let mut vals = Vec::new();
        let mut x = 7u64;
        let mut next = || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (x >> 11) as f64 / (1u64 << 53) as f64 * 500.0
        };
        let exact_at = |vals: &[f64], q: f64| {
            let mut sorted = vals.to_vec();
            sorted.sort_by(|a, b| a.total_cmp(b));
            percentile(&sorted, q)
        };
        for _ in 0..SKETCH_EXACT_LIMIT - 1 {
            let v = next();
            vals.push(v);
            sketch.add(v);
        }
        assert!(sketch.is_exact(), "LIMIT - 1 observations must stay exact");
        assert_eq!(sketch.quantile(0.5), exact_at(&vals, 0.5), "bitwise at LIMIT - 1");

        let v = next();
        vals.push(v);
        sketch.add(v); // observation number LIMIT: the last exact one
        assert!(sketch.is_exact(), "exactly LIMIT observations must stay exact");
        assert_eq!(sketch.quantile(0.95), exact_at(&vals, 0.95), "bitwise at LIMIT");

        let v = next();
        vals.push(v);
        sketch.add(v); // LIMIT + 1 spills into streaming mode
        assert!(!sketch.is_exact(), "LIMIT + 1 observations must spill to P²");
        assert_eq!(sketch.len(), SKETCH_EXACT_LIMIT + 1);
        for q in [0.5, 0.95] {
            let est = sketch.quantile(q).unwrap();
            let exact = exact_at(&vals, q).unwrap();
            assert!(
                (est - exact).abs() < 5.0, // 1% of the 500-wide uniform range
                "q={q} just past the spill: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn sketch_is_deterministic() {
        let feed = |seed: u64| {
            let mut s = QuantileSketch::new(&[0.5, 0.99], 64);
            let mut x = seed;
            for _ in 0..5000 {
                x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
                s.add((x >> 12) as f64);
            }
            s.quantile_many(&[0.5, 0.99])
        };
        assert_eq!(feed(42), feed(42));
    }

    #[test]
    fn sketch_empty_and_single() {
        let mut s = QuantileSketch::new(&[0.5], 16);
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), None);
        s.add(7.5);
        assert_eq!(s.quantile(0.5), Some(7.5));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn sketch_monotone_stream() {
        // A sorted stream is the adversarial case for P² seeding; the
        // estimate must still stay inside the observed range and close
        // to the true quantile.
        let mut s = QuantileSketch::new(&[0.5], 32);
        for i in 0..10_000 {
            s.add(i as f64);
        }
        let est = s.quantile(0.5).unwrap();
        assert!(est >= 0.0 && est <= 9999.0);
        assert!((est - 4999.5).abs() < 500.0, "p50 of 0..10000 ≈ 5000, got {est}");
    }
}
