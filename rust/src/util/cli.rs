//! Tiny command-line parser (clap is unavailable offline).
//!
//! Supports `pacpp <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, and --options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_usize_opt(name).unwrap_or(default)
    }

    /// Like [`Args::get_usize`] but `None` when the option is absent
    /// (for knobs whose default is computed, e.g. planner threads).
    pub fn get_usize_opt(&self, name: &str) -> Option<usize> {
        self.get(name).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}"))
        })
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("plan env_a t5-large");
        assert_eq!(a.subcommand.as_deref(), Some("plan"));
        assert_eq!(a.positional, vec!["env_a", "t5-large"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse("train --epochs 3 --lr=0.1 --verbose --model base100m");
        assert_eq!(a.get_usize("epochs", 0), 3);
        assert!((a.get_f64("lr", 0.0) - 0.1).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("model"), Some("base100m"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.flag("quick"));
        assert!(a.get("quick").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_usize_opt("missing"), None);
    }

    #[test]
    fn optional_usize() {
        let a = parse("plan --threads 3");
        assert_eq!(a.get_usize_opt("threads"), Some(3));
        assert_eq!(a.get_usize("threads", 1), 3);
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(vec![]);
        assert_eq!(a.subcommand, None);
        assert!(a.positional.is_empty());
        assert!(a.options.is_empty());
        assert!(a.flags.is_empty());
    }

    #[test]
    fn equals_value_may_contain_equals() {
        // only the first '=' splits: `--filter key=value` stays intact
        let a = parse("run --filter a=b --empty=");
        assert_eq!(a.get("filter"), Some("a=b"));
        assert_eq!(a.get("empty"), Some(""));
    }

    #[test]
    fn flag_followed_by_option_stays_a_flag() {
        let a = parse("plan --homo --threads 3");
        assert!(a.flag("homo"));
        assert!(a.get("homo").is_none());
        assert_eq!(a.get_usize_opt("threads"), Some(3));
    }

    #[test]
    fn negative_number_is_a_value_not_a_flag() {
        // single-dash tokens don't look like options, so they bind as
        // the preceding key's value
        let a = parse("train --lr -0.5");
        assert!((a.get_f64("lr", 0.0) + 0.5).abs() < 1e-12);
        assert!(!a.flag("lr"));
    }

    #[test]
    fn positionals_interleave_with_options() {
        let a = parse("exp run table5 --format json --out report.json");
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["run", "table5"]);
        assert_eq!(a.get("format"), Some("json"));
        assert_eq!(a.get("out"), Some("report.json"));
    }

    #[test]
    fn repeated_option_last_wins() {
        let a = parse("plan --m 2 --m 4");
        assert_eq!(a.get_usize_opt("m"), Some(4));
    }

    #[test]
    fn repeated_flag_still_answers_true() {
        let a = parse("bench --quick --quick");
        assert!(a.flag("quick"));
        assert_eq!(a.flags.iter().filter(|f| *f == "quick").count(), 2);
    }
}
