//! Tiny command-line parser (clap is unavailable offline).
//!
//! Supports `pacpp <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, and --options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_usize_opt(name).unwrap_or(default)
    }

    /// Like [`Args::get_usize`] but `None` when the option is absent
    /// (for knobs whose default is computed, e.g. planner threads).
    pub fn get_usize_opt(&self, name: &str) -> Option<usize> {
        self.get(name).map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}"))
        })
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    // -- checked getters ----------------------------------------------------
    //
    // `get_usize`/`get_f64` predate error plumbing and panic on garbage;
    // the checked getters below return the uniform "invalid value for
    // --flag" diagnostic instead, and additionally reject values that
    // parse but are nonsensical (a zero count, a zero seed). New flags
    // should use these.

    /// Checked count (`--jobs 40`, `--threads 4`): absent → `default`;
    /// zero, negative or unparseable → an "invalid value" error.
    pub fn get_count(&self, name: &str, default: usize) -> crate::Result<usize> {
        Ok(self.get_count_opt(name)?.unwrap_or(default))
    }

    /// Like [`Args::get_count`] but `None` when the option is absent
    /// (for knobs whose default is computed, e.g. planner threads).
    pub fn get_count_opt(&self, name: &str) -> crate::Result<Option<usize>> {
        if self.flag(name) {
            return Err(invalid_value(name, "", "a positive integer"));
        }
        let Some(v) = self.get(name) else { return Ok(None) };
        match v.parse::<usize>() {
            Ok(n) if n > 0 => Ok(Some(n)),
            _ => Err(invalid_value(name, v, "a positive integer")),
        }
    }

    /// Checked non-negative count (`--ckpt 0`, `--over-select 0`):
    /// zero is a meaningful "off"/"none" value, so only a bare flag or
    /// an unparseable value is rejected.
    pub fn get_count0(&self, name: &str, default: usize) -> crate::Result<usize> {
        if self.flag(name) {
            return Err(invalid_value(name, "", "a non-negative integer"));
        }
        let Some(v) = self.get(name) else { return Ok(default) };
        match v.parse::<usize>() {
            Ok(n) => Ok(n),
            Err(_) => Err(invalid_value(name, v, "a non-negative integer")),
        }
    }

    /// Checked RNG seed (`--seed 42`): a positive integer, so every
    /// seeded run is reproducible by quoting one number.
    pub fn get_seed(&self, name: &str, default: u64) -> crate::Result<u64> {
        if self.flag(name) {
            return Err(invalid_value(name, "", "a positive integer"));
        }
        let Some(v) = self.get(name) else { return Ok(default) };
        match v.parse::<u64>() {
            Ok(n) if n > 0 => Ok(n),
            _ => Err(invalid_value(name, v, "a positive integer")),
        }
    }

    /// Checked string option (`--trace diurnal`): absent → `default`;
    /// present as a bare flag (the value was forgotten or swallowed by
    /// the next `--option`) → an "invalid value" error instead of a
    /// silent default.
    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> crate::Result<&'a str> {
        if self.flag(name) {
            return Err(invalid_value(name, "", "a value"));
        }
        Ok(self.get(name).unwrap_or(default))
    }

    /// Checked non-negative rate (`--churn 2.5`): absent → `default`;
    /// negative, non-finite or unparseable → an "invalid value" error.
    pub fn get_rate(&self, name: &str, default: f64) -> crate::Result<f64> {
        self.checked_f64(name, default, 0.0, "a non-negative number")
    }

    /// Checked positive magnitude (`--horizon 48`): zero is rejected
    /// too — a zero horizon or size is never a meaningful run.
    pub fn get_positive_f64(&self, name: &str, default: f64) -> crate::Result<f64> {
        self.checked_f64(name, default, f64::MIN_POSITIVE, "a positive number")
    }

    fn checked_f64(
        &self,
        name: &str,
        default: f64,
        min: f64,
        expected: &str,
    ) -> crate::Result<f64> {
        if self.flag(name) {
            return Err(invalid_value(name, "", expected));
        }
        let Some(v) = self.get(name) else { return Ok(default) };
        match v.parse::<f64>() {
            Ok(x) if x.is_finite() && x >= min => Ok(x),
            _ => Err(invalid_value(name, v, expected)),
        }
    }
}

/// The one spelling of the bad-numeric-flag diagnostic.
fn invalid_value(name: &str, got: &str, expected: &str) -> anyhow::Error {
    anyhow::anyhow!("invalid value for --{name}: {got:?} (expected {expected})")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("plan env_a t5-large");
        assert_eq!(a.subcommand.as_deref(), Some("plan"));
        assert_eq!(a.positional, vec!["env_a", "t5-large"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse("train --epochs 3 --lr=0.1 --verbose --model base100m");
        assert_eq!(a.get_usize("epochs", 0), 3);
        assert!((a.get_f64("lr", 0.0) - 0.1).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("model"), Some("base100m"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.flag("quick"));
        assert!(a.get("quick").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_usize_opt("missing"), None);
    }

    #[test]
    fn optional_usize() {
        let a = parse("plan --threads 3");
        assert_eq!(a.get_usize_opt("threads"), Some(3));
        assert_eq!(a.get_usize("threads", 1), 3);
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(vec![]);
        assert_eq!(a.subcommand, None);
        assert!(a.positional.is_empty());
        assert!(a.options.is_empty());
        assert!(a.flags.is_empty());
    }

    #[test]
    fn equals_value_may_contain_equals() {
        // only the first '=' splits: `--filter key=value` stays intact
        let a = parse("run --filter a=b --empty=");
        assert_eq!(a.get("filter"), Some("a=b"));
        assert_eq!(a.get("empty"), Some(""));
    }

    #[test]
    fn flag_followed_by_option_stays_a_flag() {
        let a = parse("plan --homo --threads 3");
        assert!(a.flag("homo"));
        assert!(a.get("homo").is_none());
        assert_eq!(a.get_usize_opt("threads"), Some(3));
    }

    #[test]
    fn negative_number_is_a_value_not_a_flag() {
        // single-dash tokens don't look like options, so they bind as
        // the preceding key's value
        let a = parse("train --lr -0.5");
        assert!((a.get_f64("lr", 0.0) + 0.5).abs() < 1e-12);
        assert!(!a.flag("lr"));
    }

    #[test]
    fn positionals_interleave_with_options() {
        let a = parse("exp run table5 --format json --out report.json");
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["run", "table5"]);
        assert_eq!(a.get("format"), Some("json"));
        assert_eq!(a.get("out"), Some("report.json"));
    }

    #[test]
    fn repeated_option_last_wins() {
        let a = parse("plan --m 2 --m 4");
        assert_eq!(a.get_usize_opt("m"), Some(4));
    }

    #[test]
    fn repeated_flag_still_answers_true() {
        let a = parse("bench --quick --quick");
        assert!(a.flag("quick"));
        assert_eq!(a.flags.iter().filter(|f| *f == "quick").count(), 2);
    }

    #[test]
    fn checked_count_accepts_positive() {
        let a = parse("fleet --jobs 50 --threads 4");
        assert_eq!(a.get_count("jobs", 1).unwrap(), 50);
        assert_eq!(a.get_count_opt("threads").unwrap(), Some(4));
        assert_eq!(a.get_count("absent", 7).unwrap(), 7);
        assert_eq!(a.get_count_opt("absent").unwrap(), None);
    }

    #[test]
    fn nonneg_count_accepts_zero_rejects_garbage() {
        let a = parse("fleet --ckpt 0 --over-select 3");
        assert_eq!(a.get_count0("ckpt", 9).unwrap(), 0);
        assert_eq!(a.get_count0("over-select", 9).unwrap(), 3);
        assert_eq!(a.get_count0("absent", 9).unwrap(), 9);
        for argv in ["fleet --ckpt -1", "fleet --ckpt 1.5", "fleet --ckpt many", "fleet --ckpt"] {
            let err = parse(argv).get_count0("ckpt", 0).unwrap_err().to_string();
            assert!(err.contains("invalid value for --ckpt"), "{argv}: {err}");
            assert!(err.contains("non-negative"), "{argv}: {err}");
        }
    }

    #[test]
    fn checked_count_rejects_zero_and_garbage() {
        for (argv, flag) in [
            ("fleet --jobs 0", "jobs"),
            ("fleet --jobs -3", "jobs"),
            ("fleet --jobs 1.5", "jobs"),
            ("fleet --jobs many", "jobs"),
            ("plan --threads 0", "threads"),
            ("plan --threads=0x4", "threads"),
        ] {
            let a = parse(argv);
            let err = a.get_count(flag, 1).unwrap_err().to_string();
            assert!(
                err.contains(&format!("invalid value for --{flag}")),
                "{argv}: {err}"
            );
        }
        // a value-less trailing flag is not silently the default
        let a = parse("fleet --jobs");
        assert!(a.get_count("jobs", 1).is_err());
        assert!(a.get_count_opt("jobs").is_err());
    }

    #[test]
    fn checked_seed() {
        let a = parse("fleet --seed 1234");
        assert_eq!(a.get_seed("seed", 42).unwrap(), 1234);
        assert_eq!(parse("fleet").get_seed("seed", 42).unwrap(), 42);
        for argv in ["fleet --seed 0", "fleet --seed -1", "fleet --seed abc", "fleet --seed"] {
            let err = parse(argv).get_seed("seed", 42).unwrap_err().to_string();
            assert!(err.contains("invalid value for --seed"), "{argv}: {err}");
        }
    }

    #[test]
    fn checked_str_rejects_bare_flag() {
        let a = parse("fleet --trace diurnal");
        assert_eq!(a.get_str("trace", "steady").unwrap(), "diurnal");
        assert_eq!(parse("fleet").get_str("trace", "steady").unwrap(), "steady");
        // `--policy --format json`: policy parsed as a bare flag
        let a = parse("fleet --policy --format json");
        let err = a.get_str("policy", "all").unwrap_err().to_string();
        assert!(err.contains("invalid value for --policy"), "{err}");
    }

    #[test]
    fn trace_flags_validate_like_the_rest() {
        let a = parse("fleet --trace-out out/trace.json --trace-sample 4");
        assert_eq!(a.get_str("trace-out", "").unwrap(), "out/trace.json");
        assert_eq!(a.get_count("trace-sample", 1).unwrap(), 4);
        // absent → defaults (tracing off, record everything)
        assert_eq!(parse("fleet").get_str("trace-out", "").unwrap(), "");
        assert_eq!(parse("fleet").get_count("trace-sample", 1).unwrap(), 1);
        // zero, negative, fractional, textual and value-less samples
        // all get the uniform diagnostic
        for argv in [
            "fleet --trace-sample 0",
            "fleet --trace-sample -3",
            "fleet --trace-sample 1.5",
            "fleet --trace-sample many",
            "fleet --trace-sample",
        ] {
            let err = parse(argv).get_count("trace-sample", 1).unwrap_err().to_string();
            assert!(err.contains("invalid value for --trace-sample"), "{argv}: {err}");
            assert!(err.contains("positive integer"), "{argv}: {err}");
        }
        // `--trace-out --format json`: the swallowed value surfaces as
        // an error, not a silent no-trace run
        let err = parse("fleet --trace-out --format json")
            .get_str("trace-out", "")
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid value for --trace-out"), "{err}");
    }

    #[test]
    fn checked_floats() {
        let a = parse("fleet --churn 2.5 --horizon 12");
        assert!((a.get_rate("churn", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert!((a.get_positive_f64("horizon", 48.0).unwrap() - 12.0).abs() < 1e-12);
        // defaults when absent
        assert_eq!(parse("fleet").get_rate("churn", 0.0).unwrap(), 0.0);
        assert_eq!(parse("fleet").get_positive_f64("horizon", 48.0).unwrap(), 48.0);
        // zero is a valid rate but not a valid positive magnitude
        assert_eq!(parse("fleet --churn 0").get_rate("churn", 1.0).unwrap(), 0.0);
        assert!(parse("fleet --horizon 0").get_positive_f64("horizon", 48.0).is_err());
        for argv in [
            "fleet --churn -2",
            "fleet --churn abc",
            "fleet --churn nan",
            "fleet --churn inf",
            "fleet --churn",
        ] {
            let err = parse(argv).get_rate("churn", 0.0).unwrap_err().to_string();
            assert!(err.contains("invalid value for --churn"), "{argv}: {err}");
        }
    }
}
