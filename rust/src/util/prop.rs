//! Property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, check)` runs `check` on `cases` random inputs
//! from `gen`; on failure it performs a simple halving shrink via the
//! generator's size parameter and reports the smallest failing case found.

use super::rng::Rng;

/// Generator context handed to the input generator: RNG + a size hint that
/// the shrinker reduces on failure.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Integer in [lo, min(hi, lo+size)) — respects the shrink size.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = hi.min(lo + self.size.max(1));
        self.rng.range(lo, hi_eff.max(lo + 1))
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }
}

/// Run a property over `cases` generated inputs. Panics with the smallest
/// failing case's debug representation on failure.
pub fn forall<T: std::fmt::Debug, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = 4 + case * 4; // grow inputs over the run
        let input = gen(&mut Gen { rng: &mut rng, size });
        if let Err(msg) = prop(&input) {
            // shrink: regenerate with smaller sizes from fresh sub-seeds
            let mut smallest = (format!("{input:?}"), msg.clone());
            let mut shrink_size = size / 2;
            while shrink_size >= 1 {
                let mut found = false;
                for attempt in 0..20 {
                    let mut r2 = Rng::new(seed ^ (attempt + 1) ^ (shrink_size as u64) << 32);
                    let cand = gen(&mut Gen { rng: &mut r2, size: shrink_size });
                    if let Err(m2) = prop(&cand) {
                        smallest = (format!("{cand:?}"), m2);
                        found = true;
                        break;
                    }
                }
                if !found {
                    break;
                }
                shrink_size /= 2;
            }
            panic!(
                "property failed (case {case}/{cases}):\n  input: {}\n  error: {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assertion helper for property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |g| g.int(0, 100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 100, |g| g.int(0, 1000), |&x| check(x < 3, format!("x={x}")));
    }

    #[test]
    fn generators_respect_size() {
        let mut rng = Rng::new(3);
        let mut g = Gen { rng: &mut rng, size: 2 };
        for _ in 0..100 {
            assert!(g.int(5, 100) < 7);
        }
    }
}
