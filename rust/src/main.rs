//! `pacpp` — the PAC+ coordinator CLI.
//!
//! ```text
//! pacpp plan     --env env_b --model t5-large [--method pa|full|lora|adapters]
//!                [--strategy pac+] [--minibatch 16] [--microbatch B] [--m M]
//!                [--homo] [--threads N]
//! pacpp simulate --env env_a --model t5-base --samples 3668 --epochs 3
//!                [--system pac+|dp|pp|standalone|asteroid|hetpipe|pac-homo]
//! pacpp strategies                 (list the registered strategies)
//! pacpp table    1|5|6|7           (regenerate a paper table)
//! pacpp fig      3|12|13|15|16|17|18
//! pacpp train    --artifacts artifacts/small --epochs 4 [--pipeline N] [--quant int8]
//! pacpp info     --artifacts artifacts/tiny  (dump manifest summary)
//! ```

use std::sync::Arc;

use pacpp::cluster::Env;
use pacpp::data::SyntheticTask;
use pacpp::exec::{self, TrainOptions};
use pacpp::exp;
use pacpp::model::graph::LayerGraph;
use pacpp::model::{Method, ModelSpec, Precision};
use pacpp::planner::{plan, PlannerOptions};
use pacpp::profiler::Profile;
use pacpp::runtime::Runtime;
use pacpp::strategy::{ParallelismStrategy, StrategyRegistry, TrainJob};
use pacpp::util::cli::Args;
use pacpp::util::{fmt_bytes, fmt_secs};

fn parse_method(s: &str) -> Method {
    match s {
        "full" => Method::FullFT,
        "lora" => Method::lora_default(),
        "adapters" => Method::adapters_default(),
        "pa" => Method::pa(false),
        "pa+cache" | "pac" => Method::pa(true),
        other => panic!("unknown method {other:?} (full|lora|adapters|pa|pa+cache)"),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("plan") => cmd_plan(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("strategies") => cmd_strategies(),
        Some("table") => cmd_table(&args),
        Some("fig") => cmd_fig(&args),
        Some("train") => cmd_train(&args),
        Some("timeline") => cmd_timeline(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("usage: pacpp <plan|simulate|strategies|table|fig|train|info> [options]");
            eprintln!("see rust/src/main.rs docs for options");
            Ok(())
        }
    }
}

/// List the registered parallelism strategies (names, aliases, roles).
fn cmd_strategies() -> anyhow::Result<()> {
    let registry = StrategyRegistry::with_defaults();
    println!("registered parallelism strategies:");
    for s in registry.iter() {
        let aliases = s.aliases().join(", ");
        println!("  {:<14} [{aliases}]", s.name());
        if !s.description().is_empty() {
            println!("  {:<14} {}", "", s.description());
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let env = Env::by_name(args.get_or("env", "env_a")).expect("unknown env");
    let spec = ModelSpec::by_name(args.get_or("model", "t5-base")).expect("unknown model");
    let method = parse_method(args.get_or("method", "pa"));
    let registry = StrategyRegistry::with_defaults();
    let strategy_name = args.get_or("strategy", "pac+");
    let Some(strategy) = registry.get(strategy_name) else {
        anyhow::bail!(
            "unknown strategy {strategy_name:?}; registered: {}",
            registry.names().join(", ")
        );
    };
    let profile = Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, 128);
    // start from the strategy's own job mapping (PAC-Homo turns off
    // heterogeneity awareness, Standalone/DP use mini-batch granularity,
    // ...), then apply explicit CLI overrides on top
    let job = TrainJob::new(0, 1, 128, args.get_usize("minibatch", 16));
    let mut opts = strategy.options(&env, &job);
    if let Some(b) = args.get_usize_opt("microbatch") {
        opts.microbatch = b;
    }
    if let Some(m) = args.get_usize_opt("m") {
        opts.n_microbatches = m;
    }
    if args.flag("homo") {
        opts.hetero_aware = false;
    }
    opts.search_threads = args.get_usize_opt("threads");
    match strategy.plan(&profile, &env, &opts) {
        Ok(p) => {
            println!(
                "{} plan for {} ({}) on {}:",
                strategy.name(),
                spec.name,
                method.name(),
                env.name
            );
            println!("  stages: {}  grouping: {}", p.n_stages(), p.grouping());
            for (i, s) in p.stages.iter().enumerate() {
                let devs: Vec<String> =
                    s.devices.iter().map(|d| format!("{}#{}", d.kind.name(), d.id)).collect();
                println!(
                    "  stage {i}: blocks [{}, {}), devices [{}], dispatch {:?}, peak mem {}",
                    s.range.0,
                    s.range.1,
                    devs.join(", "),
                    s.dispatch,
                    fmt_bytes(s.peak_mem)
                );
            }
            let (lb, le, ln) = p.phase_latency;
            println!(
                "  minibatch: {} (begin {}, exec {}, end {})  throughput {:.2} samples/s",
                fmt_secs(p.minibatch_time),
                fmt_secs(lb),
                fmt_secs(le),
                fmt_secs(ln),
                p.throughput()
            );
        }
        Err(e) => println!("planning failed: {e}"),
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let env = Env::by_name(args.get_or("env", "env_a")).expect("unknown env");
    let spec = ModelSpec::by_name(args.get_or("model", "t5-base")).expect("unknown model");
    let method = parse_method(args.get_or("method", "pa+cache"));
    let registry = StrategyRegistry::with_defaults();
    let system_name = args.get_or("system", "pac+");
    let Some(strategy) = registry.get(system_name) else {
        anyhow::bail!(
            "unknown system {system_name:?}; registered: {}",
            registry.names().join(", ")
        );
    };
    let profile = Profile::new(
        LayerGraph::new(spec.clone()),
        method,
        Precision::FP32,
        args.get_usize("seq", exp::TABLE_SEQ),
    );
    let job = TrainJob::new(
        args.get_usize("samples", 3668),
        args.get_usize("epochs", 3),
        args.get_usize("seq", exp::TABLE_SEQ),
        args.get_usize("minibatch", 16),
    );
    match strategy.run(&profile, &env, job) {
        Ok(r) => {
            println!(
                "{} fine-tuning {} ({}) on {}: {} samples x {} epochs",
                strategy.name(),
                spec.name,
                method.name(),
                env.name,
                job.samples,
                job.epochs
            );
            println!("  epoch 1:        {}", fmt_secs(r.epoch1));
            if r.redistribution > 0.0 {
                println!("  redistribution: {}", fmt_secs(r.redistribution));
                println!("  cached epoch:   {}", fmt_secs(r.epoch_cached));
            }
            println!("  total:          {}", fmt_secs(r.total));
        }
        Err(e) => println!("{}: {e}", strategy.name()),
    }
    Ok(())
}

fn cmd_table(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    match which {
        "1" => exp::print_table1(),
        "5" => exp::print_table5(),
        "6" | "7" => {
            let rt = Arc::new(Runtime::load(args.get_or("artifacts", "artifacts/small"))?);
            let budget = exp::accuracy::Budget::default();
            if which == "6" {
                exp::accuracy::print_table6(&rt, budget)?;
            } else {
                exp::accuracy::print_table7(&rt, budget)?;
            }
        }
        "all" => {
            exp::print_table1();
            exp::print_table5();
        }
        other => eprintln!("unknown table {other} (1|5|6|7|all)"),
    }
    Ok(())
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    match which {
        "3" => exp::print_fig3(),
        "12" => exp::print_fig12(),
        "13" => exp::print_fig13(),
        "14" => {
            let rt = Arc::new(Runtime::load(args.get_or("artifacts", "artifacts/small"))?);
            exp::accuracy::print_fig14(&rt, exp::accuracy::Budget::default())?;
        }
        "15" => exp::print_fig15(),
        "16" => exp::print_fig16(),
        "17" => exp::print_fig17(),
        "18" => exp::print_fig18(),
        "all" => {
            exp::print_fig3();
            exp::print_fig12();
            exp::print_fig13();
            exp::print_fig15();
            exp::print_fig16();
            exp::print_fig17();
            exp::print_fig18();
        }
        other => eprintln!("unknown fig {other}"),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts/small");
    let rt = Arc::new(Runtime::load(dir)?);
    let cfg = rt.manifest.config.clone();
    println!(
        "loaded {} artifacts for config {} ({} backbone params) on {}",
        rt.manifest.artifacts.len(),
        cfg.name,
        cfg.params_backbone,
        rt.platform()
    );
    let n = args.get_usize("samples", 256);
    let task = SyntheticTask::generate(n + 64, cfg.seq_len, cfg.vocab, 0.02, 7);
    let (train, eval) = task.split(64.0 / (n + 64) as f64);

    let mut opts = TrainOptions::new(
        std::path::PathBuf::from(args.get_or("cache-dir", "/tmp/pacpp_cache")),
    );
    opts.epochs = args.get_usize("epochs", 3);
    opts.lr = args.get_f64("lr", 0.005) as f32;
    opts.workers = args.get_usize("workers", 2);
    opts.init_tag = format!("adapter_{}", args.get_or("init", "prune"));
    opts.quant = args.get("quant").map(String::from);
    opts.use_cache = !args.flag("no-cache");

    let t0 = std::time::Instant::now();
    let log = if let Some(stages) = args.get("pipeline") {
        exec::train_pipelined(&rt, &train, &opts, stages.parse().unwrap())?
    } else {
        exec::train_data_parallel(&rt, &train, &opts)?
    };
    println!(
        "trained {} steps in {}: cache hits {}, backbone passes {}",
        log.steps.len(),
        fmt_secs(t0.elapsed().as_secs_f64()),
        log.cache_hits,
        log.backbone_passes
    );
    for (e, t) in log.epoch_times.iter().enumerate() {
        println!("  epoch {e}: {} (mean loss {:.4})", fmt_secs(*t), log.mean_loss(e));
    }
    let adapter = exec::take_final_adapter().expect("adapter missing");
    let (eloss, acc) = exec::evaluate(&rt, &adapter, &eval, &opts.quant)?;
    println!("eval: loss {eloss:.4}, accuracy {:.1}%", acc * 100.0);
    Ok(())
}

/// Render the 1F1B schedule of a plan as ASCII art (paper Fig. 10(b)).
fn cmd_timeline(args: &Args) -> anyhow::Result<()> {
    let env = Env::by_name(args.get_or("env", "env_a")).expect("unknown env");
    let spec = ModelSpec::by_name(args.get_or("model", "t5-base")).expect("unknown model");
    let method = parse_method(args.get_or("method", "pa"));
    let profile = Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, 128);
    let opts = PlannerOptions {
        microbatch: args.get_usize("microbatch", 4),
        n_microbatches: args.get_usize("m", 6),
        ..Default::default()
    };
    let p = plan(&profile, &env, &opts).map_err(|e| anyhow::anyhow!("{e}"))?;
    let sim = pacpp::sched::simulate_minibatch(&p, &profile, &env.network);
    println!("{}", p.grouping());
    print!(
        "{}",
        pacpp::sched::timeline::render(&sim, p.n_stages(), args.get_usize("width", 120))
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts/tiny");
    let m = pacpp::runtime::Manifest::load(dir)?;
    println!(
        "config {}: L={} d={} heads={} ff={} vocab={} B={} S={} r={}",
        m.config.name,
        m.config.layers,
        m.config.d_model,
        m.config.n_heads,
        m.config.d_ff,
        m.config.vocab,
        m.config.batch,
        m.config.seq_len,
        m.config.reduction
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {:<24} {} inputs -> {} outputs ({})",
            name,
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    println!("parameter sets:");
    for (tag, p) in &m.params {
        println!(
            "  {:<24} {} arrays, {}",
            tag,
            p.entries.len(),
            fmt_bytes(p.total_bytes as u64)
        );
    }
    Ok(())
}
