//! `pacpp` — the PAC+ coordinator CLI.
//!
//! ```text
//! pacpp plan     --env env_b --model t5-large [--method pa|full|lora|adapters]
//!                [--strategy pac+] [--minibatch 16] [--microbatch B] [--m M]
//!                [--homo] [--threads N]
//! pacpp simulate --env env_a --model t5-base --samples 3668 --epochs 3
//!                [--system pac+|dp|pp|standalone|asteroid|hetpipe|pac-homo]
//! pacpp strategies                 (list the registered strategies)
//! pacpp exp      list              (list the registered experiments)
//! pacpp exp      run <name> [--format text|json|csv] [--out FILE]
//! pacpp exp      all        [--format text|json|csv] [--out FILE]
//! pacpp fleet    [--env env_a] [--policy all|fifo|best-fit|preempt[,..]]
//!                [--queue fifo|backfill|sjf|edf|llf]
//!                [--trace steady|diurnal|bursty]
//!                [--jobs 40] [--seed 42] [--churn EVENTS_PER_HOUR]
//!                [--churn-file FILE] [--horizon HOURS] [--deadline SCALE]
//!                [--ckpt K] [--ckpt-cost SECS] [--strategy pac+]
//!                [--event-queue calendar|heap] [--legacy-dispatch]
//!                [--trace-out FILE] [--trace-sample N]
//!                [--format text|json|csv] [--out FILE]
//! pacpp fed      [--rounds 50] [--clients 24] [--k 6]
//!                [--select all|uniform|power-of-d|availability|fair|utility[,..]]
//!                [--straggler wait-all|deadline|over-select]
//!                [--agg allreduce|allgather|star]
//!                [--agg-mode sync|async] [--buffer-k K] [--seed 42]
//!                [--trace stable|churny|flaky] [--churn-file FILE]
//!                [--net lan|wifi]
//!                [--model t5-base] [--strategy pac+] [--horizon HOURS]
//!                [--deadline-mult X] [--over-select S] [--secure-agg]
//!                [--dp-cost SECS] [--jitter X] [--target ROUNDS]
//!                [--shards N] [--trace-out FILE] [--trace-sample N]
//!                [--format text|json|csv] [--out FILE]
//! pacpp learn    [--env env_a] [--episodes 30] [--jobs 40] [--seed 42]
//!                [--eval-seeds 3] [--horizon HOURS] [--deadline SCALE]
//!                [--weights FILE] [--trace-out FILE] [--trace-sample N]
//!                [--format text|json|csv] [--out FILE]
//!                     (train the in-sim DQN scheduler, dump + reload its
//!                      weights, and evaluate vs FIFO/backfill/EDF)
//! pacpp trace    summarize <FILE> [--section summary|critical|gaps|all]
//!                [--top N] [--format text|json|csv] [--out FILE]
//!                     (offline analysis of a --trace-out artifact:
//!                      per-category aggregates, critical paths/stragglers,
//!                      gap/bubble accounting)
//! pacpp bench    record <FILE...> [--history bench_history.jsonl]
//!                [--label LABEL] [--extract name=key.path[,..]]
//!                [--baseline-out FILE] [--tolerance 0.05]
//!                     (extract scalar series from BENCH_*.json reports /
//!                      bench dumps / traces and append them to the history)
//! pacpp bench    compare <FILE...> --baseline FILE [--tolerance T]
//! pacpp bench    compare --history bench_history.jsonl [--window 8]
//!                [--tolerance 0.05]
//!                     (deterministic regression verdict; exits nonzero on
//!                      any regressed series)
//! pacpp bench    trend [--history bench_history.jsonl] [--series SUBSTR]
//!                [--window 8] [--format text|json|csv] [--out FILE]
//! pacpp timeline --env env_a [--microbatch 4] [--m 6] [--width 120]
//!                                  (render a plan's 1F1B schedule as ASCII art)
//! pacpp table    1|5|6|7           (deprecated alias for `exp run table<N>`)
//! pacpp fig      3|12|...|18       (deprecated alias for `exp run fig<N>`)
//! pacpp train    --artifacts artifacts/small --epochs 4 [--pipeline N] [--quant int8]
//! pacpp info     --artifacts artifacts/tiny  (dump manifest summary)
//! ```

use std::sync::Arc;

use pacpp::cluster::{Env, Network};
use pacpp::data::SyntheticTask;
use pacpp::exec::{self, TrainOptions};
use pacpp::exp::{self, ExpContext, ExperimentRegistry, Format, Report};
use pacpp::fed::{
    simulate_fed_observed, AggMode, AggregationMode, FedOptions, FedTraceKind,
    SelectionRegistry, StragglerRegistry,
};
use pacpp::fleet::{
    churn_from_json, generate_churn, generate_jobs, simulate_fleet_observed, CheckpointSpec,
    EventQueueKind, FleetOptions, PlacementPolicy, PolicyRegistry, QueuePolicyRegistry,
    TraceKind, DEFAULT_CKPT_COST,
};
use pacpp::learn::TrainConfig;
use pacpp::model::graph::LayerGraph;
use pacpp::model::{Method, ModelSpec, Precision};
use pacpp::obs::analyze::{analyze, critical_report, gaps_report, summary_report, TraceDoc};
use pacpp::obs::regress::{
    compare_to_baseline, compare_to_history, extract, trend_report, Baseline, BenchHistory,
    HistoryPoint,
};
use pacpp::obs::{Observer, DEFAULT_TRACE_CAPACITY};
use pacpp::planner::{plan, PlannerOptions};
use pacpp::profiler::Profile;
use pacpp::runtime::Runtime;
use pacpp::strategy::{ParallelismStrategy, StrategyRegistry, TrainJob};
use pacpp::util::cli::Args;
use pacpp::util::{fmt_bytes, fmt_secs};

fn parse_method(s: &str) -> Method {
    match s {
        "full" => Method::FullFT,
        "lora" => Method::lora_default(),
        "adapters" => Method::adapters_default(),
        "pa" => Method::pa(false),
        "pa+cache" | "pac" => Method::pa(true),
        other => panic!("unknown method {other:?} (full|lora|adapters|pa|pa+cache)"),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("plan") => cmd_plan(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("strategies") => cmd_strategies(),
        Some("exp") => cmd_exp(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("fed") => cmd_fed(&args),
        Some("learn") => cmd_learn(&args),
        Some("trace") => cmd_trace(&args),
        Some("bench") => cmd_bench(&args),
        Some("table") => cmd_table(&args),
        Some("fig") => cmd_fig(&args),
        Some("train") => cmd_train(&args),
        Some("timeline") => cmd_timeline(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: pacpp <plan|simulate|strategies|exp|fleet|fed|learn|trace|bench|\
                 timeline|table|fig|train|info> [options]"
            );
            eprintln!("see rust/src/main.rs docs for options");
            Ok(())
        }
    }
}

/// List the registered parallelism strategies (names, aliases, roles).
fn cmd_strategies() -> anyhow::Result<()> {
    let registry = StrategyRegistry::with_defaults();
    println!("registered parallelism strategies:");
    for s in registry.iter() {
        let aliases = s.aliases().join(", ");
        println!("  {:<14} [{aliases}]", s.name());
        if !s.description().is_empty() {
            println!("  {:<14} {}", "", s.description());
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let env = Env::by_name(args.get_or("env", "env_a")).expect("unknown env");
    let spec = ModelSpec::by_name(args.get_or("model", "t5-base")).expect("unknown model");
    let method = parse_method(args.get_or("method", "pa"));
    let registry = StrategyRegistry::with_defaults();
    let strategy_name = args.get_or("strategy", "pac+");
    let strategy = registry.get_or_err(strategy_name)?;
    let profile = Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, 128);
    // start from the strategy's own job mapping (PAC-Homo turns off
    // heterogeneity awareness, Standalone/DP use mini-batch granularity,
    // ...), then apply explicit CLI overrides on top
    let job = TrainJob::new(0, 1, 128, args.get_usize("minibatch", 16));
    let mut opts = strategy.options(&env, &job);
    if let Some(b) = args.get_usize_opt("microbatch") {
        opts.microbatch = b;
    }
    if let Some(m) = args.get_usize_opt("m") {
        opts.n_microbatches = m;
    }
    if args.flag("homo") {
        opts.hetero_aware = false;
    }
    opts.search_threads = args.get_count_opt("threads")?;
    match strategy.plan(&profile, &env, &opts) {
        Ok(p) => {
            println!(
                "{} plan for {} ({}) on {}:",
                strategy.name(),
                spec.name,
                method.name(),
                env.name
            );
            println!("  stages: {}  grouping: {}", p.n_stages(), p.grouping());
            for (i, s) in p.stages.iter().enumerate() {
                let devs: Vec<String> =
                    s.devices.iter().map(|d| format!("{}#{}", d.kind.name(), d.id)).collect();
                println!(
                    "  stage {i}: blocks [{}, {}), devices [{}], dispatch {:?}, peak mem {}",
                    s.range.0,
                    s.range.1,
                    devs.join(", "),
                    s.dispatch,
                    fmt_bytes(s.peak_mem)
                );
            }
            let (lb, le, ln) = p.phase_latency;
            println!(
                "  minibatch: {} (begin {}, exec {}, end {})  throughput {:.2} samples/s",
                fmt_secs(p.minibatch_time),
                fmt_secs(lb),
                fmt_secs(le),
                fmt_secs(ln),
                p.throughput()
            );
        }
        Err(e) => println!("planning failed: {e}"),
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let env = Env::by_name(args.get_or("env", "env_a")).expect("unknown env");
    let spec = ModelSpec::by_name(args.get_or("model", "t5-base")).expect("unknown model");
    let method = parse_method(args.get_or("method", "pa+cache"));
    let registry = StrategyRegistry::with_defaults();
    let system_name = args.get_or("system", "pac+");
    let strategy = registry.get_or_err(system_name)?;
    let profile = Profile::new(
        LayerGraph::new(spec.clone()),
        method,
        Precision::FP32,
        args.get_usize("seq", exp::TABLE_SEQ),
    );
    let job = TrainJob::new(
        args.get_usize("samples", 3668),
        args.get_usize("epochs", 3),
        args.get_usize("seq", exp::TABLE_SEQ),
        args.get_usize("minibatch", 16),
    );
    match strategy.run(&profile, &env, job) {
        Ok(r) => {
            println!(
                "{} fine-tuning {} ({}) on {}: {} samples x {} epochs",
                strategy.name(),
                spec.name,
                method.name(),
                env.name,
                job.samples,
                job.epochs
            );
            println!("  epoch 1:        {}", fmt_secs(r.epoch1));
            if r.redistribution > 0.0 {
                println!("  redistribution: {}", fmt_secs(r.redistribution));
                println!("  cached epoch:   {}", fmt_secs(r.epoch_cached));
            }
            println!("  total:          {}", fmt_secs(r.total));
        }
        Err(e) => println!("{}: {e}", strategy.name()),
    }
    Ok(())
}

/// The experiment registry: `pacpp exp <list|run <name>|all>`.
fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let registry = ExperimentRegistry::with_defaults();
    match args.positional.first().map(|s| s.as_str()) {
        Some("list") => {
            println!("registered experiments:");
            for e in registry.iter() {
                let aliases = e.aliases().join(", ");
                println!("  {:<20} [{aliases}]", e.name());
                if !e.description().is_empty() {
                    println!("  {:<20} {}", "", e.description());
                }
            }
            Ok(())
        }
        Some("run") => {
            let names = &args.positional[1..];
            if names.is_empty() {
                anyhow::bail!(
                    "usage: pacpp exp run <name...> [--format text|json|csv] [--out FILE]"
                );
            }
            run_experiments(&registry, names, args)
        }
        Some("all") => {
            let format = parse_format(args)?;
            ensure_csv_single(format, registry.len())?;
            validate_out(args)?;
            let ctx = exp_context(args);
            // only a genuinely absent artifact set downgrades a
            // requires-artifacts failure to a skip; when artifacts are
            // present, a table6/7/fig14 error is a real regression
            let artifacts_missing = !std::path::Path::new(&ctx.artifacts).exists();
            let mut reports = Vec::new();
            let mut failed = Vec::new();
            for (name, res) in registry.run_all(&ctx) {
                match res {
                    Ok(r) => reports.push(r),
                    Err(e) => {
                        let skippable = artifacts_missing
                            && registry
                                .get(&name)
                                .map(|x| x.requires_artifacts())
                                .unwrap_or(false);
                        if skippable {
                            eprintln!("{name}: skipped, no artifacts at {} ({e:#})", ctx.artifacts);
                        } else {
                            eprintln!("{name}: {e:#}");
                            failed.push(name);
                        }
                    }
                }
            }
            // failure tally counts only attempted experiments (skips
            // excluded), but `exp all` always emits a JSON array so the
            // document shape never depends on runtime outcomes
            let attempted = reports.len() + failed.len();
            emit_outcome(reports, failed, attempted, true, format, args)
        }
        other => anyhow::bail!(
            "unknown exp subcommand {:?}; usage: pacpp exp <list|run <name>|all> \
             [--format text|json|csv] [--out FILE]",
            other.unwrap_or("<none>")
        ),
    }
}

fn exp_context(args: &Args) -> ExpContext {
    ExpContext::with_artifacts(args.get_or("artifacts", "artifacts/small"))
}

fn parse_format(args: &Args) -> anyhow::Result<Format> {
    let spec = args.get_or("format", "text");
    Format::parse(spec).ok_or_else(|| anyhow::anyhow!("unknown format {spec:?} (text|json|csv)"))
}

/// Concatenated CSV sections would not be machine-readable (differing
/// headers per report); JSON handles many reports in one document, CSV
/// does not. Checked before running and again before emitting.
fn ensure_csv_single(format: Format, n_reports: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        format != Format::Csv || n_reports == 1,
        "csv renders a single report; run experiments one at a time or use --format json"
    );
    Ok(())
}

/// The `--out` destination must be writable *before* experiments run —
/// minutes of work must not be lost to a bad path. Missing parent
/// directories are created up front (`util::ensure_parent_dirs`, so a
/// permission problem surfaces in seconds with a clear error naming
/// the directory — the deliberate cost is that a run that later fails
/// leaves the created directories behind); a directory target is
/// rejected.
fn validate_out(args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.get("out") {
        let p = std::path::Path::new(path);
        anyhow::ensure!(!p.is_dir(), "--out {path}: is a directory, expected a file path");
        pacpp::util::ensure_parent_dirs(path)
            .map_err(|e| anyhow::anyhow!("--out {path}: {e}"))?;
    }
    Ok(())
}

/// Parse the shared tracing flags of `fleet`/`fed`/`learn`:
/// `--trace-out FILE` enables the observer and picks the export format
/// by extension (`.jsonl` → JSONL, anything else → Chrome trace-event
/// JSON, Perfetto-loadable); `--trace-sample N` keeps 1-in-N trace
/// subjects. The destination is validated up front like `--out`.
fn parse_observer(args: &Args) -> anyhow::Result<(Observer, Option<String>)> {
    let sample = args.get_count("trace-sample", 1)? as u64;
    let trace_out = match args.get_str("trace-out", "")? {
        "" => None,
        path => Some(path.to_string()),
    };
    if let Some(path) = &trace_out {
        let p = std::path::Path::new(path);
        anyhow::ensure!(
            !p.is_dir(),
            "--trace-out {path}: is a directory, expected a file path"
        );
        pacpp::util::ensure_parent_dirs(path)
            .map_err(|e| anyhow::anyhow!("--trace-out {path}: {e}"))?;
    }
    let obs = if trace_out.is_some() {
        Observer::with(sample, DEFAULT_TRACE_CAPACITY)
    } else {
        Observer::disabled()
    };
    Ok((obs, trace_out))
}

/// Shared tail of the traced subcommands: write the trace file (if
/// requested) and print the wall-clock phase footer on stderr.
fn finish_observer(obs: &Observer, trace_out: &Option<String>) -> anyhow::Result<()> {
    if let Some(path) = trace_out {
        let text = if path.ends_with(".jsonl") {
            obs.to_jsonl()
        } else {
            let mut s = obs.to_chrome_json().to_string_pretty();
            s.push('\n');
            s
        };
        pacpp::util::write_creating_dirs(path, &text)?;
        let (held, recorded, dropped) = obs.trace_counts();
        eprintln!(
            "wrote {path} ({} bytes, {held} trace events held, {recorded} recorded, \
             {dropped} overwritten)",
            text.len()
        );
        if dropped > 0 {
            eprintln!(
                "warning: trace ring overflowed — the oldest {dropped} of {recorded} events \
                 were overwritten, so {path} holds only the run's tail (raise --trace-sample \
                 to thin the stream)"
            );
        }
    }
    for (phase, stat) in obs.wall_phases() {
        eprintln!("  wall {phase}: {} over {} call(s)", fmt_secs(stat.secs), stat.count);
    }
    Ok(())
}

/// Run registry experiments by name and render them. Names, the output
/// format and the `--out` destination are validated *before* anything
/// runs — a typo in the last name or in `--format` must not cost a
/// full run of the first — and a mid-run failure (e.g. missing
/// artifacts) still emits the reports that did succeed before exiting
/// nonzero. A name and its alias resolve to one run, not two.
fn run_experiments(
    registry: &ExperimentRegistry,
    names: &[impl AsRef<str>],
    args: &Args,
) -> anyhow::Result<()> {
    let format = parse_format(args)?;
    validate_out(args)?;
    let mut experiments = Vec::new();
    for name in names {
        let e = registry.get_or_err(name.as_ref())?;
        // dedup: `exp run table5 hours` runs table5 once
        if !experiments.iter().any(|x| x.name() == e.name()) {
            experiments.push(e);
        }
    }
    ensure_csv_single(format, experiments.len())?;
    let ctx = exp_context(args);
    let mut reports = Vec::new();
    let mut failed = Vec::new();
    // independent experiments run concurrently, like `exp all`
    let results = ExperimentRegistry::run_set(&experiments, &ctx);
    for (e, res) in experiments.iter().zip(results) {
        match res {
            Ok(r) => reports.push(r),
            Err(err) => {
                eprintln!("{}: {err:#}", e.name());
                failed.push(e.name().to_string());
            }
        }
    }
    let n = experiments.len();
    emit_outcome(reports, failed, n, n > 1, format, args)
}

/// Shared tail of `exp run`/`exp all`: emit what succeeded, and exit
/// nonzero if anything failed. Nothing is written (no degenerate empty
/// document) when every experiment failed. `as_array` follows how many
/// experiments were REQUESTED, so partial failure cannot flip the JSON
/// document shape between runs.
fn emit_outcome(
    reports: Vec<Report>,
    failed: Vec<String>,
    total: usize,
    as_array: bool,
    format: Format,
    args: &Args,
) -> anyhow::Result<()> {
    if reports.is_empty() && !failed.is_empty() {
        anyhow::bail!("every experiment failed: {}", failed.join(", "));
    }
    emit_reports(&reports, format, as_array, args)?;
    anyhow::ensure!(
        failed.is_empty(),
        "{} of {} experiment(s) failed: {}",
        failed.len(),
        total,
        failed.join(", ")
    );
    Ok(())
}

/// Render reports in `format` and write to `--out` or stdout. JSON is
/// round-tripped through `util::json::parse` before it leaves the
/// process, so a written report file is guaranteed machine-readable.
fn emit_reports(
    reports: &[Report],
    format: Format,
    as_array: bool,
    args: &Args,
) -> anyhow::Result<()> {
    let rendered = match format {
        Format::Text => {
            let texts: Vec<String> = reports.iter().map(Report::to_text).collect();
            texts.join("\n")
        }
        Format::Csv => {
            ensure_csv_single(format, reports.len())?;
            reports[0].to_csv()
        }
        Format::Json => {
            let json = if as_array {
                pacpp::util::json::Json::Arr(reports.iter().map(Report::to_json).collect())
            } else {
                reports[0].to_json()
            };
            let mut s = json.to_string_pretty();
            let back = pacpp::util::json::Json::parse(&s)
                .map_err(|e| anyhow::anyhow!("report json does not parse back: {e}"))?;
            anyhow::ensure!(back == json, "report json round-trip mismatch");
            s.push('\n');
            s
        }
    };
    match args.get("out") {
        Some(path) => {
            pacpp::util::write_creating_dirs(path, &rendered)?;
            eprintln!("wrote {path} ({} bytes, {})", rendered.len(), format.name());
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

/// `pacpp trace <summarize>`: offline analysis of a `--trace-out`
/// artifact (Chrome trace-event JSON or JSONL — format sniffed, not
/// extension-guessed, so renamed files still load).
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("summarize") => cmd_trace_summarize(args),
        other => anyhow::bail!(
            "unknown trace action {:?}: usage: pacpp trace summarize <FILE> \
             [--section summary|critical|gaps|all] [--top N] \
             [--format text|json|csv] [--out FILE]",
            other.unwrap_or("")
        ),
    }
}

/// `pacpp trace summarize FILE`: load the trace, reduce it via
/// `obs::analyze`, and emit the requested report section(s) —
/// per-(category, name) span aggregates, critical-path groups with
/// straggler attribution, and per-category gap/bubble accounting.
fn cmd_trace_summarize(args: &Args) -> anyhow::Result<()> {
    let Some(path) = args.positional.get(1) else {
        anyhow::bail!("trace summarize: missing trace file argument");
    };
    let format = parse_format(args)?;
    validate_out(args)?;
    let section = args.get_str("section", "all")?;
    let top = args.get_count("top", 10)?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let doc = TraceDoc::load(&text).map_err(|e| anyhow::anyhow!("loading {path}: {e:#}"))?;
    let analysis = analyze(&doc);
    let mut reports = Vec::new();
    if matches!(section, "summary" | "all") {
        reports.push(summary_report(&analysis));
    }
    if matches!(section, "critical" | "all") {
        reports.push(critical_report(&analysis, top));
    }
    if matches!(section, "gaps" | "all") {
        reports.push(gaps_report(&analysis));
    }
    anyhow::ensure!(
        !reports.is_empty(),
        "unknown --section {section:?} (summary|critical|gaps|all)"
    );
    for r in &mut reports {
        r.meta.insert("source".to_string(), path.clone());
    }
    ensure_csv_single(format, reports.len())?;
    let as_array = reports.len() > 1;
    emit_reports(&reports, format, as_array, args)
}

/// `pacpp bench <record|compare|trend>`: benchmark history and
/// regression gating over the `BENCH_*.json` artifacts (`obs::regress`).
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("record") => cmd_bench_record(args),
        Some("compare") => cmd_bench_compare(args),
        Some("trend") => cmd_bench_trend(args),
        other => anyhow::bail!(
            "unknown bench action {:?}: usage: pacpp bench \
             record <FILE...> [--history H] [--label L] [--baseline-out F] | \
             compare <FILE...> --baseline F | compare --history H [--window N] | \
             trend [--history H] [--series SUBSTR]",
            other.unwrap_or("")
        ),
    }
}

/// Read + extract every artifact named on a `bench record`/`compare`
/// command line. Returns `(series, values)` pairs; the series names are
/// prefixed per the artifact shape (`<report>.meta.*`, `bench.*`,
/// `trace.<stem>.*`). `--extract name=key.path[,..]` adds custom series
/// pulled by `util::json` key-path (e.g. `goodput=meta.goodput` or
/// `first_row=rows[0][2]`).
fn extract_files(args: &Args, files: &[String]) -> anyhow::Result<Vec<(String, f64)>> {
    anyhow::ensure!(!files.is_empty(), "no artifact files given");
    let custom: Vec<(String, String)> = match args.get_str("extract", "")? {
        "" => Vec::new(),
        spec => spec
            .split(',')
            .map(|pair| {
                pair.split_once('=')
                    .map(|(n, p)| (n.to_string(), p.to_string()))
                    .ok_or_else(|| {
                        anyhow::anyhow!("--extract: expected name=key.path, got {pair:?}")
                    })
            })
            .collect::<anyhow::Result<_>>()?,
    };
    let mut series = Vec::new();
    for path in files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
        let json = pacpp::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let stem = std::path::Path::new(path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("artifact");
        let extracted = extract(&json, stem);
        anyhow::ensure!(
            !extracted.is_empty() || !custom.is_empty(),
            "{path}: no recognizable series (expected a report, bench dump or trace)"
        );
        series.extend(extracted);
        for (name, keypath) in &custom {
            let v = json
                .path_str(keypath)
                .and_then(pacpp::util::json::Json::as_f64)
                .ok_or_else(|| {
                    anyhow::anyhow!("--extract {name}={keypath}: no numeric value in {path}")
                })?;
            series.push((name.clone(), v));
        }
    }
    Ok(series)
}

/// `pacpp bench record FILE...`: append each artifact's extracted
/// series to the history (`--history`, default `bench_history.jsonl`)
/// under `--label` (commit sha, date, ...; default "local").
/// `--baseline-out FILE` additionally writes the gated (deterministic)
/// series as a fresh regression baseline at `--tolerance`.
fn cmd_bench_record(args: &Args) -> anyhow::Result<()> {
    let files = &args.positional[1..];
    let history = args.get_str("history", "bench_history.jsonl")?;
    let label = args.get_str("label", "local")?;
    let tolerance = args.get_rate("tolerance", 0.05)?;
    anyhow::ensure!(!files.is_empty(), "bench record: no artifact files given");
    let mut points = Vec::new();
    for path in files {
        let series = extract_files(args, std::slice::from_ref(path))?;
        let source = std::path::Path::new(path)
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or(path)
            .to_string();
        for (name, value) in series {
            points.push(HistoryPoint {
                label: label.to_string(),
                source: source.clone(),
                series: name,
                value,
            });
        }
    }
    pacpp::util::append_creating_dirs(history, &BenchHistory::render(&points))?;
    eprintln!(
        "recorded {} series from {} file(s) into {history} (label {label})",
        points.len(),
        files.len()
    );
    if let Some(out) = args.get("baseline-out") {
        let series: Vec<(String, f64)> =
            points.iter().map(|p| (p.series.clone(), p.value)).collect();
        let baseline = Baseline::from_series(&series, tolerance);
        let mut text = baseline.to_json().to_string_pretty();
        text.push('\n');
        pacpp::util::write_creating_dirs(out, &text)?;
        eprintln!(
            "wrote {out} ({} gated series, tolerance {tolerance})",
            baseline.series.len()
        );
    }
    Ok(())
}

/// `pacpp bench compare`: deterministic regression verdict. Two modes:
/// `compare FILE... --baseline F` gates freshly extracted series
/// against a committed baseline; `compare --history H` gates each
/// series' newest history point against the median of its last
/// `--window` points. The verdict report is emitted *before* the exit
/// status so a failing CI run still shows the full table.
fn cmd_bench_compare(args: &Args) -> anyhow::Result<()> {
    let format = parse_format(args)?;
    validate_out(args)?;
    let baseline_path = args.get_str("baseline", "")?;
    let history_path = args.get_str("history", "")?;
    anyhow::ensure!(
        (baseline_path == "") != (history_path == ""),
        "bench compare: pass exactly one of --baseline FILE or --history FILE"
    );
    let verdict = if !baseline_path.is_empty() {
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow::anyhow!("cannot read {baseline_path}: {e}"))?;
        let json = pacpp::util::json::Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{baseline_path}: {e}"))?;
        let mut baseline = Baseline::from_json(&json)
            .map_err(|e| anyhow::anyhow!("{baseline_path}: {e:#}"))?;
        baseline.tolerance = args.get_rate("tolerance", baseline.tolerance)?;
        let current = extract_files(args, &args.positional[1..])?;
        compare_to_baseline(&current, &baseline)
    } else {
        let text = std::fs::read_to_string(history_path)
            .map_err(|e| anyhow::anyhow!("cannot read {history_path}: {e}"))?;
        let hist = BenchHistory::parse(&text)?;
        let window = args.get_count("window", 8)?;
        let tolerance = args.get_rate("tolerance", 0.05)?;
        compare_to_history(&hist, window, tolerance)
    };
    let mode = if baseline_path.is_empty() { "history" } else { "baseline" };
    let mut report = verdict.report("Benchmark regression verdict");
    report.meta.insert("mode".to_string(), mode.to_string());
    emit_reports(std::slice::from_ref(&report), format, false, args)?;
    let regressed = verdict.regressions();
    anyhow::ensure!(
        regressed.is_empty(),
        "{} series regressed: {}",
        regressed.len(),
        regressed.join(", ")
    );
    Ok(())
}

/// `pacpp bench trend`: per-series first/median/last over the trailing
/// `--window` history points, filtered by `--series` substring.
fn cmd_bench_trend(args: &Args) -> anyhow::Result<()> {
    let format = parse_format(args)?;
    validate_out(args)?;
    let history = args.get_str("history", "bench_history.jsonl")?;
    let filter = args.get_str("series", "")?;
    let window = args.get_count("window", 8)?;
    let text = std::fs::read_to_string(history)
        .map_err(|e| anyhow::anyhow!("cannot read {history}: {e}"))?;
    let hist = BenchHistory::parse(&text)?;
    let mut report = trend_report(&hist, filter, window);
    report.meta.insert("history".to_string(), history.to_string());
    emit_reports(std::slice::from_ref(&report), format, false, args)
}

/// `pacpp fleet`: one deterministic multi-tenant simulation per selected
/// policy over a shared (optionally churning) pool, reported in the
/// fleet experiment schema. `--queue` picks the queueing discipline,
/// `--deadline` scales every job's deadline slack (0 disables
/// deadlines), and `--ckpt K` turns on checkpointing every K epochs at
/// `--ckpt-cost` seconds apiece (0 = off).
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    let env_name = args.get_str("env", "env_a")?;
    let Some(env) = Env::by_name(env_name) else {
        anyhow::bail!("unknown env {env_name:?} (env_a|env_b|<n>xnano)");
    };
    let trace_name = args.get_str("trace", "steady")?;
    let Some(trace) = TraceKind::parse(trace_name) else {
        anyhow::bail!("unknown trace {trace_name:?} (steady|diurnal|bursty)");
    };
    let n_jobs = args.get_count("jobs", 40)?;
    let seed = args.get_seed("seed", 42)?;
    let churn_per_hour = args.get_rate("churn", 0.0)?;
    let horizon_h = args.get_positive_f64("horizon", 48.0)?;
    let queue_name = args.get_str("queue", "fifo")?;
    let queue_registry = QueuePolicyRegistry::with_defaults();
    let queue = queue_registry.get_or_err(queue_name)?;
    let deadline_scale = args.get_rate("deadline", 1.0)?;
    // `--ckpt 0` reads naturally as "off", so this flag takes a
    // non-negative count rather than the strictly-positive get_count
    let ckpt_k = args.get_count0("ckpt", 0)?;
    let ckpt_cost = args.get_rate("ckpt-cost", DEFAULT_CKPT_COST)?;
    // scaling knobs: both paths are bit-identical to the defaults
    // (property-tested) — these exist for benchmarking them against
    // each other on big runs
    let eventq_name = args.get_str("event-queue", "calendar")?;
    let Some(event_queue) = EventQueueKind::parse(eventq_name) else {
        anyhow::bail!("unknown event queue {eventq_name:?} (calendar|heap)");
    };
    let incremental_queue = !args.flag("legacy-dispatch");
    let format = parse_format(args)?;
    validate_out(args)?;
    let (obs, trace_out) = parse_observer(args)?;

    let registry = PolicyRegistry::with_defaults();
    let spec = args.get_str("policy", "all")?;
    let mut policies = Vec::new();
    if spec == "all" {
        policies.extend(registry.iter().cloned());
    } else {
        for one in spec.split(',') {
            policies.push(registry.get_or_err(one.trim())?.clone());
        }
    }

    let opts = FleetOptions {
        strategy: args.get_str("strategy", "pac+")?.to_string(),
        horizon: horizon_h * 3600.0,
        queue: queue.name().to_string(),
        deadline_scale,
        ckpt: if ckpt_k > 0 { Some(CheckpointSpec::new(ckpt_k, ckpt_cost)) } else { None },
        event_queue,
        incremental_queue,
    };
    let jobs = generate_jobs(trace, n_jobs, seed);
    // `--churn-file` replays a recorded JSON event list (see
    // `fleet::churn_to_json` for the format) instead of sampling one
    let churn_file = args.get("churn-file").map(String::from);
    let churn = match &churn_file {
        Some(path) => {
            anyhow::ensure!(
                churn_per_hour == 0.0,
                "--churn and --churn-file are mutually exclusive"
            );
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--churn-file {path}: {e}"))?;
            let json = pacpp::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("--churn-file {path}: {e}"))?;
            churn_from_json(&json).map_err(|e| anyhow::anyhow!("--churn-file {path}: {e}"))?
        }
        None if churn_per_hour > 0.0 => generate_churn(&env, opts.horizon, churn_per_hour, seed),
        None => Vec::new(),
    };

    let mut report = exp::fleet_schema(
        "fleet",
        &format!("Fleet — {n_jobs} jobs ({trace_name}) on {}", env.name),
    )
    .meta("jobs", n_jobs)
    .meta("seed", seed)
    .meta("trace", trace.name())
    .meta("env", &env.name)
    .meta("strategy", &opts.strategy)
    .meta("queue", queue.name())
    .meta("horizon_h", horizon_h)
    .meta("churn_per_hour", churn_per_hour)
    .meta("churn_file", churn_file.as_deref().unwrap_or("-"))
    .meta("deadline_scale", deadline_scale)
    .meta("ckpt", ckpt_k)
    .meta("ckpt_cost", ckpt_cost)
    .meta("event_queue", event_queue.name())
    .meta("incremental_queue", incremental_queue);
    // observe counters, summed over the policy rows
    let (mut events, mut hits, mut misses, mut rescans) = (0usize, 0usize, 0usize, 0usize);
    let t0 = std::time::Instant::now();
    for policy in &policies {
        let m = simulate_fleet_observed(&env, &jobs, &churn, policy.as_ref(), &opts, &obs)?;
        events += m.events;
        hits += m.oracle_hits;
        misses += m.oracle_misses;
        rescans += m.rescans_avoided;
        report.push(exp::fleet_row(
            &env.name,
            trace.name(),
            policy.name(),
            queue.name(),
            ckpt_k,
            n_jobs,
            &m,
        ));
    }
    report = report
        .meta("events_total", events)
        .meta("oracle_hits_total", hits)
        .meta("oracle_misses_total", misses)
        .meta("rescans_avoided_total", rescans)
        .meta(exp::ELAPSED_SECS_META, format!("{:.3}", t0.elapsed().as_secs_f64()));
    finish_observer(&obs, &trace_out)?;
    emit_reports(&[report], format, false, args)
}

/// `pacpp fed`: one deterministic federated adapter-aggregation
/// simulation per selected client-selection policy, reported in the fed
/// experiment schema. `--straggler` picks the round-end discipline,
/// `--agg` the aggregation collective, `--trace` the client
/// availability pattern (or `--churn-file` replays a recorded fleet
/// churn trace as availability), and `--secure-agg`/`--dp-cost` the
/// privacy cost knobs.
fn cmd_fed(args: &Args) -> anyhow::Result<()> {
    let rounds = args.get_count("rounds", 50)?;
    let n_clients = args.get_count("clients", 24)?;
    let k = args.get_count("k", 6)?;
    let seed = args.get_seed("seed", 42)?;
    let trace_name = args.get_str("trace", "churny")?;
    let Some(trace) = FedTraceKind::parse(trace_name) else {
        anyhow::bail!("unknown trace {trace_name:?} (stable|churny|flaky)");
    };
    let agg_name = args.get_str("agg", "allreduce")?;
    let Some(agg) = AggMode::parse(agg_name) else {
        anyhow::bail!("unknown aggregation mode {agg_name:?} (allreduce|allgather|star)");
    };
    let agg_mode_name = args.get_str("agg-mode", "sync")?;
    let Some(agg_mode) = AggregationMode::parse(agg_mode_name) else {
        anyhow::bail!("unknown aggregation timing {agg_mode_name:?} (sync|async)");
    };
    // async buffer size; 0 = auto (one buffer per K folds)
    let buffer_k = args.get_count0("buffer-k", 0)?;
    let net_name = args.get_str("net", "lan")?;
    let network = match net_name {
        "lan" => Network::lan_1gbps(),
        "wifi" => Network::wifi_100mbps(),
        other => anyhow::bail!("unknown network {other:?} (lan|wifi)"),
    };
    let model_name = args.get_str("model", "t5-base")?;
    let Some(model) = ModelSpec::by_name(model_name) else {
        anyhow::bail!("unknown model {model_name:?}");
    };
    let straggler_registry = StragglerRegistry::with_defaults();
    let straggler_name = args.get_str("straggler", "wait-all")?;
    let straggler = straggler_registry.get_or_err(straggler_name)?;
    let horizon_h = args.get_positive_f64("horizon", 336.0)?;
    // `--churn-file` replays a recorded *fleet* churn trace (see
    // `fleet::churn_to_json` for the format) as the client
    // availability pattern: client i mirrors device id i
    // (`fed::traces_from_churn`). It replaces the generated `--trace`
    // patterns entirely, so the two flags are mutually exclusive.
    let churn_file = args.get("churn-file").map(String::from);
    let churn_traces = match &churn_file {
        Some(path) => {
            anyhow::ensure!(
                args.get("trace").is_none(),
                "--trace and --churn-file are mutually exclusive"
            );
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--churn-file {path}: {e}"))?;
            let json = pacpp::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("--churn-file {path}: {e}"))?;
            let events =
                churn_from_json(&json).map_err(|e| anyhow::anyhow!("--churn-file {path}: {e}"))?;
            Some(pacpp::fed::traces_from_churn(&events, n_clients, horizon_h * 3600.0))
        }
        None => None,
    };
    let trace_label = if churn_file.is_some() { "churn-file" } else { trace.name() };
    let deadline_mult = args.get_positive_f64("deadline-mult", 2.0)?;
    // `--over-select 0` reads naturally as "no spares" (the
    // over-select policy still floors it at one spare)
    let over_select = args.get_count0("over-select", 2)?;
    let dp_cost = args.get_rate("dp-cost", 0.0)?;
    let jitter = args.get_rate("jitter", 0.25)?;
    let target = args.get_rate("target", 0.0)?;
    // scaling knob: quoting-pass shards, 0 = auto (see FedOptions)
    let shards = args.get_count0("shards", 0)?;
    let format = parse_format(args)?;
    validate_out(args)?;
    let (obs, trace_out) = parse_observer(args)?;

    let selection_registry = SelectionRegistry::with_defaults();
    let spec = args.get_str("select", "all")?;
    let mut selects = Vec::new();
    if spec == "all" {
        selects.extend(selection_registry.names().iter().map(|s| s.to_string()));
    } else {
        for one in spec.split(',') {
            selects.push(selection_registry.get_or_err(one.trim())?.name().to_string());
        }
    }

    let mut report = exp::fed_schema(
        "fed",
        &format!("Fed — {rounds} rounds x K={k} of {n_clients} clients ({trace_name})"),
    )
    .meta("rounds", rounds)
    .meta("clients", n_clients)
    .meta("k", k)
    .meta("seed", seed)
    .meta("trace", trace_label)
    .meta("churn_file", churn_file.as_deref().unwrap_or("-"))
    .meta("net", net_name)
    .meta("agg", agg.name())
    .meta("agg_mode", agg_mode.name())
    .meta("buffer_k", buffer_k)
    .meta("model", &model.name)
    .meta("straggler", straggler.name())
    .meta("strategy", args.get_str("strategy", "pac+")?)
    .meta("horizon_h", horizon_h)
    .meta("secure_agg", args.flag("secure-agg"))
    .meta("dp_cost", dp_cost)
    .meta("jitter", jitter)
    .meta("target", target)
    .meta("shards", shards);
    // observe counters, summed over the selection rows
    let (mut hits, mut misses) = (0usize, 0usize);
    let t0 = std::time::Instant::now();
    for select in &selects {
        let opts = FedOptions {
            rounds,
            clients: n_clients,
            k,
            select: select.clone(),
            straggler: straggler.name().to_string(),
            agg,
            agg_mode,
            buffer_k,
            seed,
            trace,
            strategy: args.get_str("strategy", "pac+")?.to_string(),
            network,
            model: model.clone(),
            horizon: horizon_h * 3600.0,
            deadline_mult,
            over_select,
            secure_agg: args.flag("secure-agg"),
            dp_cost,
            jitter,
            target_rounds: target,
            shards,
        };
        let m = match &churn_traces {
            Some(traces) => {
                let clients = pacpp::fed::generate_clients(n_clients, seed);
                pacpp::fed::simulate_fed_with_observed(&clients, traces, &opts, &obs)?
            }
            None => simulate_fed_observed(&opts, &obs)?,
        };
        hits += m.oracle_hits;
        misses += m.oracle_misses;
        report.push(exp::fed_row(net_name, trace_label, &opts, &m));
    }
    report = report
        .meta("oracle_hits_total", hits)
        .meta("oracle_misses_total", misses)
        .meta(exp::ELAPSED_SECS_META, format!("{:.3}", t0.elapsed().as_secs_f64()));
    finish_observer(&obs, &trace_out)?;
    emit_reports(&[report], format, false, args)
}

/// `pacpp learn`: train the in-simulator DQN scheduler
/// ([`pacpp::learn`]) — episodes of the fleet simulator under the
/// exploring trainer queue — then dump the weights as JSON, reload the
/// dump, and evaluate the reloaded policy against FIFO, EASY-backfill
/// and EDF on held-out seeds, all in one invocation. `--weights FILE`
/// additionally persists the (reloaded) weights for later
/// `LearnedQueue` use.
fn cmd_learn(args: &Args) -> anyhow::Result<()> {
    let env_name = args.get_str("env", "env_a")?;
    let Some(env) = Env::by_name(env_name) else {
        anyhow::bail!("unknown env {env_name:?} (env_a|env_b|<n>xnano)");
    };
    let d = TrainConfig::default();
    let cfg = TrainConfig {
        episodes: args.get_count("episodes", d.episodes)?,
        jobs: args.get_count("jobs", d.jobs)?,
        seed: args.get_seed("seed", d.seed)?,
        eval_seeds: args.get_count("eval-seeds", d.eval_seeds)?,
        horizon: args.get_positive_f64("horizon", d.horizon / 3600.0)? * 3600.0,
        deadline_scale: args.get_rate("deadline", d.deadline_scale)?,
        dqn: d.dqn,
    };
    let format = parse_format(args)?;
    validate_out(args)?;
    let (obs, trace_out) = parse_observer(args)?;
    let weights_path = args.get("weights").map(String::from);
    if let Some(path) = &weights_path {
        let p = std::path::Path::new(path);
        anyhow::ensure!(!p.is_dir(), "--weights {path}: is a directory, expected a file path");
        pacpp::util::ensure_parent_dirs(path)
            .map_err(|e| anyhow::anyhow!("--weights {path}: {e}"))?;
    }

    let t0 = std::time::Instant::now();
    let (mut report, net) = exp::learn_report_observed(&env, &cfg, &obs)?;
    report
        .meta
        .insert(exp::ELAPSED_SECS_META.into(), format!("{:.3}", t0.elapsed().as_secs_f64()));
    if let Some(path) = &weights_path {
        let text = net.to_json().to_string_pretty();
        pacpp::util::write_creating_dirs(path, &text)?;
        eprintln!("wrote {path} ({} bytes, weights json)", text.len());
    }
    finish_observer(&obs, &trace_out)?;
    emit_reports(&[report], format, false, args)
}

/// Deprecated alias: `pacpp table N` forwards to `exp run tableN`.
fn cmd_table(args: &Args) -> anyhow::Result<()> {
    eprintln!("note: `pacpp table` is deprecated; use `pacpp exp run <name>`");
    let registry = ExperimentRegistry::with_defaults();
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let names: Vec<String> = match which {
        "all" => vec!["table1".into(), "table5".into()],
        n => vec![format!("table{n}")],
    };
    run_experiments(&registry, &names, args)
}

/// Deprecated alias: `pacpp fig N` forwards to `exp run figN`.
fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    eprintln!("note: `pacpp fig` is deprecated; use `pacpp exp run <name>`");
    let registry = ExperimentRegistry::with_defaults();
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let names: Vec<String> = match which {
        // the simulator-backed figures, legacy `fig all` line-up
        "all" => ["fig3", "fig12", "fig13", "fig15", "fig16", "fig17", "fig18"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        n => vec![format!("fig{n}")],
    };
    run_experiments(&registry, &names, args)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts/small");
    let rt = Arc::new(Runtime::load(dir)?);
    let cfg = rt.manifest.config.clone();
    println!(
        "loaded {} artifacts for config {} ({} backbone params) on {}",
        rt.manifest.artifacts.len(),
        cfg.name,
        cfg.params_backbone,
        rt.platform()
    );
    let n = args.get_usize("samples", 256);
    let task = SyntheticTask::generate(n + 64, cfg.seq_len, cfg.vocab, 0.02, 7);
    let (train, eval) = task.split(64.0 / (n + 64) as f64);

    let mut opts = TrainOptions::new(
        std::path::PathBuf::from(args.get_or("cache-dir", "/tmp/pacpp_cache")),
    );
    opts.epochs = args.get_usize("epochs", 3);
    opts.lr = args.get_f64("lr", 0.005) as f32;
    opts.workers = args.get_usize("workers", 2);
    opts.init_tag = format!("adapter_{}", args.get_or("init", "prune"));
    opts.quant = args.get("quant").map(String::from);
    opts.use_cache = !args.flag("no-cache");

    let t0 = std::time::Instant::now();
    let log = if let Some(stages) = args.get("pipeline") {
        exec::train_pipelined(&rt, &train, &opts, stages.parse().unwrap())?
    } else {
        exec::train_data_parallel(&rt, &train, &opts)?
    };
    println!(
        "trained {} steps in {}: cache hits {}, backbone passes {}",
        log.steps.len(),
        fmt_secs(t0.elapsed().as_secs_f64()),
        log.cache_hits,
        log.backbone_passes
    );
    for (e, t) in log.epoch_times.iter().enumerate() {
        println!("  epoch {e}: {} (mean loss {:.4})", fmt_secs(*t), log.mean_loss(e));
    }
    let adapter = exec::take_final_adapter().expect("adapter missing");
    let (eloss, acc) = exec::evaluate(&rt, &adapter, &eval, &opts.quant)?;
    println!("eval: loss {eloss:.4}, accuracy {:.1}%", acc * 100.0);
    Ok(())
}

/// Render the 1F1B schedule of a plan as ASCII art (paper Fig. 10(b)).
fn cmd_timeline(args: &Args) -> anyhow::Result<()> {
    let env = Env::by_name(args.get_or("env", "env_a")).expect("unknown env");
    let spec = ModelSpec::by_name(args.get_or("model", "t5-base")).expect("unknown model");
    let method = parse_method(args.get_or("method", "pa"));
    let profile = Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, 128);
    let opts = PlannerOptions {
        microbatch: args.get_usize("microbatch", 4),
        n_microbatches: args.get_usize("m", 6),
        ..Default::default()
    };
    let p = plan(&profile, &env, &opts).map_err(|e| anyhow::anyhow!("{e}"))?;
    let sim = pacpp::sched::simulate_minibatch(&p, &profile, &env.network);
    println!("{}", p.grouping());
    print!(
        "{}",
        pacpp::sched::timeline::render(&sim, p.n_stages(), args.get_usize("width", 120))
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts/tiny");
    let m = pacpp::runtime::Manifest::load(dir)?;
    println!(
        "config {}: L={} d={} heads={} ff={} vocab={} B={} S={} r={}",
        m.config.name,
        m.config.layers,
        m.config.d_model,
        m.config.n_heads,
        m.config.d_ff,
        m.config.vocab,
        m.config.batch,
        m.config.seq_len,
        m.config.reduction
    );
    println!("artifacts:");
    for (name, a) in &m.artifacts {
        println!(
            "  {:<24} {} inputs -> {} outputs ({})",
            name,
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    println!("parameter sets:");
    for (tag, p) in &m.params {
        println!(
            "  {:<24} {} arrays, {}",
            tag,
            p.entries.len(),
            fmt_bytes(p.total_bytes as u64)
        );
    }
    Ok(())
}
