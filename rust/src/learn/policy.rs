//! The learned queue disciplines: [`LearnedQueue`] (inference-only,
//! loadable weights) and [`TrainerQueue`] (the in-simulator training
//! shim that explores, records transitions, and learns between
//! episodes).
//!
//! Both implement [`crate::fleet::QueuePolicy`] and plug into
//! [`crate::fleet::simulate_fleet_with`] like any hand-written
//! discipline — the simulator cannot tell a learned policy from FIFO.
//! Candidate enumeration mirrors the built-ins exactly: respect the
//! incremental index's known-unplaceable cache, attempt placements
//! through the run's placement policy, quote whole-pool estimates
//! through the shared memo — so a learned run's per-dispatch cost
//! profile matches an LLF run's, plus one tiny MLP forward per
//! candidate.

use std::sync::Mutex;

use crate::cluster::Device;
use crate::fleet::{FleetMetrics, Placement, QueueCtx, QueueDecision, QueuePolicy};

use super::agent::DqnAgent;
use super::feature::{featurize, N_FEATURES};
use super::net::Mlp;
use super::replay::Transition;

/// Queue positions considered per decision. Bounds per-dispatch cost on
/// deep backlogs; 32 front positions is far beyond where any candidate
/// is still competitive under the arrival-ordered queue.
pub const CANDIDATE_CAP: usize = 32;

/// One placeable candidate at a decision point.
struct Candidate {
    pos: usize,
    feats: Vec<f64>,
    placement: Placement,
}

/// Enumerate the placeable candidates among the first
/// [`CANDIDATE_CAP`] queue positions, featurized. Shares the
/// incremental index's placement-failure and whole-pool-estimate memos
/// with the built-in policies.
fn gather_candidates(ctx: &QueueCtx) -> Vec<Candidate> {
    if ctx.queue.is_empty() || ctx.free.is_empty() {
        return Vec::new();
    }
    let mut pool: Vec<Device> = ctx.free.to_vec();
    for r in ctx.running {
        pool.extend(r.devices.iter().cloned());
    }
    pool.sort_by_key(|d| d.id);
    let mut out = Vec::new();
    for pos in 0..ctx.queue.len().min(CANDIDATE_CAP) {
        let job = ctx.queue[pos];
        if ctx.index.is_some_and(|ix| ix.known_unplaceable(job)) {
            continue;
        }
        let Some(placement) = ctx.try_place(&ctx.jobs[job], ctx.free, ctx.n_running) else {
            if let Some(ix) = ctx.index {
                ix.note_unplaceable(job);
            }
            continue;
        };
        let est = match ctx.index {
            Some(ix) => ix.pool_est(ctx, &pool, job),
            None => ctx
                .oracle
                .service_time(&ctx.jobs[job], &pool)
                .unwrap_or(f64::INFINITY),
        };
        let feats = featurize(ctx, pos, est, &placement);
        out.push(Candidate { pos, feats, placement });
    }
    out
}

/// The inference-only learned discipline: score every placeable
/// candidate with the trained Q network, start the argmax. Stateless
/// per decision (the net is read-only), so it is `Sync`-shareable like
/// every registry policy — but it is *not* a registry default, because
/// it cannot exist without weights
/// ([`crate::fleet::QueuePolicyRegistry::with_defaults`] documents
/// this). Build one from a dumped-weights file via [`Mlp::from_json`].
#[derive(Debug, Clone)]
pub struct LearnedQueue {
    net: Mlp,
}

impl LearnedQueue {
    pub fn new(net: Mlp) -> LearnedQueue {
        assert_eq!(
            net.n_in(),
            N_FEATURES,
            "LearnedQueue weights expect {N_FEATURES} features"
        );
        LearnedQueue { net }
    }

    pub fn net(&self) -> &Mlp {
        &self.net
    }
}

impl QueuePolicy for LearnedQueue {
    fn name(&self) -> &str {
        "Learned"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["learned", "dqn", "rl"]
    }

    fn description(&self) -> &str {
        "score placeable queued jobs with a trained Q network, start the argmax"
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        let cands = gather_candidates(ctx);
        let best = cands.into_iter().max_by(|a, b| {
            self.net
                .scalar(&a.feats)
                .total_cmp(&self.net.scalar(&b.feats))
                // earlier queue position wins ties: deterministic, and
                // the same prior the built-ins' stable sorts encode
                .then(b.pos.cmp(&a.pos))
        })?;
        Some(QueueDecision { queue_pos: best.pos, placement: best.placement })
    }
}

/// Per-decision record the trainer keeps until the episode's rewards
/// are known.
struct EpisodeStep {
    feats: Vec<f64>,
    job: usize,
    cands: Vec<Vec<f64>>,
}

struct TrainerInner {
    agent: DqnAgent,
    steps: Vec<EpisodeStep>,
}

/// What one training episode earned, from
/// [`TrainerQueue::finish_episode`].
#[derive(Debug, Clone, PartialEq)]
pub struct EpisodeOutcome {
    /// Dispatch decisions taken.
    pub steps: usize,
    /// Summed per-decision reward (deadline-met dispatches pay +1, late
    /// completions +0.25, never-finished −0.5).
    pub reward: f64,
    /// Exploration rate after this episode's decay.
    pub epsilon: f64,
    /// Mean fitted-Q loss over the episode's batches (`None` while the
    /// replay buffer warms up).
    pub loss: Option<f64>,
}

/// The training shim: an ε-greedy [`DqnAgent`] behind a `Mutex`
/// (QueuePolicy takes `&self`; the simulator drives it from one
/// thread, so lock order — and therefore training — is deterministic).
/// Run an episode with [`crate::fleet::simulate_fleet_with`], then call
/// [`TrainerQueue::finish_episode`] with the metrics to assign the
/// delayed per-job rewards and learn.
pub struct TrainerQueue {
    inner: Mutex<TrainerInner>,
}

impl TrainerQueue {
    pub fn new(agent: DqnAgent) -> TrainerQueue {
        TrainerQueue { inner: Mutex::new(TrainerInner { agent, steps: Vec::new() }) }
    }

    /// Assign rewards from the finished episode's per-job outcomes,
    /// feed the replay buffer (each decision's `next` is the following
    /// decision's candidate matrix; the last is terminal), run the
    /// post-episode SGD batches, decay ε.
    pub fn finish_episode(&self, metrics: &FleetMetrics) -> EpisodeOutcome {
        let inner = &mut *self.inner.lock().expect("trainer lock");
        let steps = std::mem::take(&mut inner.steps);
        let mut reward_total = 0.0;
        for (i, s) in steps.iter().enumerate() {
            let stat = &metrics.per_job[s.job];
            let reward = if stat.met {
                1.0
            } else if stat.finish.is_some() {
                0.25
            } else {
                -0.5
            };
            reward_total += reward;
            let next =
                if i + 1 < steps.len() { steps[i + 1].cands.clone() } else { Vec::new() };
            inner.agent.remember(Transition { state: s.feats.clone(), reward, next });
        }
        let loss = inner.agent.train_episode();
        EpisodeOutcome {
            steps: steps.len(),
            reward: reward_total,
            epsilon: inner.agent.epsilon(),
            loss,
        }
    }

    /// Extract the agent (and its trained network) when training ends.
    pub fn into_agent(self) -> DqnAgent {
        self.inner.into_inner().expect("trainer lock").agent
    }
}

impl QueuePolicy for TrainerQueue {
    fn name(&self) -> &str {
        "Learned-trainer"
    }

    fn description(&self) -> &str {
        "epsilon-greedy training shim over the learned discipline (not for the registry)"
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        let cands = gather_candidates(ctx);
        if cands.is_empty() {
            return None;
        }
        let inner = &mut *self.inner.lock().expect("trainer lock");
        let matrix: Vec<Vec<f64>> = cands.iter().map(|c| c.feats.clone()).collect();
        let choice = inner.agent.act(&matrix);
        let chosen = &cands[choice];
        inner.steps.push(EpisodeStep {
            feats: chosen.feats.clone(),
            job: ctx.queue[chosen.pos],
            cands: matrix,
        });
        Some(QueueDecision { queue_pos: chosen.pos, placement: chosen.placement.clone() })
    }
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;

    use super::*;
    use crate::cluster::DeviceKind;
    use crate::fleet::policy::{BestFit, PlanOracle};
    use crate::fleet::Job;
    use crate::learn::DqnConfig;
    use crate::model::ModelSpec;
    use crate::util::rng::Rng;

    struct FlatOracle;

    impl PlanOracle for FlatOracle {
        fn service_time(&self, job: &Job, devices: &[Device]) -> Option<f64> {
            (!devices.is_empty()).then(|| job.samples as f64 / devices.len() as f64)
        }
    }

    struct Fx {
        jobs: Vec<Job>,
        queue: VecDeque<usize>,
        free: Vec<Device>,
        done: Vec<f64>,
        deadlines: Vec<f64>,
    }

    impl Fx {
        fn new(n: usize) -> Fx {
            let jobs: Vec<Job> = (0..n)
                .map(|i| Job::new(i, 0.0, ModelSpec::tiny(), 100 * (i + 1), 2))
                .collect();
            Fx {
                queue: (0..n).collect(),
                free: (0..2).map(|i| Device::new(i, DeviceKind::NanoH)).collect(),
                done: vec![0.0; n],
                deadlines: vec![f64::INFINITY; n],
                jobs,
            }
        }

        fn ctx(&self) -> QueueCtx<'_> {
            QueueCtx {
                jobs: &self.jobs,
                queue: &self.queue,
                free: &self.free,
                present: self.free.len(),
                n_running: 0,
                running: &[],
                done: &self.done,
                deadlines: &self.deadlines,
                now: 0.0,
                placement: &BestFit,
                oracle: &FlatOracle,
                ckpt: None,
                index: None,
            }
        }
    }

    #[test]
    fn learned_queue_picks_deterministically_and_within_queue() {
        let fx = Fx::new(5);
        let net = Mlp::new(&[N_FEATURES, 8, 1], &mut Rng::new(4));
        let policy = LearnedQueue::new(net);
        let a = policy.next(&fx.ctx()).expect("placeable jobs exist");
        let b = policy.next(&fx.ctx()).expect("placeable jobs exist");
        assert_eq!(a.queue_pos, b.queue_pos);
        assert!(a.queue_pos < fx.queue.len());
        assert_eq!(
            a.placement.devices.len(),
            b.placement.devices.len(),
            "same decision, same placement"
        );
    }

    #[test]
    fn empty_queue_and_no_free_devices_yield_none() {
        let mut fx = Fx::new(3);
        let net = Mlp::new(&[N_FEATURES, 8, 1], &mut Rng::new(4));
        let policy = LearnedQueue::new(net);
        fx.free.clear();
        assert!(policy.next(&fx.ctx()).is_none(), "no free devices");
        let mut fx = Fx::new(3);
        fx.queue.clear();
        assert!(policy.next(&fx.ctx()).is_none(), "empty queue");
    }

    /// The trainer records one step per decision and turns per-job
    /// outcomes into the documented rewards at episode end.
    #[test]
    fn trainer_records_and_rewards() {
        use crate::cluster::Env;
        use crate::fleet::{simulate_fleet_with, FleetOptions};
        // a real tiny run, for a well-formed single-job FleetMetrics
        let env = Env::env_a();
        let jobs = vec![Job::new(0, 0.0, ModelSpec::tiny(), 64, 1)];
        let m = simulate_fleet_with(
            &env,
            &jobs,
            &[],
            &BestFit,
            &crate::fleet::FifoQueue,
            &FleetOptions::default(),
        )
        .unwrap();

        let fx = Fx::new(1);
        let trainer = TrainerQueue::new(DqnAgent::new(DqnConfig::default(), 9));
        trainer.next(&fx.ctx()).expect("placeable");
        let out = trainer.finish_episode(&m);
        assert_eq!(out.steps, 1);
        let expected = if m.per_job[0].met {
            1.0
        } else if m.per_job[0].finish.is_some() {
            0.25
        } else {
            -0.5
        };
        assert_eq!(out.reward, expected);
        // episode log cleared: a second finish sees zero steps
        assert_eq!(trainer.finish_episode(&m).steps, 0);
    }
}
