//! Bounded experience-replay buffer with seeded sampling.
//!
//! A plain ring buffer: once `capacity` transitions are stored, new
//! pushes overwrite the oldest (standard DQN replay). Sampling is
//! **without replacement** via a partial Fisher–Yates over an index
//! array, drawn from the *caller's* seeded [`crate::util::rng::Rng`] —
//! the buffer itself holds no randomness, so the whole training loop
//! stays a pure function of its seed.

use crate::util::rng::Rng;

/// One decision-point experience: the features of the action taken,
/// the (delayed, per-job) reward it earned, and the candidate feature
/// matrix of the *next* decision (empty = terminal, no bootstrap).
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub state: Vec<f64>,
    pub reward: f64,
    pub next: Vec<Vec<f64>>,
}

/// Bounded FIFO-overwrite replay store.
#[derive(Debug, Clone)]
pub struct Replay {
    capacity: usize,
    buf: Vec<Transition>,
    /// Next write slot once the buffer is full (ring cursor).
    head: usize,
}

impl Replay {
    pub fn new(capacity: usize) -> Replay {
        assert!(capacity > 0, "replay capacity must be positive");
        Replay { capacity, buf: Vec::new(), head: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Store a transition, overwriting the oldest once full.
    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Sample `n` distinct stored transitions (all of them, in storage
    /// order, when `n >= len`; none when empty). Partial Fisher–Yates:
    /// exactly `min(n, len)` draws from `rng`, so the RNG consumption —
    /// and therefore everything downstream — is deterministic.
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Rng) -> Vec<&'a Transition> {
        let len = self.buf.len();
        if len == 0 {
            return Vec::new();
        }
        if n >= len {
            return self.buf.iter().collect();
        }
        let mut idx: Vec<usize> = (0..len).collect();
        for i in 0..n {
            let j = rng.range(i, len);
            idx.swap(i, j);
        }
        idx[..n].iter().map(|&i| &self.buf[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(tag: f64) -> Transition {
        Transition { state: vec![tag], reward: tag, next: Vec::new() }
    }

    fn tags(sample: &[&Transition]) -> Vec<f64> {
        sample.iter().map(|t| t.reward).collect()
    }

    /// The ring wraps: pushing past capacity overwrites oldest-first,
    /// keeping exactly the newest `capacity` transitions.
    #[test]
    fn wraparound_overwrites_oldest() {
        let mut r = Replay::new(4);
        for i in 0..4 {
            r.push(t(i as f64));
        }
        assert_eq!(r.len(), 4);
        // 3 more pushes overwrite slots 0, 1, 2
        for i in 4..7 {
            r.push(t(i as f64));
        }
        assert_eq!(r.len(), 4);
        let mut held = tags(&r.sample(10, &mut Rng::new(1)));
        held.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(held, vec![3.0, 4.0, 5.0, 6.0]);
        // a full second lap lands back on slot 0
        for i in 7..12 {
            r.push(t(i as f64));
        }
        let mut held = tags(&r.sample(10, &mut Rng::new(1)));
        held.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(held, vec![8.0, 9.0, 10.0, 11.0]);
    }

    /// Small-n sampling is without replacement: every sampled index is
    /// distinct, and n ≥ len degrades to "all of them, storage order".
    #[test]
    fn sampling_is_without_replacement() {
        let mut r = Replay::new(16);
        for i in 0..10 {
            r.push(t(i as f64));
        }
        let mut rng = Rng::new(9);
        for n in [1usize, 3, 7, 9] {
            let s = r.sample(n, &mut rng);
            assert_eq!(s.len(), n);
            let mut got = tags(&s);
            got.sort_by(|a, b| a.total_cmp(b));
            got.dedup();
            assert_eq!(got.len(), n, "duplicate transition in a sample of {n}");
        }
        assert_eq!(tags(&r.sample(10, &mut rng)), (0..10).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(r.sample(25, &mut rng).len(), 10);
    }

    /// Empty buffer: sampling returns nothing and consumes no RNG state.
    #[test]
    fn empty_buffer_samples_nothing() {
        let r = Replay::new(8);
        assert!(r.is_empty());
        let mut rng = Rng::new(5);
        let before = rng.clone();
        assert!(r.sample(4, &mut rng).is_empty());
        assert_eq!(rng.next_u64(), { let mut b = before; b.next_u64() });
    }

    #[test]
    fn same_seed_same_sample() {
        let mut r = Replay::new(32);
        for i in 0..20 {
            r.push(t(i as f64));
        }
        let a = tags(&r.sample(8, &mut Rng::new(42)));
        let b = tags(&r.sample(8, &mut Rng::new(42)));
        assert_eq!(a, b);
    }
}
