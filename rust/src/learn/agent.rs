//! The epsilon-greedy DQN-style agent over fleet decision points.
//!
//! "Action-in" architecture: instead of a fixed action space, the Q
//! network scores each *candidate job's* feature vector
//! ([`super::feature::featurize`]) and the agent picks the argmax (or
//! explores with probability ε). That makes the action space exactly
//! "the placeable queued jobs right now" — variable-width, like the
//! queue itself — with one scalar-head network.
//!
//! Learning is standard fitted Q with a target network: for each
//! replayed transition, `y = r + γ · max_a' Q_target(a')` (no bootstrap
//! on terminal transitions), one SGD step on the online network toward
//! `y`, target weights re-synced every [`DqnConfig::target_sync`]
//! batches. Rewards arrive *delayed* — the fleet simulator only knows a
//! dispatch's worth once the job meets/misses its deadline — so γ is
//! kept small: most credit is assigned directly to the dispatch
//! decision, with a light bootstrap through the queue state it left
//! behind.
//!
//! Exploration, replay sampling and weight init all draw from one
//! seeded [`crate::util::rng::Rng`], so a whole training run is a pure
//! function of `(workloads, seed)` — the bit-reproducibility the
//! property tests pin.

use super::feature::N_FEATURES;
use super::net::Mlp;
use super::replay::{Replay, Transition};
use crate::util::rng::Rng;

/// DQN hyperparameters. The defaults are the ones the `fleet_learn`
/// experiment and acceptance tests were tuned with; they favor fast,
/// stable convergence on hundreds-of-decisions episodes over
/// asymptotic polish.
#[derive(Debug, Clone)]
pub struct DqnConfig {
    /// Hidden tanh units in the Q head.
    pub hidden: usize,
    /// SGD learning rate.
    pub lr: f64,
    /// Discount on the bootstrapped next-decision value. Small by
    /// design: rewards are per-job outcomes already assigned to the
    /// dispatching decision.
    pub gamma: f64,
    /// Initial exploration rate.
    pub epsilon0: f64,
    /// Exploration floor.
    pub epsilon_min: f64,
    /// Multiplicative ε decay applied once per episode.
    pub epsilon_decay: f64,
    /// Transitions per SGD batch.
    pub batch: usize,
    /// SGD batches run after each episode.
    pub batches_per_episode: usize,
    /// Batches between target-network re-syncs.
    pub target_sync: usize,
    /// Replay-buffer capacity.
    pub replay_capacity: usize,
    /// No training until the buffer holds this many transitions.
    pub min_replay: usize,
}

impl Default for DqnConfig {
    fn default() -> DqnConfig {
        DqnConfig {
            hidden: 16,
            lr: 0.02,
            gamma: 0.2,
            epsilon0: 0.4,
            epsilon_min: 0.02,
            epsilon_decay: 0.85,
            batch: 32,
            batches_per_episode: 12,
            target_sync: 8,
            replay_capacity: 4096,
            min_replay: 48,
        }
    }
}

/// The agent: online + target Q networks, bounded replay, seeded
/// exploration state.
#[derive(Debug, Clone)]
pub struct DqnAgent {
    cfg: DqnConfig,
    q: Mlp,
    target: Mlp,
    replay: Replay,
    rng: Rng,
    epsilon: f64,
    batches: usize,
}

/// Greedy argmax over candidate scores; first-wins tie-break keeps the
/// choice deterministic (and queue-order-biased, a sane prior).
fn argmax(net: &Mlp, candidates: &[Vec<f64>]) -> usize {
    let mut best = 0;
    let mut best_q = f64::NEG_INFINITY;
    for (i, c) in candidates.iter().enumerate() {
        let q = net.scalar(c);
        if q > best_q {
            best_q = q;
            best = i;
        }
    }
    best
}

impl DqnAgent {
    pub fn new(cfg: DqnConfig, seed: u64) -> DqnAgent {
        let mut rng = Rng::new(seed ^ 0xD0_9E75);
        let q = Mlp::new(&[N_FEATURES, cfg.hidden, 1], &mut rng);
        let target = q.clone();
        let replay = Replay::new(cfg.replay_capacity);
        let epsilon = cfg.epsilon0;
        DqnAgent { cfg, q, target, replay, rng, epsilon, batches: 0 }
    }

    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// Pick a candidate index: uniform with probability ε, greedy
    /// otherwise. Panics on an empty candidate list (callers gate on
    /// non-empty — "no placeable job" is the policy's `None`, not an
    /// action).
    pub fn act(&mut self, candidates: &[Vec<f64>]) -> usize {
        assert!(!candidates.is_empty(), "act() needs at least one candidate");
        if self.rng.f64() < self.epsilon {
            self.rng.range(0, candidates.len())
        } else {
            argmax(&self.q, candidates)
        }
    }

    /// Greedy choice under the *online* network — what the exported
    /// inference-only policy will do with these weights.
    pub fn act_greedy(&self, candidates: &[Vec<f64>]) -> usize {
        argmax(&self.q, candidates)
    }

    pub fn remember(&mut self, t: Transition) {
        self.replay.push(t);
    }

    /// Post-episode learning: [`DqnConfig::batches_per_episode`] fitted-Q
    /// batches (skipped below [`DqnConfig::min_replay`]), then one ε
    /// decay. Returns the mean per-sample loss across the batches run
    /// (`None` when the buffer was still warming up).
    pub fn train_episode(&mut self) -> Option<f64> {
        if self.replay.len() < self.cfg.min_replay {
            self.epsilon = (self.epsilon * self.cfg.epsilon_decay).max(self.cfg.epsilon_min);
            return None;
        }
        let mut loss_sum = 0.0;
        let mut samples = 0usize;
        for _ in 0..self.cfg.batches_per_episode {
            // sample indices first; the SGD borrow needs &mut self.q
            // while the transitions borrow self.replay, so copy out
            let batch: Vec<Transition> = self
                .replay
                .sample(self.cfg.batch, &mut self.rng)
                .into_iter()
                .cloned()
                .collect();
            for t in &batch {
                let bootstrap = t
                    .next
                    .iter()
                    .map(|c| self.target.scalar(c))
                    .fold(f64::NEG_INFINITY, f64::max);
                let y = if bootstrap.is_finite() {
                    t.reward + self.cfg.gamma * bootstrap
                } else {
                    t.reward // terminal: nothing to bootstrap through
                };
                loss_sum += self.q.sgd_scalar(&t.state, y, self.cfg.lr);
                samples += 1;
            }
            self.batches += 1;
            if self.batches % self.cfg.target_sync == 0 {
                self.target = self.q.clone();
            }
        }
        self.epsilon = (self.epsilon * self.cfg.epsilon_decay).max(self.cfg.epsilon_min);
        (samples > 0).then(|| loss_sum / samples as f64)
    }

    /// Extract the trained online network (for dumping / wrapping in
    /// [`super::LearnedQueue`]).
    pub fn into_net(self) -> Mlp {
        self.q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(v: f64) -> Vec<f64> {
        let mut c = vec![0.0; N_FEATURES];
        c[0] = 1.0;
        c[1] = v;
        c
    }

    #[test]
    fn same_seed_agents_act_identically() {
        let cands = vec![cand(0.1), cand(0.5), cand(0.9)];
        let mut a = DqnAgent::new(DqnConfig::default(), 42);
        let mut b = DqnAgent::new(DqnConfig::default(), 42);
        for _ in 0..200 {
            assert_eq!(a.act(&cands), b.act(&cands));
        }
    }

    /// Fitted Q on a bandit: candidate with feature 0.9 pays +1, the
    /// others −1. After training, the greedy choice is the paying arm.
    #[test]
    fn learns_a_contextual_bandit() {
        let mut agent = DqnAgent::new(DqnConfig::default(), 7);
        let cands = vec![cand(0.1), cand(0.5), cand(0.9)];
        for _ in 0..40 {
            for (i, c) in cands.iter().enumerate() {
                let reward = if i == 2 { 1.0 } else { -1.0 };
                agent.remember(Transition {
                    state: c.clone(),
                    reward,
                    next: Vec::new(),
                });
            }
            agent.train_episode();
        }
        assert_eq!(agent.act_greedy(&cands), 2);
        assert!(agent.epsilon() < DqnConfig::default().epsilon0, "epsilon decayed");
    }

    #[test]
    fn no_training_below_min_replay() {
        let mut agent = DqnAgent::new(DqnConfig::default(), 3);
        agent.remember(Transition { state: cand(0.5), reward: 1.0, next: Vec::new() });
        assert_eq!(agent.train_episode(), None, "buffer below min_replay");
        assert!(agent.replay_len() < DqnConfig::default().min_replay);
    }
}
