//! `learn` — an in-simulator **reinforcement-learning scheduling
//! subsystem**: a dependency-free DQN trained at the fleet simulator's
//! decision points, exported as a pluggable queue discipline.
//!
//! The fleet layer's hand-written disciplines (FIFO, EASY-backfill,
//! SJF, EDF, LLF — [`crate::fleet::queue`]) each order the queue by one
//! signal. This module learns the ordering instead: every dispatch
//! decision becomes a state, every placeable queued job an action, and
//! the per-job outcome (deadline met / late / never finished) the
//! reward. Because the simulator is deterministic and fast, training
//! runs *inside* it — no environment wrappers, no frameworks, no
//! `rand`:
//!
//! * [`net`] — a small dense network (tanh MLP, scalar Q head) with
//!   seeded init, pure-Rust forward/backward, and **bit-exact** JSON
//!   weight dump/load via [`crate::util::json`];
//! * [`replay`] — a bounded ring replay buffer with seeded
//!   without-replacement sampling;
//! * [`feature`] — the decision-point featurizer: queue depth, oracle
//!   ETA, deadline slack, laxity, pool capacity/occupancy — a feature
//!   space containing every built-in discipline's key;
//! * [`agent`] — the ε-greedy fitted-Q agent (action-in scalar head,
//!   target network, per-episode SGD), reproducible bit for bit from
//!   its seed;
//! * [`policy`] — [`LearnedQueue`], the inference-only
//!   [`crate::fleet::QueuePolicy`] built from trained weights, and
//!   [`TrainerQueue`], the exploring/recording training shim;
//! * [`train`] — the episode loop over Weibull/UUniFast-diversified
//!   seeded workloads ([`workload`]), with provably disjoint held-out
//!   evaluation seeds ([`held_out_seed`]).
//!
//! Entry points: the `fleet_learn` experiment
//! ([`crate::exp::learn::fleet_learn_report`]), the `pacpp learn` CLI
//! subcommand (train → dump weights → reload → eval against
//! FIFO/backfill/EDF in one invocation), and the library pair
//! [`train()`]/[`evaluate()`]. See the crate docs ("Training a policy
//! in-sim") for the walkthrough.

pub mod agent;
pub mod feature;
pub mod net;
pub mod policy;
pub mod replay;
pub mod train;

pub use agent::{DqnAgent, DqnConfig};
pub use feature::{featurize, N_FEATURES};
pub use net::{Dense, Mlp};
pub use policy::{EpisodeOutcome, LearnedQueue, TrainerQueue, CANDIDATE_CAP};
pub use replay::{Replay, Transition};
pub use train::{
    evaluate, held_out_seed, train, train_observed, train_seed, workload, EpisodeStats,
    EvalStats, TrainConfig, TrainResult,
};
