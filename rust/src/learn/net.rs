//! A small dense policy/value network in pure Rust — the offline image
//! has no ML framework, and none is needed: the Q function over
//! [`super::feature::N_FEATURES`]-dimensional decision features is tiny
//! (one hidden layer of a few dozen tanh units), so forward passes and
//! SGD backward passes are a few hundred multiply-adds.
//!
//! Everything is `f64` end to end, for one load-bearing reason:
//! [`Mlp::to_json`]/[`Mlp::from_json`] must round-trip weights
//! **bit-exactly** through [`crate::util::json`] (whose numbers are
//! `f64` rendered with Rust's shortest-roundtrip `Display`), so a
//! dumped-and-reloaded network is the *same* network — the property the
//! determinism tests and the CI smoke (train → dump → reload → eval in
//! one step) pin down. Initialization draws from the caller's seeded
//! [`crate::util::rng::Rng`]; nothing here touches the wall clock or
//! thread-local randomness.

use anyhow::{anyhow, ensure, Result};

use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// One fully-connected layer: `out = act(W·x + b)`, weights stored
/// row-major (`w[o * n_in + i]`).
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    pub n_in: usize,
    pub n_out: usize,
    pub w: Vec<f64>,
    pub b: Vec<f64>,
}

impl Dense {
    /// Xavier/Glorot uniform init from the caller's seeded RNG.
    fn init(n_in: usize, n_out: usize, rng: &mut Rng) -> Dense {
        let s = (6.0 / (n_in + n_out) as f64).sqrt();
        let w = (0..n_in * n_out).map(|_| (2.0 * rng.f64() - 1.0) * s).collect();
        Dense { n_in, n_out, w, b: vec![0.0; n_out] }
    }

    fn forward(&self, x: &[f64], out: &mut Vec<f64>) {
        out.clear();
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = self.b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out.push(acc);
        }
    }
}

/// A multi-layer perceptron with tanh hidden activations and a linear
/// output layer — the DQN's scalar Q head ([`super::agent::DqnAgent`])
/// uses `dims = [N_FEATURES, hidden, 1]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Mlp {
    pub layers: Vec<Dense>,
}

impl Mlp {
    /// Build with seeded-deterministic initialization. `dims` lists the
    /// layer widths input-first; at least one weight layer is required.
    pub fn new(dims: &[usize], rng: &mut Rng) -> Mlp {
        assert!(dims.len() >= 2, "an MLP needs at least [n_in, n_out]");
        let layers = dims.windows(2).map(|w| Dense::init(w[0], w[1], rng)).collect();
        Mlp { layers }
    }

    pub fn n_in(&self) -> usize {
        self.layers[0].n_in
    }

    /// Forward pass, returning the (linear) output vector.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.n_in());
        let last = self.layers.len() - 1;
        let mut cur = x.to_vec();
        let mut next = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            layer.forward(&cur, &mut next);
            if li != last {
                for v in next.iter_mut() {
                    *v = v.tanh();
                }
            }
            std::mem::swap(&mut cur, &mut next);
        }
        cur
    }

    /// Scalar convenience for the 1-output Q head.
    pub fn scalar(&self, x: &[f64]) -> f64 {
        self.forward(x)[0]
    }

    /// One SGD step toward `target` on the scalar head under squared
    /// error, returning the pre-step loss. Plain backprop: tanh hidden
    /// gradients, linear output, no momentum — deterministic and
    /// dependency-free beats fancy here.
    pub fn sgd_scalar(&mut self, x: &[f64], target: f64, lr: f64) -> f64 {
        // forward, keeping each layer's post-activation output
        let last = self.layers.len() - 1;
        let mut acts: Vec<Vec<f64>> = Vec::with_capacity(self.layers.len() + 1);
        acts.push(x.to_vec());
        for (li, layer) in self.layers.iter().enumerate() {
            let mut out = Vec::new();
            layer.forward(&acts[li], &mut out);
            if li != last {
                for v in out.iter_mut() {
                    *v = v.tanh();
                }
            }
            acts.push(out);
        }
        let y = acts[last + 1][0];
        let err = y - target;
        let loss = 0.5 * err * err;

        // backward: delta starts at dL/dy for the linear scalar head
        let mut delta = vec![err];
        for li in (0..self.layers.len()).rev() {
            let layer = &mut self.layers[li];
            let input = &acts[li];
            // gradient w.r.t. this layer's input, before updating W
            let mut prev_delta = vec![0.0; layer.n_in];
            for o in 0..layer.n_out {
                let row = &layer.w[o * layer.n_in..(o + 1) * layer.n_in];
                for (pd, wi) in prev_delta.iter_mut().zip(row) {
                    *pd += wi * delta[o];
                }
            }
            // parameter step
            for o in 0..layer.n_out {
                let row = &mut layer.w[o * layer.n_in..(o + 1) * layer.n_in];
                for (wi, xi) in row.iter_mut().zip(input) {
                    *wi -= lr * delta[o] * xi;
                }
                layer.b[o] -= lr * delta[o];
            }
            if li > 0 {
                // through the tanh of the layer below: act' = 1 - act²
                for (pd, a) in prev_delta.iter_mut().zip(&acts[li]) {
                    *pd *= 1.0 - a * a;
                }
                delta = prev_delta;
            }
        }
        loss
    }

    /// Serialize as nested JSON arrays (`{"dims": [...], "layers":
    /// [{"w": [...], "b": [...]}, ...]}`). Numbers are `f64` through
    /// and through, so [`Mlp::from_json`] restores every weight
    /// bit-exactly.
    pub fn to_json(&self) -> Json {
        let mut dims: Vec<Json> = vec![self.n_in().into()];
        dims.extend(self.layers.iter().map(|l| Json::from(l.n_out)));
        let layers: Json = self
            .layers
            .iter()
            .map(|l| {
                obj(vec![
                    ("w", l.w.iter().map(|&v| Json::from(v)).collect()),
                    ("b", l.b.iter().map(|&v| Json::from(v)).collect()),
                ])
            })
            .collect();
        obj(vec![("dims", Json::Arr(dims)), ("layers", layers)])
    }

    /// Parse the [`Mlp::to_json`] format, validating every shape.
    pub fn from_json(json: &Json) -> Result<Mlp> {
        let dims: Vec<usize> = json
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("weights: missing \"dims\" array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("weights: non-integer dim")))
            .collect::<Result<_>>()?;
        ensure!(dims.len() >= 2, "weights: need at least [n_in, n_out] dims");
        let layers_json = json
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("weights: missing \"layers\" array"))?;
        ensure!(
            layers_json.len() == dims.len() - 1,
            "weights: {} layers for {} dims",
            layers_json.len(),
            dims.len()
        );
        let floats = |j: Option<&Json>, what: &str, want: usize| -> Result<Vec<f64>> {
            let arr = j
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("weights: missing \"{what}\" array"))?;
            ensure!(arr.len() == want, "weights: {what} has {} values, want {want}", arr.len());
            arr.iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow!("weights: non-numeric {what}")))
                .collect()
        };
        let mut layers = Vec::with_capacity(layers_json.len());
        for (i, l) in layers_json.iter().enumerate() {
            let (n_in, n_out) = (dims[i], dims[i + 1]);
            layers.push(Dense {
                n_in,
                n_out,
                w: floats(l.get("w"), "w", n_in * n_out)?,
                b: floats(l.get("b"), "b", n_out)?,
            });
        }
        Ok(Mlp { layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_seeded_and_deterministic() {
        let a = Mlp::new(&[4, 8, 1], &mut Rng::new(7));
        let b = Mlp::new(&[4, 8, 1], &mut Rng::new(7));
        assert_eq!(a, b);
        let c = Mlp::new(&[4, 8, 1], &mut Rng::new(8));
        assert_ne!(a, c, "different seeds must give different nets");
    }

    /// SGD on the scalar head drives the squared error down on a tiny
    /// regression problem (fit y = 2·x₀ − x₁).
    #[test]
    fn sgd_learns_a_linear_target() {
        let mut net = Mlp::new(&[2, 8, 1], &mut Rng::new(3));
        let data: Vec<([f64; 2], f64)> = (0..16)
            .map(|i| {
                let x0 = (i % 4) as f64 / 4.0;
                let x1 = (i / 4) as f64 / 4.0;
                ([x0, x1], 2.0 * x0 - x1)
            })
            .collect();
        let loss_sum = |net: &Mlp| -> f64 {
            data.iter().map(|(x, y)| (net.scalar(x) - y).powi(2)).sum()
        };
        let before = loss_sum(&net);
        for _ in 0..400 {
            for (x, y) in &data {
                net.sgd_scalar(x, *y, 0.05);
            }
        }
        let after = loss_sum(&net);
        assert!(after < before * 0.05, "loss {before} -> {after}");
    }

    /// Weights survive JSON bit-exactly: dump → parse → identical
    /// structure AND identical forward outputs to the bit, through the
    /// full text pipeline the CLI uses.
    #[test]
    fn json_roundtrip_is_bit_exact() {
        let mut rng = Rng::new(11);
        let mut net = Mlp::new(&[6, 12, 1], &mut rng);
        // push the weights off their clean init values
        for _ in 0..50 {
            let x: Vec<f64> = (0..6).map(|_| rng.f64()).collect();
            net.sgd_scalar(&x, rng.f64(), 0.1);
        }
        let text = net.to_json().to_string_pretty();
        let back = Mlp::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(net, back);
        let x: Vec<f64> = (0..6).map(|_| rng.f64()).collect();
        assert_eq!(net.scalar(&x).to_bits(), back.scalar(&x).to_bits());
    }

    #[test]
    fn from_json_rejects_malformed_weights() {
        for (src, needle) in [
            (r#"{"layers": []}"#, "missing \"dims\""),
            (r#"{"dims": [4], "layers": []}"#, "at least"),
            (r#"{"dims": [2, 1], "layers": []}"#, "0 layers for 2 dims"),
            (r#"{"dims": [2, 1], "layers": [{"b": [0]}]}"#, "missing \"w\""),
            (
                r#"{"dims": [2, 1], "layers": [{"w": [1], "b": [0]}]}"#,
                "w has 1 values, want 2",
            ),
            (
                r#"{"dims": [2, 1], "layers": [{"w": [1, 2], "b": []}]}"#,
                "b has 0 values, want 1",
            ),
        ] {
            let err =
                Mlp::from_json(&Json::parse(src).unwrap()).unwrap_err().to_string();
            assert!(err.contains(needle), "{src}: {err}");
        }
    }
}
