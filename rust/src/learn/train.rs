//! The in-simulator training loop: episodes of
//! [`crate::fleet::simulate_fleet_with`] under the
//! [`super::TrainerQueue`], over a seeded grid of diversified
//! workloads, with held-out seeds for evaluation.
//!
//! **Workload diversification** ([`workload`]): each episode's trace
//! starts from one of the three built-in [`TraceKind`]s, then gets its
//! arrivals re-spaced with Weibull inter-arrival gaps
//! ([`crate::util::rng::Rng::weibull`] — shape < 1 produces burstiness
//! the built-in generators never reach) and its deadline-slack budget
//! re-spread across jobs with UUniFast
//! ([`crate::util::rng::Rng::uunifast`] — total slack fixed, its
//! distribution varying per seed, so some jobs are tight and some
//! loose in every episode). Diverse training workloads are what stop
//! the agent from memorizing one trace's dispatch sequence.
//!
//! **Seed hygiene**: training seeds are always even
//! ([`train_seed`]), held-out evaluation seeds always odd
//! ([`held_out_seed`]) — provably disjoint, so an evaluation win can
//! never be a memorized workload.
//!
//! Everything here is a pure function of `(env, config)`: same config,
//! same weights, bit for bit (property-tested in
//! `tests/prop_invariants.rs`).

use anyhow::Result;

use crate::cluster::Env;
use crate::fleet::{
    generate_churn, generate_jobs, simulate_fleet_with, simulate_fleet_with_observed, BestFit,
    ChurnEvent, FleetOptions, Job, QueuePolicy, TraceKind,
};
use crate::obs::Observer;
use crate::util::rng::Rng;

use super::agent::{DqnAgent, DqnConfig};
use super::net::Mlp;
use super::policy::TrainerQueue;

/// Training-run configuration: workload shape + DQN hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Training episodes (one fleet simulation each).
    pub episodes: usize,
    /// Jobs per episode.
    pub jobs: usize,
    /// Master seed: drives weight init, exploration, replay sampling
    /// and the training-workload grid.
    pub seed: u64,
    /// Held-out evaluation workloads ([`held_out_seed`] indices `0..n`).
    pub eval_seeds: usize,
    /// Simulated horizon per episode, seconds.
    pub horizon: f64,
    /// Deadline scale forwarded to [`FleetOptions::deadline_scale`].
    pub deadline_scale: f64,
    pub dqn: DqnConfig,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            episodes: 30,
            jobs: 40,
            seed: 42,
            eval_seeds: 3,
            horizon: 48.0 * 3600.0,
            deadline_scale: 1.0,
            dqn: DqnConfig::default(),
        }
    }
}

/// Seed of training episode `e`: always **even**.
pub fn train_seed(seed: u64, episode: usize) -> u64 {
    seed.wrapping_add(0x51AB3u64.wrapping_mul(episode as u64 + 1)) << 1
}

/// Seed of held-out evaluation workload `i`: always **odd**, hence
/// disjoint from every [`train_seed`].
pub fn held_out_seed(i: usize) -> u64 {
    (0x9E1D_5EEDu64.wrapping_add(i as u64) << 1) | 1
}

/// One diversified episode workload: a built-in trace re-spaced with
/// Weibull inter-arrivals and re-slacked with a UUniFast spread, plus
/// (on some seeds) a sampled churn trace. Deterministic in `seed`.
pub fn workload(env: &Env, n_jobs: usize, horizon: f64, seed: u64) -> (Vec<Job>, Vec<ChurnEvent>) {
    let mut rng = Rng::new(seed ^ 0x11EA2D);
    let kind = *rng.choose(&TraceKind::ALL);
    let mut jobs = generate_jobs(kind, n_jobs, seed);
    // arrivals: Weibull gaps at a mean that lands the stream inside
    // roughly the first half of the horizon, so late arrivals still
    // have room to finish. Cumulative sums keep ids arrival-sorted.
    let shape = *rng.choose(&[0.6, 0.8, 1.0, 1.4]);
    let mean_gap = 0.5 * horizon / n_jobs.max(1) as f64;
    let mut t = 0.0;
    for j in jobs.iter_mut() {
        t += rng.weibull(shape, mean_gap);
        j.arrival = t;
    }
    // deadline slack: a fixed total budget, UUniFast-spread — every
    // episode mixes tight and loose jobs in different proportions
    for (j, p) in jobs.iter_mut().zip(rng.uunifast(n_jobs, n_jobs as f64)) {
        j.deadline_mult = (0.8 + 1.2 * p).clamp(0.9, 4.0);
    }
    let churn_rate = *rng.choose(&[0.0, 1.0, 2.5]);
    let churn = if churn_rate > 0.0 {
        generate_churn(env, horizon, churn_rate, seed)
    } else {
        Vec::new()
    };
    (jobs, churn)
}

fn fleet_opts(cfg: &TrainConfig) -> FleetOptions {
    FleetOptions {
        horizon: cfg.horizon,
        deadline_scale: cfg.deadline_scale,
        ..FleetOptions::default()
    }
}

/// One row of the training curve.
#[derive(Debug, Clone, PartialEq)]
pub struct EpisodeStats {
    pub episode: usize,
    pub seed: u64,
    /// Dispatch decisions the agent took.
    pub steps: usize,
    /// Summed per-decision reward.
    pub reward: f64,
    /// Exploration rate after this episode.
    pub epsilon: f64,
    /// Mean fitted-Q loss (`None` during replay warm-up).
    pub loss: Option<f64>,
    pub goodput: f64,
    pub miss_rate: f64,
    pub completed: usize,
    pub met: usize,
}

/// What [`train`] returns: the episode curve and the trained network.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub episodes: Vec<EpisodeStats>,
    pub net: Mlp,
}

/// Run the training loop. Bit-deterministic in `(env, cfg)`.
pub fn train(env: &Env, cfg: &TrainConfig) -> Result<TrainResult> {
    train_observed(env, cfg, &Observer::disabled())
}

/// [`train`] with an [`Observer`]: episodes become `learn.episode`
/// spans laid end-to-end on a cumulative virtual-makespan axis, each
/// episode's fleet-level job events are traced through
/// [`simulate_fleet_with_observed`], and the whole loop runs under the
/// `training` wall-clock phase. Observation never perturbs training
/// (property-pinned weight determinism still holds).
pub fn train_observed(env: &Env, cfg: &TrainConfig, obs: &Observer) -> Result<TrainResult> {
    let training_timer = obs.timer("training");
    let opts = fleet_opts(cfg);
    let trainer = TrainerQueue::new(DqnAgent::new(cfg.dqn.clone(), cfg.seed));
    let mut episodes = Vec::with_capacity(cfg.episodes);
    let mut virtual_t = 0.0f64;
    for e in 0..cfg.episodes {
        let seed = train_seed(cfg.seed, e);
        let (jobs, churn) = workload(env, cfg.jobs, cfg.horizon, seed);
        let m = simulate_fleet_with_observed(env, &jobs, &churn, &BestFit, &trainer, &opts, obs)?;
        obs.span("learn.episode", "episode", e as u64, virtual_t, m.makespan);
        virtual_t += m.makespan;
        let out = trainer.finish_episode(&m);
        episodes.push(EpisodeStats {
            episode: e,
            seed,
            steps: out.steps,
            reward: out.reward,
            epsilon: out.epsilon,
            loss: out.loss,
            goodput: m.goodput_per_hour,
            miss_rate: m.deadline_miss_rate,
            completed: m.completed,
            met: m.deadline_met,
        });
    }
    drop(training_timer);
    Ok(TrainResult { episodes, net: trainer.into_agent().into_net() })
}

/// Held-out evaluation aggregate for one queue policy.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalStats {
    pub policy: String,
    /// Mean goodput (deadline-met jobs/hour) over the held-out seeds.
    pub goodput: f64,
    /// Mean deadline-miss rate over the held-out seeds.
    pub miss_rate: f64,
    /// Completions summed over the held-out seeds.
    pub completed: usize,
    /// Deadline-met completions summed over the held-out seeds.
    pub met: usize,
}

/// Evaluate one queue policy on the `cfg.eval_seeds` held-out
/// workloads ([`held_out_seed`] — disjoint from every training seed).
pub fn evaluate(env: &Env, cfg: &TrainConfig, policy: &dyn QueuePolicy) -> Result<EvalStats> {
    let opts = fleet_opts(cfg);
    let (mut goodput, mut miss) = (0.0, 0.0);
    let (mut completed, mut met) = (0usize, 0usize);
    for i in 0..cfg.eval_seeds {
        let (jobs, churn) = workload(env, cfg.jobs, cfg.horizon, held_out_seed(i));
        let m = simulate_fleet_with(env, &jobs, &churn, &BestFit, policy, &opts)?;
        goodput += m.goodput_per_hour;
        miss += m.deadline_miss_rate;
        completed += m.completed;
        met += m.deadline_met;
    }
    let n = cfg.eval_seeds.max(1) as f64;
    Ok(EvalStats {
        policy: policy.name().to_string(),
        goodput: goodput / n,
        miss_rate: miss / n,
        completed,
        met,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Training/eval seed spaces cannot collide: even vs odd.
    #[test]
    fn seed_spaces_are_disjoint() {
        for s in [0u64, 42, 7_000_000] {
            for e in 0..50 {
                assert_eq!(train_seed(s, e) & 1, 0);
            }
        }
        for i in 0..50 {
            assert_eq!(held_out_seed(i) & 1, 1);
        }
    }

    #[test]
    fn workloads_are_deterministic_and_sorted() {
        let env = Env::env_a();
        let (a_jobs, a_churn) = workload(&env, 30, 48.0 * 3600.0, held_out_seed(0));
        let (b_jobs, b_churn) = workload(&env, 30, 48.0 * 3600.0, held_out_seed(0));
        assert_eq!(a_jobs.len(), 30);
        assert_eq!(a_churn, b_churn);
        for (x, y) in a_jobs.iter().zip(&b_jobs) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.deadline_mult.to_bits(), y.deadline_mult.to_bits());
        }
        for w in a_jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "respaced arrivals stay sorted");
        }
        for (i, j) in a_jobs.iter().enumerate() {
            assert_eq!(j.id, i, "ids stay index-aligned");
            assert!((0.9..=4.0).contains(&j.deadline_mult));
        }
        // different seeds give different workloads
        let (c_jobs, _) = workload(&env, 30, 48.0 * 3600.0, held_out_seed(1));
        assert_ne!(a_jobs[0].arrival.to_bits(), c_jobs[0].arrival.to_bits());
    }
}
