//! The featurizer: one fleet decision point → a fixed-width, normalized
//! feature vector the Q network scores.
//!
//! A "decision point" is one `(queue state, candidate job)` pair inside
//! [`crate::fleet::QueuePolicy::next`]: the agent ranks every placeable
//! queued job by `Q(φ(state, job))` and starts the best one. The
//! features deliberately span what the *hand-written* disciplines each
//! read in isolation — queue depth (FIFO's blindness), the oracle's
//! whole-pool ETA (SJF's key), deadline slack (EDF's key), laxity
//! (LLF's key), plus pool capacity/occupancy signals none of them use —
//! so the learned policy's hypothesis space contains every built-in
//! ordering and the blends between them.
//!
//! Every feature is squashed to a bounded range (most via
//! `x / (1 + |x|)`, a cheap smooth sigmoid that keeps relative order),
//! with time-like quantities pre-scaled to hours. Bounded inputs keep
//! the tanh hidden layer out of saturation regardless of how long the
//! simulated horizon runs.

use crate::fleet::{Placement, QueueCtx};

/// Width of [`featurize`]'s output — the Q network's input dimension.
pub const N_FEATURES: usize = 12;

/// Smooth squash to (−1, 1): monotone, cheap, no saturation cliff.
fn squash(x: f64) -> f64 {
    x / (1.0 + x.abs())
}

/// Hours-scaled squash for durations/slacks; ±∞ maps to ±1.
fn squash_h(seconds: f64) -> f64 {
    if seconds.is_infinite() {
        seconds.signum()
    } else {
        squash(seconds / 3600.0)
    }
}

/// Featurize the candidate at queue position `pos` given its whole-pool
/// service estimate `est` (the SJF/LLF oracle quote, ∞ = infeasible on
/// the full pool) and the `placement` it would start with right now.
///
/// Layout (each entry documented because the dump/load weights format
/// is only meaningful against a fixed feature contract):
///
/// | # | feature | range |
/// |---|---------------------------------------------|--------|
/// | 0 | bias (always 1) | 1 |
/// | 1 | queue depth / 32, capped | [0, 1] |
/// | 2 | free-device fraction of the present pool | [0, 1] |
/// | 3 | running-job count / present devices, capped | [0, 1] |
/// | 4 | candidate's queue position / queue length | [0, 1) |
/// | 5 | wait so far (now − arrival), squashed hours | [0, 1) |
/// | 6 | whole-pool ETA `est`, squashed hours | [0, 1] |
/// | 7 | this placement's attempt duration, squashed | [0, 1) |
/// | 8 | deadline slack (deadline − now), squashed | (−1, 1] |
/// | 9 | laxity (slack − attempt on this placement) | (−1, 1] |
/// | 10| devices the placement claims / present | (0, 1] |
/// | 11| durable progress already checkpointed | [0, 1] |
pub fn featurize(ctx: &QueueCtx, pos: usize, est: f64, placement: &Placement) -> Vec<f64> {
    let job_id = ctx.queue[pos];
    let job = &ctx.jobs[job_id];
    let present = ctx.present.max(1) as f64;
    let deadline = ctx.deadlines[job_id];
    let attempt = ctx.attempt_duration(job, placement.service_time);
    vec![
        1.0,
        (ctx.queue.len() as f64 / 32.0).min(1.0),
        ctx.free.len() as f64 / present,
        (ctx.n_running as f64 / present).min(1.0),
        pos as f64 / ctx.queue.len().max(1) as f64,
        squash_h(ctx.now - job.arrival),
        squash_h(est),
        squash_h(attempt),
        squash_h(deadline - ctx.now),
        squash_h(if deadline.is_infinite() { deadline } else { deadline - ctx.now - attempt }),
        placement.devices.len() as f64 / present,
        ctx.done[job_id],
    ]
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;

    use super::*;
    use crate::cluster::{Device, DeviceKind};
    use crate::fleet::policy::{BestFit, PlanOracle};
    use crate::fleet::Job;
    use crate::model::ModelSpec;

    struct FlatOracle;

    impl PlanOracle for FlatOracle {
        fn service_time(&self, job: &Job, devices: &[Device]) -> Option<f64> {
            (!devices.is_empty()).then(|| job.samples as f64 / devices.len() as f64)
        }
    }

    #[test]
    fn features_are_bounded_and_deadline_aware() {
        let jobs = vec![
            Job::new(0, 0.0, ModelSpec::tiny(), 7200, 2),
            Job::new(1, 100.0, ModelSpec::tiny(), 3600, 2).with_deadline_mult(1.0),
        ];
        let queue: VecDeque<usize> = VecDeque::from(vec![0, 1]);
        let free: Vec<Device> = (0..4).map(|i| Device::new(i, DeviceKind::NanoH)).collect();
        let done = vec![0.0, 0.25];
        // job 0 deadline-less, job 1 tight
        let deadlines = vec![f64::INFINITY, 500.0];
        let ctx = QueueCtx {
            jobs: &jobs,
            queue: &queue,
            free: &free,
            present: 4,
            n_running: 0,
            running: &[],
            done: &done,
            deadlines: &deadlines,
            now: 400.0,
            placement: &BestFit,
            oracle: &FlatOracle,
            ckpt: None,
            index: None,
        };
        for pos in 0..2 {
            let p = ctx.try_place(&jobs[ctx.queue[pos]], &free, 0).unwrap();
            let est = FlatOracle.service_time(&jobs[ctx.queue[pos]], &free).unwrap();
            let f = featurize(&ctx, pos, est, &p);
            assert_eq!(f.len(), N_FEATURES);
            assert!(f.iter().all(|v| v.is_finite() && (-1.0..=1.0).contains(v)), "{f:?}");
        }
        // deadline-less head: slack and laxity saturate at +1
        let p0 = ctx.try_place(&jobs[0], &free, 0).unwrap();
        let f0 = featurize(&ctx, 0, 1800.0, &p0);
        assert_eq!(f0[8], 1.0);
        assert_eq!(f0[9], 1.0);
        assert_eq!(f0[11], 0.0, "fresh job has no durable progress");
        // the tight job: positive wait, small positive slack, progress
        let p1 = ctx.try_place(&jobs[1], &free, 0).unwrap();
        let f1 = featurize(&ctx, 1, 900.0, &p1);
        assert!(f1[5] > 0.0, "waited 300 s");
        assert!(f1[8] > 0.0 && f1[8] < 0.1, "100 s of slack squashes small");
        assert!(f1[9] < f1[8], "laxity < slack once the attempt is subtracted");
        assert_eq!(f1[11], 0.25);
    }

    /// Infeasible-on-the-full-pool candidates (est = ∞) featurize to
    /// the saturated ETA rather than NaN/∞ — the net must always see
    /// finite inputs.
    #[test]
    fn infinite_estimate_saturates() {
        let jobs = vec![Job::new(0, 0.0, ModelSpec::tiny(), 100, 2)];
        let queue: VecDeque<usize> = VecDeque::from(vec![0]);
        let free: Vec<Device> = vec![Device::new(0, DeviceKind::NanoH)];
        let ctx = QueueCtx {
            jobs: &jobs,
            queue: &queue,
            free: &free,
            present: 1,
            n_running: 0,
            running: &[],
            done: &[0.0],
            deadlines: &[f64::INFINITY],
            now: 0.0,
            placement: &BestFit,
            oracle: &FlatOracle,
            ckpt: None,
            index: None,
        };
        let p = ctx.try_place(&jobs[0], &free, 0).unwrap();
        let f = featurize(&ctx, 0, f64::INFINITY, &p);
        assert_eq!(f[6], 1.0);
        assert!(f.iter().all(|v| v.is_finite()));
    }
}
