//! Synthetic workload generators standing in for the paper's GLUE tasks
//! (§VI-A: MRPC, STS-B, SST-2, QNLI) — see DESIGN.md §2 for the
//! substitution rationale.
//!
//! Two things are needed from "data" in this reproduction:
//!
//! 1. **Dataset sizes** driving the timing experiments (epoch time =
//!    samples × per-sample cost) — [`Task`] carries the real GLUE train
//!    sizes and the paper's epoch counts.
//! 2. **Learnable synthetic token tasks** for the real-execution accuracy
//!    experiments — [`SyntheticTask::generate`] emits token sequences whose
//!    label is a (noisy) function of token statistics, so fine-tuning has
//!    real signal to find.

use crate::util::rng::Rng;

/// A GLUE evaluation task (paper Table V/VI setup).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Mrpc,
    StsB,
    Sst2,
    Qnli,
}

impl Task {
    pub fn all() -> [Task; 4] {
        [Task::Mrpc, Task::StsB, Task::Sst2, Task::Qnli]
    }

    pub fn name(self) -> &'static str {
        match self {
            Task::Mrpc => "MRPC",
            Task::StsB => "STS-B",
            Task::Sst2 => "SST-2",
            Task::Qnli => "QNLI",
        }
    }

    /// GLUE training-split sizes.
    pub fn train_samples(self) -> usize {
        match self {
            Task::Mrpc => 3_668,
            Task::StsB => 5_749,
            Task::Sst2 => 67_349,
            Task::Qnli => 104_743,
        }
    }

    /// Paper §VI-B: 3 epochs for the small datasets (MRPC, STS-B),
    /// 1 epoch for the large ones (SST-2, QNLI).
    pub fn epochs(self) -> usize {
        match self {
            Task::Mrpc | Task::StsB => 3,
            Task::Sst2 | Task::Qnli => 1,
        }
    }
}

/// Labeling rule for generated tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Parity of the count of "low" tokens (< vocab/2) — hard: the model
    /// must count mod 2 across the whole sequence.
    Parity,
    /// Majority vote of low tokens in the first half of the sequence —
    /// easier (attention-pooling suffices); used by the accuracy-shape
    /// experiments where convergence within a small budget matters.
    HalfMajority,
}

/// A generated token-classification dataset.
#[derive(Debug, Clone)]
pub struct SyntheticTask {
    pub tokens: Vec<Vec<i32>>, // [n][seq]
    pub labels: Vec<i32>,      // [n]
    pub vocab: usize,
    pub n_classes: usize,
}

impl SyntheticTask {
    /// Token-statistic classification with the [`Rule::Parity`] label,
    /// flipped with probability `noise`.
    pub fn generate(
        n: usize,
        seq: usize,
        vocab: usize,
        noise: f64,
        seed: u64,
    ) -> SyntheticTask {
        Self::generate_rule(n, seq, vocab, noise, seed, Rule::Parity)
    }

    /// Generate with an explicit labeling rule.
    pub fn generate_rule(
        n: usize,
        seq: usize,
        vocab: usize,
        noise: f64,
        seed: u64,
        rule: Rule,
    ) -> SyntheticTask {
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<i32> = (0..seq).map(|_| rng.range(0, vocab) as i32).collect();
            let mut y = match rule {
                Rule::Parity => {
                    let low =
                        row.iter().filter(|&&t| (t as usize) < vocab / 2).count();
                    (low % 2) as i32
                }
                Rule::HalfMajority => {
                    let half = &row[..seq / 2];
                    let low =
                        half.iter().filter(|&&t| (t as usize) < vocab / 2).count();
                    i32::from(low * 2 > half.len())
                }
            };
            if rng.f64() < noise {
                y = 1 - y;
            }
            tokens.push(row);
            labels.push(y);
        }
        SyntheticTask { tokens, labels, vocab, n_classes: 2 }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Iterate micro-batches of exactly `batch` rows (drops the remainder,
    /// matching the fixed-shape AOT artifacts).
    pub fn batches(&self, batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        let n = self.len() / batch;
        (0..n)
            .map(|b| {
                let toks: Vec<i32> = (b * batch..(b + 1) * batch)
                    .flat_map(|i| self.tokens[i].iter().copied())
                    .collect();
                let labs: Vec<i32> =
                    (b * batch..(b + 1) * batch).map(|i| self.labels[i]).collect();
                (toks, labs)
            })
            .collect()
    }

    /// Split off the last `frac` of samples as a held-out eval set.
    pub fn split(mut self, frac: f64) -> (SyntheticTask, SyntheticTask) {
        let n_eval = ((self.len() as f64 * frac) as usize).max(1);
        let n_train = self.len() - n_eval;
        let eval = SyntheticTask {
            tokens: self.tokens.split_off(n_train),
            labels: self.labels.split_off(n_train),
            vocab: self.vocab,
            n_classes: self.n_classes,
        };
        (self, eval)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glue_sizes() {
        assert_eq!(Task::Mrpc.train_samples(), 3668);
        assert_eq!(Task::Qnli.train_samples(), 104_743);
        assert_eq!(Task::Mrpc.epochs(), 3);
        assert_eq!(Task::Sst2.epochs(), 1);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = SyntheticTask::generate(20, 16, 100, 0.0, 42);
        let b = SyntheticTask::generate(20, 16, 100, 0.0, 42);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn labels_follow_rule_when_noiseless() {
        let t = SyntheticTask::generate(50, 16, 100, 0.0, 1);
        for (row, &y) in t.tokens.iter().zip(&t.labels) {
            let low = row.iter().filter(|&&t| t < 50).count();
            assert_eq!(y, (low % 2) as i32);
        }
    }

    #[test]
    fn batching_shapes() {
        let t = SyntheticTask::generate(25, 8, 100, 0.0, 2);
        let bs = t.batches(4);
        assert_eq!(bs.len(), 6); // 25/4 = 6, remainder dropped
        for (toks, labs) in &bs {
            assert_eq!(toks.len(), 32);
            assert_eq!(labs.len(), 4);
        }
    }

    #[test]
    fn split_fractions() {
        let t = SyntheticTask::generate(100, 8, 100, 0.0, 3);
        let (train, eval) = t.split(0.2);
        assert_eq!(train.len(), 80);
        assert_eq!(eval.len(), 20);
    }

    #[test]
    fn half_majority_rule() {
        let t = SyntheticTask::generate_rule(50, 16, 100, 0.0, 9, Rule::HalfMajority);
        for (row, &y) in t.tokens.iter().zip(&t.labels) {
            let low = row[..8].iter().filter(|&&v| v < 50).count();
            assert_eq!(y, i32::from(low * 2 > 8));
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let t = SyntheticTask::generate(2000, 16, 100, 0.0, 4);
        let ones: usize = t.labels.iter().filter(|&&y| y == 1).count();
        assert!(ones > 800 && ones < 1200, "{ones}");
    }
}
