//! The PAC+ hybrid-parallelism planner (paper §V-A, Eq. 3–7, Algorithm 1).
//!
//! Given a profiled model and an ordered device set `D` (fastest first),
//! the planner jointly decides:
//!
//! * how many pipeline stages `σ` to use (Eq. 5–7),
//! * where to cut the layer chain (Eq. 3's balanced-sub-pipeline DP),
//! * which contiguous run of devices forms each stage's data-parallel
//!   group, and
//! * how many samples of each micro-batch every group member processes
//!   (Eq. 4's heterogeneity-aware sample-dispatch DP), excluding
//!   out-of-memory assignments by pricing them at +∞.
//!
//! Memory accounting is 1F1B-aware: stage `k` of an `s`-stage pipeline
//! holds up to `min(M, s−k+1)` in-flight micro-batches, so the DP tables
//! are computed per candidate total stage count — which also makes the σ
//! candidates independent: [`dp::plan`] searches them on worker threads
//! over one shared immutable cost view (see
//! [`PlannerOptions::search_threads`]).
//!
//! This module is the engine behind the [`crate::strategy::PacPlus`]
//! family; the other [`crate::strategy`] implementations construct their
//! plans directly but share the same [`Plan`] vocabulary and validator.

pub mod dp;

pub use dp::{plan, PlanError, PlannerOptions};

use crate::cluster::Device;

/// One pipeline stage of a finalized plan.
#[derive(Debug, Clone)]
pub struct StagePlan {
    /// Blocks `[x, y)` of the layer graph hosted by this stage.
    pub range: (usize, usize),
    /// The data-parallel device group replicating this stage.
    pub devices: Vec<Device>,
    /// Samples of each micro-batch dispatched to each group member
    /// (aligned with `devices`; sums to the micro-batch size).
    pub dispatch: Vec<usize>,
    /// Per-micro-batch forward / backward time of the slowest member.
    pub e_f: f64,
    pub e_b: f64,
    /// Peak memory bytes of the most loaded member under 1F1B.
    pub peak_mem: u64,
    /// AllReduce time of this stage's trainable parameters.
    pub allreduce: f64,
}

/// A complete hybrid-parallel execution plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub stages: Vec<StagePlan>,
    /// Micro-batches per mini-batch (M).
    pub microbatches: usize,
    /// Micro-batch size (B).
    pub microbatch_size: usize,
    /// Eq. 5–6 phase latencies (beginning, execution, ending).
    pub phase_latency: (f64, f64, f64),
    /// Estimated per-mini-batch latency (Eq. 7 objective).
    pub minibatch_time: f64,
}

impl Plan {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total devices used by the plan.
    pub fn n_devices(&self) -> usize {
        self.stages.iter().map(|s| s.devices.len()).sum()
    }

    /// Samples processed per mini-batch.
    pub fn minibatch_samples(&self) -> usize {
        self.microbatches * self.microbatch_size
    }

    /// Estimated steady-state throughput in samples/s.
    pub fn throughput(&self) -> f64 {
        self.minibatch_samples() as f64 / self.minibatch_time
    }

    /// Peak per-device memory across the cluster (Fig. 13(b)/16(b)).
    pub fn peak_mem(&self) -> u64 {
        self.stages.iter().map(|s| s.peak_mem).max().unwrap_or(0)
    }

    /// Human-readable grouping, e.g. `"[2 dev x 7 blk | 2 dev x 7 blk]"`
    /// (the paper's Fig. 17 presentation).
    pub fn grouping(&self) -> String {
        let parts: Vec<String> = self
            .stages
            .iter()
            .map(|s| format!("{} dev x {} blk", s.devices.len(), s.range.1 - s.range.0))
            .collect();
        format!("[{}]", parts.join(" | "))
    }

    /// Invariant check: stages cover the whole graph contiguously, device
    /// groups are disjoint, dispatches sum to B.
    pub fn validate(&self, n_blocks: usize, n_devices: usize) -> Result<(), String> {
        let mut cur = 0;
        for s in &self.stages {
            if s.range.0 != cur {
                return Err(format!("gap before block {}", s.range.0));
            }
            if s.range.1 <= s.range.0 {
                return Err("empty stage".into());
            }
            cur = s.range.1;
            if s.devices.is_empty() {
                return Err("stage with no devices".into());
            }
            if s.dispatch.len() != s.devices.len() {
                return Err("dispatch length mismatch".into());
            }
            if s.dispatch.iter().sum::<usize>() != self.microbatch_size {
                return Err(format!(
                    "dispatch sums to {} != B={}",
                    s.dispatch.iter().sum::<usize>(),
                    self.microbatch_size
                ));
            }
        }
        if cur != n_blocks {
            return Err(format!("stages cover {cur}/{n_blocks} blocks"));
        }
        let mut ids: Vec<usize> = self
            .stages
            .iter()
            .flat_map(|s| s.devices.iter().map(|d| d.id))
            .collect();
        let total = ids.len();
        ids.sort();
        ids.dedup();
        if ids.len() != total {
            return Err("device used by two stages".into());
        }
        if total > n_devices {
            return Err("plan uses more devices than available".into());
        }
        Ok(())
    }
}
