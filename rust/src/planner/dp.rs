//! Dynamic-programming core of the planner (Eq. 3, Eq. 4, Eq. 5–7).
//!
//! Candidate stage counts σ are independent sub-problems: each gets its
//! own Eq. 3 table (1F1B in-flight depths depend on σ), all reading one
//! immutable [`SpanCosts`] profile view shared behind an `Arc`. The
//! search therefore fans σ candidates out over scoped worker threads
//! ([`PlannerOptions::search_threads`]); results are folded in ascending
//! σ order with strict `<` improvement, so the selected plan is
//! bit-identical to the serial search.

use std::sync::Arc;

use super::{Plan, StagePlan};
use crate::cluster::{Device, Env};
use crate::profiler::{Profile, SpanCosts};

const INF: f64 = f64::INFINITY;

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerOptions {
    /// Micro-batch size B.
    pub microbatch: usize,
    /// Micro-batches per mini-batch M.
    pub n_microbatches: usize,
    /// When false, ignore device heterogeneity: samples are dispatched
    /// evenly and every group member is priced at the slowest member's
    /// speed — the older "PAC" planner used as the Fig. 12 ablation
    /// ("PAC+ (Homo)").
    pub hetero_aware: bool,
    /// Cap on the stage count explored (defaults to min(L, |D|)).
    pub max_stages: Option<usize>,
    /// Force exactly this stage count (pure-PP baselines fix it to |D|).
    pub fixed_stages: Option<usize>,
    /// Cap on the data-parallel group size per stage (pure-PP uses 1).
    pub max_group: Option<usize>,
    /// Worker threads for the σ (stage-count) search: `None` = one per
    /// available core, `Some(1)` = serial, `Some(n)` = exactly `n`.
    /// The result is identical either way; only wall-clock changes.
    pub search_threads: Option<usize>,
}

impl Default for PlannerOptions {
    fn default() -> Self {
        PlannerOptions {
            microbatch: 4,
            n_microbatches: 4,
            hetero_aware: true,
            max_stages: None,
            fixed_stages: None,
            max_group: None,
            search_threads: None,
        }
    }
}

/// Planning failure modes.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PlanError {
    #[error("cluster memory cannot accommodate the model under any explored configuration")]
    InsufficientMemory,
    #[error("no devices in environment")]
    NoDevices,
}

/// Entry point: Algorithm 1. Returns the latency-optimal plan `W_σ`.
///
/// Candidate stage counts are evaluated on scoped worker threads (see
/// the module docs); pass `search_threads: Some(1)` to force the serial
/// search. The selected plan is identical either way.
pub fn plan(profile: &Profile, env: &Env, opts: &PlannerOptions) -> Result<Plan, PlanError> {
    if env.devices.is_empty() {
        return Err(PlanError::NoDevices);
    }
    let devices = env.devices_fastest_first();
    let l = profile.graph.len();
    let smax = opts
        .max_stages
        .unwrap_or(usize::MAX)
        .min(l)
        .min(devices.len());
    let (s_lo, s_hi) = match opts.fixed_stages {
        Some(s) => (s.min(smax), s.min(smax)),
        None => (1, smax),
    };

    let if_max = opts.n_microbatches.min(smax).max(1);
    let costs = Arc::new(profile.span_costs());
    let candidates: Vec<usize> = (s_lo..=s_hi).collect();
    let threads = opts
        .search_threads
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .min(candidates.len())
        .max(1);

    let results: Vec<Option<Plan>> = if threads <= 1 {
        // serial: one context, its span-time memo shared across σ
        let mut ctx = Ctx::new(profile, env, &devices, opts, Arc::clone(&costs), if_max);
        candidates.iter().map(|&s| ctx.plan_for_stage_count(s)).collect()
    } else {
        let devices_ref: &[Device] = &devices;
        let cands: &[usize] = &candidates;
        std::thread::scope(|sc| {
            let handles: Vec<_> = (0..threads)
                .map(|w| {
                    let costs = Arc::clone(&costs);
                    sc.spawn(move || {
                        let mut ctx =
                            Ctx::new(profile, env, devices_ref, opts, costs, if_max);
                        cands
                            .iter()
                            .enumerate()
                            .skip(w)
                            .step_by(threads)
                            .map(|(i, &s)| (i, ctx.plan_for_stage_count(s)))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            let mut slots: Vec<Option<Plan>> = vec![None; candidates.len()];
            for h in handles {
                for (i, p) in h.join().expect("planner search worker panicked") {
                    slots[i] = p;
                }
            }
            slots
        })
    };

    // fold in ascending σ with strict improvement — the serial tie-break
    let mut best: Option<Plan> = None;
    for p in results.into_iter().flatten() {
        let better = best
            .as_ref()
            .map(|b| p.minibatch_time < b.minibatch_time)
            .unwrap_or(true);
        if better {
            best = Some(p);
        }
    }
    best.ok_or(PlanError::InsufficientMemory)
}

struct Ctx<'a> {
    profile: &'a Profile,
    env: &'a Env,
    devices: &'a [Device],
    opts: &'a PlannerOptions,
    /// O(1) span-cost tables (EXPERIMENTS.md §Perf), shared read-only
    /// across search workers.
    costs: Arc<SpanCosts>,
    /// Dense T(x→y, group=[gs, ge), in_flight) time memo (NAN = unset).
    t_memo: Vec<f64>,
    l: usize,
    nd: usize,
    if_max: usize,
}

impl<'a> Ctx<'a> {
    fn new(
        profile: &'a Profile,
        env: &'a Env,
        devices: &'a [Device],
        opts: &'a PlannerOptions,
        costs: Arc<SpanCosts>,
        if_max: usize,
    ) -> Ctx<'a> {
        let l = profile.graph.len();
        let nd = devices.len();
        let memo_len = (l + 1) * (l + 1) * (nd + 1) * (nd + 1) * (if_max + 1);
        Ctx {
            profile,
            env,
            devices,
            opts,
            costs,
            // dense T(x->y, [gs,ge), in_flight) memo; NAN = not yet computed
            t_memo: vec![f64::NAN; memo_len],
            l,
            nd,
            if_max,
        }
    }

    #[inline]
    fn memo_idx(&self, x: usize, y: usize, gs: usize, ge: usize, inf: usize) -> usize {
        ((((x * (self.l + 1)) + y) * (self.nd + 1) + gs) * (self.nd + 1) + ge)
            * (self.if_max + 1)
            + inf.min(self.if_max)
    }

    /// Eq. 3 DP for one candidate total stage count; reconstructs the plan.
    fn plan_for_stage_count(&mut self, s_total: usize) -> Option<Plan> {
        let l = self.profile.graph.len();
        let nd = self.devices.len();
        let m_batches = self.opts.n_microbatches;

        // w[k][y][n] = slowest-stage time of the best k-stage sub-pipeline
        // covering blocks [0, y) with the first n devices; stage depth k
        // has 1F1B in-flight = min(M, s_total - k + 1).
        // parent[k][y][n] = (q, m): last stage covers [q, y) on devices
        // [n-m, n).
        let mut w = vec![vec![vec![INF; nd + 1]; l + 1]; s_total + 1];
        let mut parent = vec![vec![vec![(0usize, 0usize); nd + 1]; l + 1]; s_total + 1];

        let max_group = self.opts.max_group.unwrap_or(nd);
        for k in 1..=s_total {
            let in_flight = (s_total - k + 1).min(m_batches);
            for y in 1..=l {
                for n in 1..=nd {
                    if k == 1 {
                        // single stage covering [0, y) on all n devices
                        if n > max_group {
                            continue;
                        }
                        let t = self.stage_time(0, y, 0, n, in_flight);
                        w[1][y][n] = t;
                        parent[1][y][n] = (0, n);
                        continue;
                    }
                    // Eq. 3: split at q, give the last stage m devices.
                    let mut best = INF;
                    let mut arg = (0usize, 0usize);
                    for q in (k - 1)..y {
                        for m in 1..n.min(max_group.saturating_add(1)) {
                            let prefix = w[k - 1][q][n - m];
                            if prefix >= best {
                                continue;
                            }
                            let t = self.stage_time(q, y, n - m, n, in_flight);
                            let cand = prefix.max(t);
                            if cand < best {
                                best = cand;
                                arg = (q, m);
                            }
                        }
                    }
                    w[k][y][n] = best;
                    parent[k][y][n] = arg;
                }
            }
        }

        if !w[s_total][l][nd].is_finite() {
            return None;
        }

        // Reconstruct stages right-to-left.
        let mut ranges = Vec::new(); // (x, y, g_start, g_end)
        let (mut y, mut n) = (l, nd);
        for k in (1..=s_total).rev() {
            let (q, m) = parent[k][y][n];
            if k == 1 {
                ranges.push((0, y, 0, n));
            } else {
                ranges.push((q, y, n - m, n));
                y = q;
                n -= m;
            }
        }
        ranges.reverse();

        self.finalize(ranges, s_total)
    }

    /// Eq. 4 wrapper: best max-member FP+BP time of a stage [x, y) run by
    /// devices [gs, ge) of the fastest-first order, with `in_flight`
    /// resident micro-batches for the OOM check. Time only — the DP inner
    /// loops never materialize dispatch vectors; `dispatch_of` recomputes
    /// them for the handful of stages in the final plan.
    fn stage_time(&mut self, x: usize, y: usize, gs: usize, ge: usize, in_flight: usize) -> f64 {
        let idx = self.memo_idx(x, y, gs, ge, in_flight);
        let cached = self.t_memo[idx];
        if !cached.is_nan() {
            return cached;
        }
        let t = self.dispatch_of(x, y, gs, ge, in_flight).0;
        self.t_memo[idx] = t;
        t
    }

    /// Full Eq. 4 solve returning (time, dispatch).
    fn dispatch_of(
        &self,
        x: usize,
        y: usize,
        gs: usize,
        ge: usize,
        in_flight: usize,
    ) -> (f64, Vec<usize>) {
        if self.opts.hetero_aware {
            self.dispatch_dp(x, y, gs, ge, in_flight)
        } else {
            self.dispatch_even(x, y, gs, ge, in_flight)
        }
    }

    /// Eq. 4: H_{x→y}(b, G_n) sample-dispatch DP over the group.
    fn dispatch_dp(
        &self,
        x: usize,
        y: usize,
        gs: usize,
        ge: usize,
        in_flight: usize,
    ) -> (f64, Vec<usize>) {
        let b = self.opts.microbatch;
        let group = &self.devices[gs..ge];
        let n = group.len();

        // member_time[j][i] = FP+BP time of member j processing i samples
        // (INF if it would OOM at this in-flight depth).
        let member_time: Vec<Vec<f64>> = group
            .iter()
            .map(|d| {
                (0..=b)
                    .map(|i| {
                        if i == 0 {
                            return 0.0;
                        }
                        let mem = self.costs.span_mem(x, y, i, in_flight);
                        if mem > d.mem_budget() {
                            INF
                        } else {
                            self.costs.span_time(d, x, y, i)
                        }
                    })
                    .collect()
            })
            .collect();

        // h[j][i] = best max-time dispatching i samples to the first j members.
        let mut h = vec![vec![INF; b + 1]; n + 1];
        let mut choice = vec![vec![0usize; b + 1]; n + 1];
        h[0][0] = 0.0;
        for j in 1..=n {
            for i in 0..=b {
                for give in 0..=i {
                    let prev = h[j - 1][i - give];
                    if !prev.is_finite() {
                        continue;
                    }
                    let t = member_time[j - 1][give];
                    let cand = prev.max(t);
                    if cand < h[j][i] {
                        h[j][i] = cand;
                        choice[j][i] = give;
                    }
                }
            }
        }
        if !h[n][b].is_finite() {
            return (INF, vec![0; n]);
        }
        let mut dispatch = vec![0usize; n];
        let mut rem = b;
        for j in (1..=n).rev() {
            dispatch[j - 1] = choice[j][rem];
            rem -= dispatch[j - 1];
        }
        (h[n][b], dispatch)
    }

    /// Heterogeneity-blind dispatch (the PAC ablation): equal shares,
    /// priced at the slowest member.
    fn dispatch_even(
        &self,
        x: usize,
        y: usize,
        gs: usize,
        ge: usize,
        in_flight: usize,
    ) -> (f64, Vec<usize>) {
        let b = self.opts.microbatch;
        let group = &self.devices[gs..ge];
        let n = group.len();
        let mut dispatch = vec![b / n; n];
        for d in dispatch.iter_mut().take(b % n) {
            *d += 1;
        }
        let mut worst: f64 = 0.0;
        for (d, &share) in group.iter().zip(&dispatch) {
            if share == 0 {
                continue;
            }
            let mem = self.costs.span_mem(x, y, share, in_flight);
            if mem > d.mem_budget() {
                return (INF, dispatch);
            }
            worst = worst.max(self.costs.span_time(d, x, y, share));
        }
        (worst, dispatch)
    }

    /// Eq. 5–7: assemble the plan, compute phase latencies.
    fn finalize(
        &mut self,
        ranges: Vec<(usize, usize, usize, usize)>,
        s_total: usize,
    ) -> Option<Plan> {
        let m_batches = self.opts.n_microbatches;
        let net = &self.env.network;
        let mut stages = Vec::with_capacity(ranges.len());

        for (idx, &(x, y, gs, ge)) in ranges.iter().enumerate() {
            let in_flight = (s_total - idx).min(m_batches);
            let (_, dispatch) = self.dispatch_of(x, y, gs, ge, in_flight);
            let group = &self.devices[gs..ge];
            let mut e_f: f64 = 0.0;
            let mut e_b: f64 = 0.0;
            let mut peak_mem: u64 = 0;
            for (d, &share) in group.iter().zip(&dispatch) {
                if share == 0 {
                    continue;
                }
                let tf = self.costs.t_f(d, x, y, share);
                let tb = self.costs.t_b(d, x, y, share);
                e_f = e_f.max(tf);
                e_b = e_b.max(tb);
                peak_mem = peak_mem.max(self.costs.span_mem(x, y, share, in_flight));
            }
            let allreduce =
                net.allreduce_time(self.profile.allreduce_bytes(x, y), group.len());
            stages.push(StagePlan {
                range: (x, y),
                devices: group.to_vec(),
                dispatch,
                e_f,
                e_b,
                peak_mem,
                allreduce,
            });
        }

        // Communication between consecutive stages.
        let b = self.opts.microbatch;
        let c_f: Vec<f64> = (0..stages.len().saturating_sub(1))
            .map(|_| net.transfer_time(self.profile.boundary_bytes_fwd(b)))
            .collect();
        let c_b: Vec<f64> = c_f
            .iter()
            .map(|_| net.transfer_time(self.profile.boundary_bytes_bwd(b)))
            .collect();

        // Eq. 5: beginning phase — the first micro-batch filling the pipe.
        let s = stages.len();
        let l_b: f64 = (0..s - 1).map(|i| stages[i].e_f + c_f[i]).sum();
        // Eq. 5: execution phase — the last stage's M (fwd+bwd) slots.
        let l_e = m_batches as f64 * (stages[s - 1].e_f + stages[s - 1].e_b);
        // Eq. 6: ending phase — drain + AllReduce overlap.
        let l_n = (0..s)
            .map(|i| {
                stages[i].allreduce
                    + (i..s - 1).map(|j| stages[j].e_b + c_b[j]).sum::<f64>()
            })
            .fold(0.0f64, f64::max);

        let total = l_b + l_e + l_n;
        if !total.is_finite() {
            return None;
        }
        Some(Plan {
            stages,
            microbatches: m_batches,
            microbatch_size: b,
            phase_latency: (l_b, l_e, l_n),
            minibatch_time: total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceKind;
    use crate::model::graph::LayerGraph;
    use crate::model::{Method, ModelSpec, Precision};

    fn profile(spec: ModelSpec, method: Method) -> Profile {
        Profile::new(LayerGraph::new(spec), method, Precision::FP32, 128)
    }

    fn opts(b: usize, m: usize) -> PlannerOptions {
        PlannerOptions { microbatch: b, n_microbatches: m, ..Default::default() }
    }

    #[test]
    fn plan_valid_on_env_a() {
        let p = profile(ModelSpec::t5_base(), Method::pa(false));
        let env = Env::env_a();
        let plan = plan(&p, &env, &opts(4, 4)).unwrap();
        plan.validate(p.graph.len(), env.n()).unwrap();
        assert!(plan.minibatch_time > 0.0);
    }

    #[test]
    fn plan_valid_on_hetero_env_b() {
        let p = profile(ModelSpec::t5_base(), Method::pa(false));
        let env = Env::env_b();
        let plan = plan(&p, &env, &opts(4, 4)).unwrap();
        plan.validate(p.graph.len(), env.n()).unwrap();
        // heterogeneity-aware dispatch gives the TX2s more samples than
        // the Nanos whenever they share a group
        for s in &plan.stages {
            for (a, b_) in s.devices.iter().zip(s.devices.iter().skip(1)) {
                let ia = s.dispatch[s.devices.iter().position(|d| d.id == a.id).unwrap()];
                let ib = s.dispatch[s.devices.iter().position(|d| d.id == b_.id).unwrap()];
                if a.kind.effective_flops() > b_.kind.effective_flops() {
                    assert!(ia >= ib, "faster device got fewer samples");
                }
            }
        }
    }

    #[test]
    fn hetero_beats_homo_on_env_b() {
        let p = profile(ModelSpec::t5_base(), Method::pa(false));
        let env = Env::env_b();
        let hetero = plan(&p, &env, &opts(8, 4)).unwrap();
        let homo = plan(
            &p,
            &env,
            &PlannerOptions { hetero_aware: false, ..opts(8, 4) },
        )
        .unwrap();
        assert!(
            hetero.minibatch_time <= homo.minibatch_time * 1.001,
            "hetero {} vs homo {}",
            hetero.minibatch_time,
            homo.minibatch_time
        );
    }

    #[test]
    fn t5_large_full_ft_ooms_on_nanos() {
        // Table V: Full+DP/Standalone OOM on 4GB Nanos for T5-Large; even
        // the hybrid planner cannot fit full-FT T5-Large on 4 Nanos.
        let p = profile(ModelSpec::t5_large(), Method::FullFT);
        let env = Env::env_a();
        let r = plan(&p, &env, &opts(16, 4));
        assert_eq!(r.unwrap_err(), PlanError::InsufficientMemory);
    }

    #[test]
    fn t5_large_pa_fits_on_nanos() {
        let p = profile(ModelSpec::t5_large(), Method::pa(false));
        let env = Env::env_a();
        let plan = plan(&p, &env, &opts(4, 4)).unwrap();
        plan.validate(p.graph.len(), env.n()).unwrap();
        assert!(plan.n_stages() >= 2, "T5-Large needs pipelining on Nanos");
    }

    #[test]
    fn more_devices_never_slower() {
        let p = profile(ModelSpec::t5_base(), Method::pa(false));
        let t4 = plan(&p, &Env::nanos(4), &opts(4, 4)).unwrap().minibatch_time;
        let t8 = plan(&p, &Env::nanos(8), &opts(4, 4)).unwrap().minibatch_time;
        assert!(t8 <= t4 * 1.05, "8 devices ({t8}) slower than 4 ({t4})");
    }

    #[test]
    fn single_device_is_one_stage() {
        let p = profile(ModelSpec::tiny(), Method::pa(false));
        let env = Env::standalone(DeviceKind::Tx2H);
        let plan = plan(&p, &env, &opts(2, 2)).unwrap();
        assert_eq!(plan.n_stages(), 1);
        assert_eq!(plan.stages[0].devices.len(), 1);
    }

    /// Golden: the threaded σ-search must select a plan bit-identical to
    /// the serial search on the paper's default environments.
    #[test]
    fn threaded_search_matches_serial_bitwise() {
        for env in [Env::env_a(), Env::env_b(), Env::nanos(6)] {
            for method in [Method::pa(false), Method::FullFT] {
                let p = profile(ModelSpec::t5_base(), method);
                let serial = plan(
                    &p,
                    &env,
                    &PlannerOptions { search_threads: Some(1), ..opts(4, 4) },
                );
                let threaded = plan(
                    &p,
                    &env,
                    &PlannerOptions { search_threads: Some(4), ..opts(4, 4) },
                );
                let (Ok(serial), Ok(threaded)) = (serial, threaded) else {
                    panic!("planning failed on {}", env.name);
                };
                assert_eq!(
                    serial.minibatch_time.to_bits(),
                    threaded.minibatch_time.to_bits(),
                    "{}: {} vs {}",
                    env.name,
                    serial.minibatch_time,
                    threaded.minibatch_time
                );
                assert_eq!(serial.grouping(), threaded.grouping(), "{}", env.name);
                assert_eq!(serial.n_stages(), threaded.n_stages());
                for (a, b) in serial.stages.iter().zip(&threaded.stages) {
                    assert_eq!(a.range, b.range);
                    assert_eq!(a.dispatch, b.dispatch);
                    assert_eq!(a.e_f.to_bits(), b.e_f.to_bits());
                    assert_eq!(a.e_b.to_bits(), b.e_b.to_bits());
                    assert_eq!(a.allreduce.to_bits(), b.allreduce.to_bits());
                    assert_eq!(a.peak_mem, b.peak_mem);
                    assert_eq!(
                        a.devices.iter().map(|d| d.id).collect::<Vec<_>>(),
                        b.devices.iter().map(|d| d.id).collect::<Vec<_>>()
                    );
                }
            }
        }
    }

    #[test]
    fn no_devices_errors() {
        let p = profile(ModelSpec::tiny(), Method::pa(false));
        let env = Env { name: "empty".into(), devices: vec![], network: crate::cluster::Network::lan_1gbps() };
        assert_eq!(plan(&p, &env, &opts(2, 2)).unwrap_err(), PlanError::NoDevices);
    }

    #[test]
    fn planner_invariants_property() {
        use crate::util::prop::{check, forall};
        forall(
            13,
            12,
            |g| {
                let n_dev = g.int(1, 6) + 1;
                let b = g.int(1, 8) + 1;
                let m = g.int(1, 4) + 1;
                (n_dev, b, m)
            },
            |&(n_dev, b, m)| {
                let p = profile(ModelSpec::t5_base(), Method::pa(false));
                let env = Env::nanos(n_dev);
                match plan(&p, &env, &opts(b, m)) {
                    Ok(pl) => {
                        pl.validate(p.graph.len(), env.n()).map_err(|e| e)?;
                        check(pl.minibatch_time.is_finite(), "infinite time")?;
                        // no stage may exceed its members' memory budgets
                        for s in &pl.stages {
                            for d in &s.devices {
                                check(
                                    s.peak_mem <= d.mem_budget(),
                                    format!("stage peak {} over budget", s.peak_mem),
                                )?;
                            }
                        }
                        Ok(())
                    }
                    Err(_) => Ok(()), // OOM is legal for adversarial configs
                }
            },
        );
    }
}
