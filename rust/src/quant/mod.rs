//! Block-wise absmax quantization (paper §IV-D, Eq. 1–2) — the Rust twin
//! of `python/compile/quantize.py`, byte-compatible with the AOT parameter
//! dumps (`params_backbone_int8.bin` etc.).
//!
//! Layout: a `[K, N]` f32 weight becomes `w_q: i8 [K, N]` (values in
//! `[-qmax, qmax]`) plus `scales: f32 [ceil(K/B), N]` — one absmax per
//! (64-row block, column). INT4 values occupy one i8 each on the compute
//! path; [`pack_int4`]/[`unpack_int4`] provide the 2-per-byte storage form.

/// Default quantization block (rows per scale).
pub const BLOCK: usize = 64;

/// Integer range limit per format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bits {
    Int8,
    Int4,
}

impl Bits {
    pub fn qmax(self) -> f32 {
        match self {
            Bits::Int8 => 127.0,
            Bits::Int4 => 7.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Bits::Int8 => "int8",
            Bits::Int4 => "int4",
        }
    }
}

/// A block-wise-quantized 2-D tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub k: usize,
    pub n: usize,
    pub block: usize,
    pub bits: Bits,
    /// Row-major [k, n] quantized values.
    pub values: Vec<i8>,
    /// Row-major [ceil(k/block), n] per-block absmax scales.
    pub scales: Vec<f32>,
}

impl QTensor {
    pub fn nblocks(&self) -> usize {
        self.k.div_ceil(self.block)
    }

    /// Storage bytes in packed form (INT4 packs 2 values/byte).
    pub fn storage_bytes(&self) -> usize {
        let vals = match self.bits {
            Bits::Int8 => self.k * self.n,
            Bits::Int4 => (self.k * self.n).div_ceil(2),
        };
        vals + self.nblocks() * self.n * 4
    }
}

/// Quantize a row-major `[k, n]` f32 matrix (Eq. 1).
///
/// Perf notes (EXPERIMENTS.md §Perf): absmax accumulates row-major
/// (streaming reads, no stride-n hops) and the per-element division is
/// hoisted into a per-(block, column) reciprocal.
pub fn quantize(w: &[f32], k: usize, n: usize, bits: Bits, block: usize) -> QTensor {
    assert_eq!(w.len(), k * n, "shape mismatch");
    assert!(block > 0);
    let qmax = bits.qmax();
    let nblocks = k.div_ceil(block);

    // pass 1: per-(block, column) absmax, accumulated row-major
    let mut scales = vec![0.0f32; nblocks * n];
    for r in 0..k {
        let b = r / block;
        let row = &w[r * n..(r + 1) * n];
        let srow = &mut scales[b * n..(b + 1) * n];
        for (s, &v) in srow.iter_mut().zip(row) {
            *s = s.max(v.abs());
        }
    }
    // zero blocks get scale 1.0; precompute qmax / scale
    let mut inv = vec![0.0f32; nblocks * n];
    for (s, iv) in scales.iter_mut().zip(inv.iter_mut()) {
        if *s == 0.0 {
            *s = 1.0;
        }
        *iv = qmax / *s;
    }

    // pass 2: quantize, row-major with the per-block reciprocal row
    let mut values = vec![0i8; k * n];
    for r in 0..k {
        let b = r / block;
        let row = &w[r * n..(r + 1) * n];
        let irow = &inv[b * n..(b + 1) * n];
        let vrow = &mut values[r * n..(r + 1) * n];
        for ((v, &x), &iv) in vrow.iter_mut().zip(row).zip(irow) {
            *v = (x * iv).round().clamp(-qmax, qmax) as i8;
        }
    }
    QTensor { k, n, block, bits, values, scales }
}

/// Dequantize back to f32 (Eq. 2).
pub fn dequantize(q: &QTensor) -> Vec<f32> {
    let qmax = q.bits.qmax();
    let mut out = vec![0.0f32; q.k * q.n];
    for r in 0..q.k {
        let b = r / q.block;
        for c in 0..q.n {
            out[r * q.n + c] =
                q.values[r * q.n + c] as f32 * (q.scales[b * q.n + c] / qmax);
        }
    }
    out
}

/// Max |w - dequant(quant(w))| over the matrix.
pub fn roundtrip_error(w: &[f32], k: usize, n: usize, bits: Bits, block: usize) -> f32 {
    let q = quantize(w, k, n, bits, block);
    let w2 = dequantize(&q);
    w.iter().zip(&w2).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
}

/// Pack INT4 values (each in [-7, 7]) two per byte: low nibble first.
pub fn pack_int4(values: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len().div_ceil(2));
    for pair in values.chunks(2) {
        let lo = (pair[0] as u8) & 0x0F;
        let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0F } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Inverse of [`pack_int4`]; `len` is the original value count.
pub fn unpack_int4(packed: &[u8], len: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(len);
    for (i, &b) in packed.iter().enumerate() {
        let lo = ((b & 0x0F) as i8) << 4 >> 4; // sign-extend nibble
        out.push(lo);
        if out.len() == len {
            break;
        }
        if 2 * i + 1 < len {
            let hi = ((b >> 4) as i8) << 4 >> 4;
            out.push(hi);
        }
    }
    out.truncate(len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall};
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn roundtrip_bound_property() {
        // |err| <= scale / (2*qmax) per entry, for random shapes/content
        forall(
            7,
            60,
            |g| {
                let k = g.int(1, 100);
                let n = g.int(1, 12);
                let w = g.vec_f32(k * n);
                let bits = if g.bool() { Bits::Int8 } else { Bits::Int4 };
                (k, n, w, bits)
            },
            |(k, n, w, bits)| {
                let q = quantize(w, *k, *n, *bits, 16);
                let w2 = dequantize(&q);
                for r in 0..*k {
                    for c in 0..*n {
                        let s = q.scales[(r / 16) * *n + c];
                        let bound = s / (2.0 * bits.qmax()) + 1e-6;
                        let err = (w[r * *n + c] - w2[r * *n + c]).abs();
                        check(err <= bound, format!("err {err} > bound {bound}"))?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scales_are_absmax() {
        let mut rng = Rng::new(2);
        let w = randn(&mut rng, 128 * 4);
        let q = quantize(&w, 128, 4, Bits::Int8, 64);
        for b in 0..2 {
            for c in 0..4 {
                let want = (b * 64..(b + 1) * 64)
                    .map(|r| w[r * 4 + c].abs())
                    .fold(0.0f32, f32::max);
                assert!((q.scales[b * 4 + c] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn matches_python_semantics() {
        // mirror of python test: zeros quantize to zeros with scale 1
        let q = quantize(&vec![0.0; 64 * 3], 64, 3, Bits::Int8, 64);
        assert!(q.values.iter().all(|&v| v == 0));
        assert!(q.scales.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn outlier_contained_to_block() {
        let mut rng = Rng::new(3);
        let mut w = randn(&mut rng, 128 * 2);
        for v in w.iter_mut() {
            *v *= 0.1;
        }
        w[0] = 50.0; // block 0 outlier
        let q = quantize(&w, 128, 2, Bits::Int8, 64);
        let w2 = dequantize(&q);
        let max_err_block1: f32 = (64..128)
            .flat_map(|r| (0..2).map(move |c| r * 2 + c))
            .map(|i| (w[i] - w2[i]).abs())
            .fold(0.0, f32::max);
        assert!(max_err_block1 < 0.01, "outlier leaked: {max_err_block1}");
    }

    #[test]
    fn int4_pack_roundtrip_property() {
        forall(
            11,
            80,
            |g| {
                let n = g.int(0, 50);
                (0..n).map(|_| (g.int(0, 15) as i8) - 7).collect::<Vec<i8>>()
            },
            |vals| {
                let packed = pack_int4(vals);
                let un = unpack_int4(&packed, vals.len());
                check(&un == vals, format!("{un:?} != {vals:?}"))
            },
        );
    }

    #[test]
    fn storage_bytes_counts_packing() {
        let w = vec![1.0f32; 128 * 8];
        let q8 = quantize(&w, 128, 8, Bits::Int8, 64);
        let q4 = quantize(&w, 128, 8, Bits::Int4, 64);
        assert_eq!(q8.storage_bytes(), 128 * 8 + 2 * 8 * 4);
        assert_eq!(q4.storage_bytes(), 128 * 8 / 2 + 2 * 8 * 4);
    }

    #[test]
    fn int8_more_accurate_than_int4() {
        let mut rng = Rng::new(5);
        let w = randn(&mut rng, 256 * 8);
        let e8 = roundtrip_error(&w, 256, 8, Bits::Int8, 64);
        let e4 = roundtrip_error(&w, 256, 8, Bits::Int4, 64);
        assert!(e8 < e4);
    }
}
