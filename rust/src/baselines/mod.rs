//! The collaborative edge training systems PAC+ is compared against
//! (paper §VI-A "Baseline Methods" and §VI-C):
//!
//! * **Standalone** — one edge device hosting the whole model.
//! * **DP (EDDL [38])** — classic data parallelism: every device holds a
//!   full replica; the mini-batch is split across devices; gradients are
//!   AllReduced. Mini-batch granularity (no micro-batching).
//! * **PP (Eco-FL [39])** — pure pipeline parallelism: |D| stages, one
//!   device each, 4 micro-batches per mini-batch.
//! * **PAC+** — the paper's hybrid planner (this repo's `planner`).
//! * **PAC (Homo)** — PAC+ without heterogeneity awareness (ablation).
//! * **Asteroid [48]** — hybrid pipeline parallelism like PAC+, but
//!   designed for full-parameter fine-tuning (no PEFT co-design, no
//!   activation cache).
//! * **HetPipe [49]** — virtual workers (intra-worker PP) + asynchronous
//!   inter-worker DP through a parameter server; the async PS traffic of
//!   full-model gradients is its bottleneck on a LAN.
//!
//! All systems share the same profile/cost substrate and the same 1F1B
//! event simulator, so differences come purely from architecture.

use crate::cluster::{DeviceKind, Env};
#[cfg(test)]
use crate::model::{Method, Precision};
use crate::planner::{PlanError, PlannerOptions};
use crate::profiler::Profile;
use crate::sched::training::{self, RunReport};

/// A collaborative training system under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    Standalone,
    DataParallel,
    PipelineParallel,
    PacPlus,
    PacHomo,
    Asteroid,
    HetPipe,
}

impl System {
    pub fn name(self) -> &'static str {
        match self {
            System::Standalone => "Standalone",
            System::DataParallel => "DP (EDDL)",
            System::PipelineParallel => "PP (Eco-FL)",
            System::PacPlus => "PAC+",
            System::PacHomo => "PAC+ (Homo)",
            System::Asteroid => "Asteroid",
            System::HetPipe => "HetPipe",
        }
    }
}

/// Shared experiment shape: GLUE-style task on an edge cluster.
#[derive(Debug, Clone, Copy)]
pub struct TrainJob {
    pub samples: usize,
    pub epochs: usize,
    pub seq: usize,
    pub minibatch: usize,
}

impl TrainJob {
    pub fn new(samples: usize, epochs: usize, seq: usize, minibatch: usize) -> TrainJob {
        TrainJob { samples, epochs, seq, minibatch }
    }
}

/// Run (simulate) `system` fine-tuning `spec`+`method` on `env`.
/// Returns the total wall-clock in seconds, or the OOM error.
pub fn run_system(
    system: System,
    profile: &Profile,
    env: &Env,
    job: TrainJob,
) -> Result<RunReport, PlanError> {
    match system {
        System::Standalone => replicated_dp(profile, env, job, 1),
        // EDDL: every device hosts the full model and processes whole
        // mini-batches ("fine-tuned strictly at the mini-batch
        // granularity", §VI-B) — throughput scales with devices, memory
        // per device does not.
        System::DataParallel => replicated_dp(profile, env, job, env.n()),
        System::PipelineParallel => pure_pp(profile, env, job),
        System::PacPlus | System::PacHomo | System::Asteroid => {
            let m = 4;
            let opts = PlannerOptions {
                microbatch: (job.minibatch / m).max(1),
                n_microbatches: m,
                hetero_aware: system != System::PacHomo,
                ..Default::default()
            };
            training::finetune(profile, env, &opts, job.samples, job.epochs)
        }
        System::HetPipe => hetpipe(profile, env, job),
    }
}

/// Eco-FL-style pure pipeline parallelism: the block chain is split into
/// |D| **even** contiguous stages (Eco-FL balances layer counts, not
/// profiled times), one device per stage, 4 micro-batches per mini-batch,
/// 1F1B scheduling. OOM if any stage exceeds its device's budget at its
/// 1F1B in-flight depth.
fn pure_pp(profile: &Profile, env: &Env, job: TrainJob) -> Result<RunReport, PlanError> {
    use crate::planner::{Plan, StagePlan};
    let l = profile.graph.len();
    let n = env.n().min(l);
    let m = 4usize; // micro-batches (paper §VI-B)
    let beta = (job.minibatch / m).max(1);

    // even split: base blocks per stage, remainder spread from the front
    let base = l / n;
    let rem = l % n;
    let mut stages = Vec::with_capacity(n);
    let mut cur = 0usize;
    for (i, d) in env.devices.iter().take(n).enumerate() {
        let k = base + usize::from(i < rem);
        let (x, y) = (cur, cur + k);
        cur = y;
        let in_flight = (n - i).min(m);
        let mem = profile.span_mem_bytes(x, y, beta, in_flight);
        if mem > d.mem_budget() {
            return Err(PlanError::InsufficientMemory);
        }
        let e_f: f64 = (x..y).map(|b| profile.t_f(d, b, beta)).sum();
        let e_b: f64 = (x..y).map(|b| profile.t_b(d, b, beta)).sum();
        let allreduce = 0.0; // single device per stage: nothing to reduce
        stages.push(StagePlan {
            range: (x, y),
            devices: vec![d.clone()],
            dispatch: vec![beta],
            e_f,
            e_b,
            peak_mem: mem,
            allreduce,
        });
    }
    let plan = Plan {
        stages,
        microbatches: m,
        microbatch_size: beta,
        phase_latency: (0.0, 0.0, 0.0),
        minibatch_time: 0.0,
    };
    let per_mb = crate::sched::simulate_minibatch(&plan, profile, &env.network).minibatch_time;
    let minibatches = job.samples.div_ceil(m * beta);
    let epoch1 = per_mb * minibatches as f64;

    let (redistribution, epoch_cached) =
        if profile.method.skips_backbone_with_cache() && job.epochs > 1 {
            (
                training::redistribution_time(profile, env, job.samples),
                training::epoch_time_cached(profile, env, job.samples, m * beta),
            )
        } else {
            (0.0, epoch1)
        };
    let mut plan = plan;
    plan.minibatch_time = per_mb;
    Ok(RunReport {
        plan,
        epoch1,
        redistribution,
        epoch_cached,
        epochs: job.epochs,
        total: epoch1 + redistribution + epoch_cached * (job.epochs - 1) as f64,
    })
}

/// Standalone / EDDL-DP execution model: the first `n` devices each host
/// the **entire** model and process whole mini-batches independently;
/// adapter/trainable gradients are AllReduced after every round. A plan
/// with one single-device stage per replica is synthesized for reporting.
fn replicated_dp(
    profile: &Profile,
    env: &Env,
    job: TrainJob,
    n: usize,
) -> Result<RunReport, PlanError> {
    use crate::planner::{Plan, StagePlan};
    let l = profile.graph.len();
    let devices: Vec<_> = env.devices.iter().take(n).cloned().collect();
    // OOM check: every replica hosts all blocks with a full mini-batch.
    let mem = profile.span_mem_bytes(0, l, job.minibatch, 1);
    for d in &devices {
        if mem > d.mem_budget() {
            return Err(PlanError::InsufficientMemory);
        }
    }
    // per-replica mini-batch compute time; the round is paced by the
    // slowest replica (synchronous DP).
    let slowest = devices
        .iter()
        .map(|d| profile.span_time(d, 0, l, job.minibatch))
        .fold(0.0f64, f64::max);
    let trainable = profile.graph.span_trainable_bytes(0, l, profile.method);
    let allreduce = env.network.allreduce_time(trainable, n);
    let rounds =
        (job.samples as f64 / (n * job.minibatch) as f64).ceil();
    let epoch1 = rounds * (slowest + allreduce);

    let (redistribution, epoch_cached) = if profile.method.skips_backbone_with_cache()
        && job.epochs > 1
    {
        let redis = training::redistribution_time(profile, env, job.samples);
        let cached = training::epoch_time_cached(profile, env, job.samples, job.minibatch);
        (redis, cached)
    } else {
        (0.0, epoch1)
    };

    let stages = devices
        .iter()
        .map(|d| StagePlan {
            range: (0, l),
            devices: vec![d.clone()],
            dispatch: vec![job.minibatch],
            e_f: slowest,
            e_b: slowest,
            peak_mem: mem,
            allreduce,
        })
        .take(1)
        .collect();
    Ok(RunReport {
        plan: Plan {
            stages,
            microbatches: 1,
            microbatch_size: job.minibatch,
            phase_latency: (0.0, slowest, allreduce),
            minibatch_time: slowest + allreduce,
        },
        epoch1,
        redistribution,
        epoch_cached,
        epochs: job.epochs,
        total: epoch1 + redistribution + epoch_cached * (job.epochs - 1) as f64,
    })
}

/// HetPipe model: devices are grouped by kind into virtual workers; each
/// worker runs pure PP internally; workers train asynchronously against a
/// parameter server that serializes full trainable-gradient push/pull on
/// the LAN. Wave-based staleness costs a utilization factor.
fn hetpipe(profile: &Profile, env: &Env, job: TrainJob) -> Result<RunReport, PlanError> {
    const STALENESS_UTILIZATION: f64 = 0.85;

    // virtual workers: group devices of the same kind (max 4 per worker)
    let mut groups: Vec<Vec<crate::cluster::Device>> = Vec::new();
    for kind in [DeviceKind::Tx2H, DeviceKind::Tx2L, DeviceKind::NanoH, DeviceKind::NanoL] {
        let ds: Vec<_> = env.devices.iter().filter(|d| d.kind == kind).cloned().collect();
        for chunk in ds.chunks(4) {
            if !chunk.is_empty() {
                groups.push(chunk.to_vec());
            }
        }
    }

    let mut agg_throughput = 0.0; // samples/s across workers
    let mut any_plan: Option<RunReport> = None;
    for g in &groups {
        let sub = Env {
            name: format!("hetpipe-worker-{}", g[0].kind.name()),
            devices: g.iter().cloned().enumerate().map(|(i, mut d)| {
                d.id = i;
                d
            }).collect(),
            network: env.network,
        };
        let m = 4;
        let opts = PlannerOptions {
            microbatch: (job.minibatch / m).max(1),
            n_microbatches: m,
            fixed_stages: Some(sub.n()),
            max_group: Some(1),
            ..Default::default()
        };
        match training::finetune(profile, &sub, &opts, job.samples, 1) {
            Ok(r) => {
                let mb_samples = r.plan.minibatch_samples() as f64;
                let mb_time = r.epoch1 / (job.samples as f64 / mb_samples).ceil();
                agg_throughput += mb_samples / mb_time;
                if any_plan.is_none() {
                    any_plan = Some(r);
                }
            }
            Err(_) => continue, // this worker cannot host the model
        }
    }
    let template = any_plan.ok_or(PlanError::InsufficientMemory)?;

    // parameter-server traffic: push grads + pull params per worker
    // mini-batch. HetPipe shards the PS across the cluster, so each
    // link carries 2 x trainable / n bytes per sync.
    let trainable_bytes = profile.method.trainable_params(&profile.graph.spec) * 4;
    let minibatches_per_epoch = (job.samples as f64 / job.minibatch as f64).ceil();
    let ps_epoch = minibatches_per_epoch * groups.len() as f64
        * (2.0 * trainable_bytes as f64 / env.n().max(1) as f64 / env.network.bandwidth);

    let compute_epoch = job.samples as f64 / (agg_throughput * STALENESS_UTILIZATION);
    let epoch = compute_epoch.max(ps_epoch);
    Ok(RunReport {
        plan: template.plan,
        epoch1: epoch,
        redistribution: 0.0,
        epoch_cached: epoch,
        epochs: job.epochs,
        total: epoch * job.epochs as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::LayerGraph;
    use crate::model::ModelSpec;

    fn profile(spec: ModelSpec, method: Method, seq: usize) -> Profile {
        Profile::new(LayerGraph::new(spec), method, Precision::FP32, seq)
    }

    fn job() -> TrainJob {
        TrainJob::new(1000, 1, 128, 16)
    }

    /// Table V column shapes on Env.A / T5-Base.
    #[test]
    fn table5_t5base_ordering() {
        let env = Env::env_a();
        let full = profile(ModelSpec::t5_base(), Method::FullFT, 128);
        let ad = profile(ModelSpec::t5_base(), Method::adapters_default(), 128);
        let pa = profile(ModelSpec::t5_base(), Method::pa(false), 128);

        // Full + Standalone/DP OOM on a 4GB Nano (Table V row 1)
        assert!(run_system(System::Standalone, &full, &env, job()).is_err());
        assert!(run_system(System::DataParallel, &full, &env, job()).is_err());
        // Full + PP fits
        let full_pp = run_system(System::PipelineParallel, &full, &env, job()).unwrap();
        // Adapters fit standalone and on DP
        let ad_solo = run_system(System::Standalone, &ad, &env, job()).unwrap();
        let ad_dp = run_system(System::DataParallel, &ad, &env, job()).unwrap();
        let ad_pp = run_system(System::PipelineParallel, &ad, &env, job()).unwrap();
        // PAC+ (parallel adapters, hybrid) beats every baseline
        let pac = run_system(System::PacPlus, &pa, &env, job()).unwrap();
        for (name, t) in [
            ("full_pp", full_pp.total),
            ("ad_solo", ad_solo.total),
            ("ad_dp", ad_dp.total),
            ("ad_pp", ad_pp.total),
        ] {
            assert!(pac.total < t, "PAC+ {} !< {name} {t}", pac.total);
        }
        // distributing helps: DP/PP beat standalone
        assert!(ad_dp.total < ad_solo.total);
        assert!(ad_pp.total < ad_solo.total);
    }

    /// Table V: BART-Large OOMs standalone/DP even with Adapters (4GB).
    #[test]
    fn table5_bart_ooms() {
        let env = Env::env_a();
        let ad = profile(ModelSpec::bart_large(), Method::adapters_default(), 128);
        assert!(run_system(System::Standalone, &ad, &env, job()).is_err());
        assert!(run_system(System::DataParallel, &ad, &env, job()).is_err());
        assert!(run_system(System::PipelineParallel, &ad, &env, job()).is_ok());
    }

    /// Fig. 12 shape on Env.B: PAC+ > Asteroid > HetPipe; homo ablation
    /// loses to heterogeneity-aware PAC+.
    #[test]
    fn fig12_system_ordering() {
        let env = Env::env_b();
        let pa = profile(ModelSpec::t5_base(), Method::pa(true), 128);
        let full = profile(ModelSpec::t5_base(), Method::FullFT, 128);
        let j = TrainJob::new(1000, 1, 128, 16);

        let pac = run_system(System::PacPlus, &pa, &env, j).unwrap().total;
        let homo = run_system(System::PacHomo, &pa, &env, j).unwrap().total;
        let asteroid = run_system(System::Asteroid, &full, &env, j).unwrap().total;
        let hetpipe = run_system(System::HetPipe, &full, &env, j).unwrap().total;

        assert!(pac <= homo * 1.001, "homo {homo} beat hetero {pac}");
        assert!(pac < asteroid, "PAC+ {pac} !< Asteroid {asteroid}");
        assert!(asteroid < hetpipe, "Asteroid {asteroid} !< HetPipe {hetpipe}");
        let speedup = hetpipe / pac;
        assert!(speedup > 2.0, "PAC+ vs HetPipe speedup only {speedup}");
    }

    /// HetPipe on a heterogeneous cluster still makes progress (multiple
    /// virtual workers), and its PS traffic hurts full-FT hardest.
    #[test]
    fn hetpipe_ps_bottleneck() {
        let env = Env::env_b();
        let full = profile(ModelSpec::t5_base(), Method::FullFT, 128);
        let pa = profile(ModelSpec::t5_base(), Method::pa(false), 128);
        let j = TrainJob::new(500, 1, 128, 16);
        let het_full = run_system(System::HetPipe, &full, &env, j).unwrap().total;
        let het_pa = run_system(System::HetPipe, &pa, &env, j).unwrap().total;
        assert!(het_pa < het_full);
    }

    #[test]
    fn names_unique() {
        let all = [
            System::Standalone,
            System::DataParallel,
            System::PipelineParallel,
            System::PacPlus,
            System::PacHomo,
            System::Asteroid,
            System::HetPipe,
        ];
        let mut names: Vec<_> = all.iter().map(|s| s.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }
}
