//! Compatibility adapters over the [`crate::strategy`] layer.
//!
//! The collaborative edge training systems PAC+ is compared against
//! (paper §VI-A "Baseline Methods" and §VI-C) used to be hand-rolled
//! inside a closed match ladder here; they now live as
//! [`ParallelismStrategy`] implementations in [`crate::strategy`] and
//! are resolved by name through a
//! [`StrategyRegistry`](crate::strategy::StrategyRegistry).
//!
//! This module keeps the old entry points stable:
//!
//! * [`System`] — **deprecated alias** retained for CLI/JSON
//!   compatibility; new code should look strategies up by name in the
//!   registry. Each variant maps 1:1 onto a registered strategy via
//!   [`System::strategy`].
//! * [`run_system`] — thin forwarder to
//!   [`ParallelismStrategy::run`].
//! * [`TrainJob`] — re-exported from `strategy` (its new home).
//!
//! All systems share the same profile/cost substrate and the same 1F1B
//! event simulator, so differences come purely from architecture.

use crate::cluster::Env;
use crate::planner::PlanError;
use crate::profiler::Profile;
use crate::sched::training::RunReport;
use crate::strategy::{
    Asteroid, DataParallel, HetPipe, PacHomo, PacPlus, ParallelismStrategy, PipelineParallel,
    Standalone,
};

pub use crate::strategy::TrainJob;

/// A collaborative training system under evaluation.
///
/// Deprecated alias over the strategy layer: prefer
/// `StrategyRegistry::with_defaults().get(name)`. Kept because the CLI
/// flags and recorded experiment JSON address systems by these variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    Standalone,
    DataParallel,
    PipelineParallel,
    PacPlus,
    PacHomo,
    Asteroid,
    HetPipe,
}

impl System {
    /// Every variant, in Table V / Fig. 12 order.
    pub const ALL: [System; 7] = [
        System::Standalone,
        System::DataParallel,
        System::PipelineParallel,
        System::PacPlus,
        System::PacHomo,
        System::Asteroid,
        System::HetPipe,
    ];

    /// The strategy this variant aliases.
    pub fn strategy(self) -> &'static dyn ParallelismStrategy {
        match self {
            System::Standalone => &Standalone,
            System::DataParallel => &DataParallel,
            System::PipelineParallel => &PipelineParallel,
            System::PacPlus => &PacPlus,
            System::PacHomo => &PacHomo,
            System::Asteroid => &Asteroid,
            System::HetPipe => &HetPipe,
        }
    }

    pub fn name(self) -> &'static str {
        self.strategy().name()
    }
}

/// Run (simulate) `system` fine-tuning on `env`: forwards to the
/// aliased strategy. Returns the run report, or the planning error.
pub fn run_system(
    system: System,
    profile: &Profile,
    env: &Env,
    job: TrainJob,
) -> Result<RunReport, PlanError> {
    system.strategy().run(profile, env, job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::LayerGraph;
    use crate::model::{Method, ModelSpec, Precision};
    use crate::strategy::StrategyRegistry;

    fn profile(spec: ModelSpec, method: Method, seq: usize) -> Profile {
        Profile::new(LayerGraph::new(spec), method, Precision::FP32, seq)
    }

    fn job() -> TrainJob {
        TrainJob::new(1000, 1, 128, 16)
    }

    /// Table V column shapes on Env.A / T5-Base.
    #[test]
    fn table5_t5base_ordering() {
        let env = Env::env_a();
        let full = profile(ModelSpec::t5_base(), Method::FullFT, 128);
        let ad = profile(ModelSpec::t5_base(), Method::adapters_default(), 128);
        let pa = profile(ModelSpec::t5_base(), Method::pa(false), 128);

        // Full + Standalone/DP OOM on a 4GB Nano (Table V row 1)
        assert!(run_system(System::Standalone, &full, &env, job()).is_err());
        assert!(run_system(System::DataParallel, &full, &env, job()).is_err());
        // Full + PP fits
        let full_pp = run_system(System::PipelineParallel, &full, &env, job()).unwrap();
        // Adapters fit standalone and on DP
        let ad_solo = run_system(System::Standalone, &ad, &env, job()).unwrap();
        let ad_dp = run_system(System::DataParallel, &ad, &env, job()).unwrap();
        let ad_pp = run_system(System::PipelineParallel, &ad, &env, job()).unwrap();
        // PAC+ (parallel adapters, hybrid) beats every baseline
        let pac = run_system(System::PacPlus, &pa, &env, job()).unwrap();
        for (name, t) in [
            ("full_pp", full_pp.total),
            ("ad_solo", ad_solo.total),
            ("ad_dp", ad_dp.total),
            ("ad_pp", ad_pp.total),
        ] {
            assert!(pac.total < t, "PAC+ {} !< {name} {t}", pac.total);
        }
        // distributing helps: DP/PP beat standalone
        assert!(ad_dp.total < ad_solo.total);
        assert!(ad_pp.total < ad_solo.total);
    }

    /// Table V: BART-Large OOMs standalone/DP even with Adapters (4GB).
    #[test]
    fn table5_bart_ooms() {
        let env = Env::env_a();
        let ad = profile(ModelSpec::bart_large(), Method::adapters_default(), 128);
        assert!(run_system(System::Standalone, &ad, &env, job()).is_err());
        assert!(run_system(System::DataParallel, &ad, &env, job()).is_err());
        assert!(run_system(System::PipelineParallel, &ad, &env, job()).is_ok());
    }

    /// Fig. 12 shape on Env.B: PAC+ > Asteroid > HetPipe; homo ablation
    /// loses to heterogeneity-aware PAC+.
    #[test]
    fn fig12_system_ordering() {
        let env = Env::env_b();
        let pa = profile(ModelSpec::t5_base(), Method::pa(true), 128);
        let full = profile(ModelSpec::t5_base(), Method::FullFT, 128);
        let j = TrainJob::new(1000, 1, 128, 16);

        let pac = run_system(System::PacPlus, &pa, &env, j).unwrap().total;
        let homo = run_system(System::PacHomo, &pa, &env, j).unwrap().total;
        let asteroid = run_system(System::Asteroid, &full, &env, j).unwrap().total;
        let hetpipe = run_system(System::HetPipe, &full, &env, j).unwrap().total;

        assert!(pac <= homo * 1.001, "homo {homo} beat hetero {pac}");
        assert!(pac < asteroid, "PAC+ {pac} !< Asteroid {asteroid}");
        assert!(asteroid < hetpipe, "Asteroid {asteroid} !< HetPipe {hetpipe}");
        let speedup = hetpipe / pac;
        assert!(speedup > 2.0, "PAC+ vs HetPipe speedup only {speedup}");
    }

    /// HetPipe on a heterogeneous cluster still makes progress (multiple
    /// virtual workers), and its PS traffic hurts full-FT hardest.
    #[test]
    fn hetpipe_ps_bottleneck() {
        let env = Env::env_b();
        let full = profile(ModelSpec::t5_base(), Method::FullFT, 128);
        let pa = profile(ModelSpec::t5_base(), Method::pa(false), 128);
        let j = TrainJob::new(500, 1, 128, 16);
        let het_full = run_system(System::HetPipe, &full, &env, j).unwrap().total;
        let het_pa = run_system(System::HetPipe, &pa, &env, j).unwrap().total;
        assert!(het_pa < het_full);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = System::ALL.iter().map(|s| s.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), System::ALL.len());
    }

    /// Golden: the enum adapter and a by-name registry lookup must
    /// resolve to the same strategy and produce bit-identical reports.
    #[test]
    fn registry_matches_enum_dispatch() {
        let reg = StrategyRegistry::with_defaults();
        let env = Env::env_b();
        let pa = profile(ModelSpec::t5_base(), Method::pa(true), 128);
        let j = TrainJob::new(500, 2, 128, 16);
        for sys in System::ALL {
            let strat = reg.get(sys.name()).unwrap_or_else(|| {
                panic!("{} not registered", sys.name())
            });
            assert_eq!(strat.name(), sys.name());
            match (run_system(sys, &pa, &env, j), strat.run(&pa, &env, j)) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.total.to_bits(), b.total.to_bits(), "{}", sys.name());
                    assert_eq!(a.epoch1.to_bits(), b.epoch1.to_bits(), "{}", sys.name());
                    assert_eq!(
                        a.redistribution.to_bits(),
                        b.redistribution.to_bits(),
                        "{}",
                        sys.name()
                    );
                    assert_eq!(a.plan.grouping(), b.plan.grouping(), "{}", sys.name());
                    for (x, y) in a.plan.stages.iter().zip(&b.plan.stages) {
                        assert_eq!(x.range, y.range);
                        assert_eq!(x.dispatch, y.dispatch);
                        assert_eq!(x.e_f.to_bits(), y.e_f.to_bits());
                        assert_eq!(x.peak_mem, y.peak_mem);
                    }
                }
                (Err(a), Err(b)) => assert_eq!(a, b, "{}", sys.name()),
                (a, b) => panic!(
                    "{}: enum {:?} vs registry {:?}",
                    sys.name(),
                    a.map(|r| r.total),
                    b.map(|r| r.total)
                ),
            }
        }
    }
}
