//! PJRT runtime: load AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the coordinator's hot path.
//!
//! The interchange format is HLO **text** (`HloModuleProto::from_text_file`)
//! — see `/opt/xla-example/README.md`: jax ≥ 0.5 emits serialized protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. All artifacts are lowered with
//! `return_tuple=True`, so outputs arrive as one tuple literal that we
//! unpack.
//!
//! The PJRT backend needs the `xla` bindings (and the native
//! `libxla_extension`), which are not always available. It is therefore
//! gated behind the **`pjrt` cargo feature**: without it, [`Runtime`]
//! compiles as a manifest-only stub — artifact metadata and parameter
//! dumps still load, every `execute` path returns a clear error, and the
//! simulator/planner layers (which never execute HLO) are unaffected.

pub mod manifest;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, Dtype, Manifest, ParamEntry, ParamSet, TensorSpec};

/// A host-side tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    /// f16 storage as raw bit patterns (upload-only: reduced-precision
    /// parameter sets; all artifact *outputs* are f32/i32).
    F16(Vec<u16>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::F16(_, s) | Tensor::I32(_, s) | Tensor::I8(_, s) => s,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(..) => Dtype::F32,
            Tensor::F16(..) => Dtype::F16,
            Tensor::I32(..) => Dtype::I32,
            Tensor::I8(..) => Dtype::I8,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Build from a raw little-endian byte buffer (parameter dumps).
    pub fn from_bytes(dtype: Dtype, shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if bytes.len() != numel * dtype.bytes() {
            bail!("byte length {} != {} x {:?}", bytes.len(), numel, dtype);
        }
        Ok(match dtype {
            Dtype::F32 => Tensor::F32(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
                shape,
            ),
            Dtype::F16 => Tensor::F16(
                bytes
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect(),
                shape,
            ),
            Dtype::I32 => Tensor::I32(
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
                shape,
            ),
            Dtype::I8 => Tensor::I8(bytes.iter().map(|&b| b as i8).collect(), shape),
        })
    }
}

/// Decode a parameter set into host tensors (order = manifest order).
/// Shared by the real and stub runtimes — reads files, never touches
/// PJRT.
fn params_from_manifest(manifest: &Manifest, tag: &str) -> Result<Vec<Tensor>> {
    let set = manifest.param_set(tag)?.clone();
    let bytes = manifest.read_param_bytes(tag)?;
    set.entries
        .iter()
        .zip(bytes)
        .map(|(e, b)| {
            Tensor::from_bytes(e.dtype, e.shape.clone(), &b)
                .with_context(|| format!("param {}", e.name))
        })
        .collect()
}

#[cfg(feature = "pjrt")]
pub use backend::{Executable, Runtime};

#[cfg(feature = "pjrt")]
mod backend {
    //! The real PJRT backend (requires the `xla` bindings).

    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::{Arc, Mutex};

    use anyhow::{anyhow, bail, Result};

    use super::{params_from_manifest, Manifest, Tensor};

    impl Tensor {
        fn to_literal(&self) -> Result<xla::Literal> {
            let (ty, bytes): (xla::ElementType, &[u8]) = match self {
                Tensor::F32(v, _) => (xla::ElementType::F32, unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }),
                Tensor::F16(v, _) => (xla::ElementType::F16, unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 2)
                }),
                Tensor::I32(v, _) => (xla::ElementType::S32, unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                }),
                Tensor::I8(v, _) => (xla::ElementType::S8, unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len())
                }),
            };
            xla::Literal::create_from_shape_and_untyped_data(ty, self.shape(), bytes)
                .map_err(|e| anyhow!("literal creation failed: {e}"))
        }

        fn from_literal(lit: &xla::Literal, spec_shape: &[usize]) -> Result<Tensor> {
            let ty = lit.ty().map_err(|e| anyhow!("literal ty: {e}"))?;
            Ok(match ty {
                xla::ElementType::F32 => Tensor::F32(
                    lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
                    spec_shape.to_vec(),
                ),
                xla::ElementType::S32 => Tensor::I32(
                    lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?,
                    spec_shape.to_vec(),
                ),
                xla::ElementType::S8 => Tensor::I8(
                    lit.to_vec::<i8>().map_err(|e| anyhow!("{e}"))?,
                    spec_shape.to_vec(),
                ),
                other => bail!("unsupported output element type {other:?}"),
            })
        }
    }

    /// A compiled executable, shareable across worker threads.
    ///
    /// SAFETY: the `xla` crate wraps raw PJRT pointers without
    /// `Send`/`Sync` markers, but the PJRT C API contract makes `Execute`
    /// thread-safe, and the CPU client (TFRT) supports concurrent
    /// execution. The only non-thread-safe part of the wrapper is the
    /// internal `Rc` refcount on the client, which we only touch under
    /// the `Runtime::executables` mutex (compilation) or at
    /// single-threaded drop time.
    pub struct Executable(xla::PjRtLoadedExecutable);

    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Executable {
        pub fn execute_literals(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
            let result = self
                .0
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute: {e}"))?;
            result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))
        }
    }

    /// The PJRT runtime: one CPU client + compiled executables by name.
    ///
    /// Executables are compiled lazily on first use and cached. `execute`
    /// is `&self` (internally synchronized) so worker threads can share
    /// one runtime behind an `Arc`.
    pub struct Runtime {
        client: Mutex<xla::PjRtClient>,
        pub manifest: Manifest,
        executables: Mutex<HashMap<String, Arc<Executable>>>,
    }

    // SAFETY: see `Executable`. The client is only used under its mutex.
    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}

    impl Runtime {
        /// Open an artifact directory produced by `python -m compile.aot`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
            let manifest = Manifest::load(&dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
            Ok(Runtime {
                client: Mutex::new(client),
                manifest,
                executables: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.lock().unwrap().platform_name()
        }

        /// Compile (or fetch the cached) executable for an artifact.
        pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
            let mut cache = self.executables.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
            let spec = self.manifest.artifact(name)?;
            let path = self.manifest.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .lock()
                .unwrap()
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            let exe = Arc::new(Executable(exe));
            cache.insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute an artifact with host tensors; validates shapes/dtypes
        /// against the manifest and unpacks the output tuple.
        pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let spec = self.manifest.artifact(name)?.clone();
            if inputs.len() != spec.inputs.len() {
                bail!(
                    "{name}: got {} inputs, manifest expects {}",
                    inputs.len(),
                    spec.inputs.len()
                );
            }
            for (t, s) in inputs.iter().zip(&spec.inputs) {
                if t.shape() != s.shape.as_slice() || t.dtype() != s.dtype {
                    bail!(
                        "{name}: input {} shape/dtype mismatch: got {:?}/{:?}, want {:?}/{:?}",
                        s.name,
                        t.shape(),
                        t.dtype(),
                        s.shape,
                        s.dtype
                    );
                }
            }
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let exe = self.executable(name)?;
            let tuple = exe
                .execute_literals(&literals)
                .map_err(|e| anyhow!("executing {name}: {e}"))?;
            let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))?;
            if parts.len() != spec.outputs.len() {
                bail!(
                    "{name}: got {} outputs, manifest expects {}",
                    parts.len(),
                    spec.outputs.len()
                );
            }
            parts
                .iter()
                .zip(&spec.outputs)
                .map(|(lit, os)| Tensor::from_literal(lit, &os.shape))
                .collect()
        }

        /// Load a parameter set as tensors (order = manifest order).
        pub fn load_params(&self, tag: &str) -> Result<Vec<Tensor>> {
            params_from_manifest(&self.manifest, tag)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use backend_stub::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod backend_stub {
    //! Manifest-only stand-in compiled when the `pjrt` feature is off:
    //! artifact metadata and parameter dumps load, execution errors out.

    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{params_from_manifest, Manifest, Tensor};

    const NO_PJRT: &str = "pacpp was built without the `pjrt` feature; executing AOT \
                           artifacts requires the XLA PJRT bindings — vendor the `xla` \
                           crate (add it to rust/Cargo.toml, see the [features] notes) \
                           and rebuild with `--features pjrt`";

    /// Stand-in for the compiled-executable handle.
    pub struct Executable;

    /// Manifest-only runtime: loads artifact metadata and parameter sets
    /// but cannot execute HLO.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Open an artifact directory produced by `python -m compile.aot`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
            Ok(Runtime { manifest: Manifest::load(&dir)? })
        }

        pub fn platform(&self) -> String {
            "none (built without the `pjrt` feature)".into()
        }

        pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            bail!("cannot compile {name:?}: {NO_PJRT}")
        }

        pub fn execute(&self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("cannot execute {name:?}: {NO_PJRT}")
        }

        /// Load a parameter set as tensors (order = manifest order).
        pub fn load_params(&self, tag: &str) -> Result<Vec<Tensor>> {
            params_from_manifest(&self.manifest, tag)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_from_bytes_roundtrip() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let t = Tensor::from_bytes(Dtype::F32, vec![3], &bytes).unwrap();
        assert_eq!(t.as_f32().unwrap(), &vals);
        assert!(Tensor::from_bytes(Dtype::F32, vec![4], &bytes).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_backend() {
        // a Runtime cannot be constructed without artifacts on disk, but
        // the error paths must name the missing feature clearly
        let err = Runtime::load("/nonexistent/artifacts").unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    /// Tests below require AOT artifacts (`make artifacts`) and the
    /// PJRT backend.
    #[cfg(feature = "pjrt")]
    mod with_artifacts {
        use std::path::PathBuf;

        use crate::runtime::*;

        fn tiny() -> Runtime {
            let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
            Runtime::load(dir).expect("run `make artifacts` first")
        }

        #[test]
        fn loads_and_compiles_backbone() {
            let rt = tiny();
            assert!(rt.platform().to_lowercase().contains("cpu")
                || rt.platform().to_lowercase().contains("host"));
            rt.executable("backbone_fwd").unwrap();
            // cached second fetch
            rt.executable("backbone_fwd").unwrap();
        }

        #[test]
        fn executes_backbone_and_matches_golden() {
            let rt = tiny();
            let cfg = rt.manifest.config.clone();
            let golden_text =
                std::fs::read_to_string(rt.manifest.dir.join("golden.json")).unwrap();
            let golden = crate::util::json::Json::parse(&golden_text).unwrap();

            let mut inputs = rt.load_params("backbone").unwrap();
            let tokens: Vec<i32> = golden
                .get("tokens")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as i32)
                .collect();
            inputs.push(Tensor::I32(tokens, vec![cfg.batch, cfg.seq_len]));
            let out = rt.execute("backbone_fwd", &inputs).unwrap();
            assert_eq!(out.len(), 1);
            let acts = out[0].as_f32().unwrap();
            let acts_sum: f64 = acts.iter().map(|&x| x as f64).sum();
            let want = golden.get("acts_sum").unwrap().as_f64().unwrap();
            assert!(
                (acts_sum - want).abs() < 1e-2 * want.abs().max(1.0),
                "acts_sum {acts_sum} vs golden {want}"
            );
            // spot-check the first 8 values
            let slice = golden.get("acts_slice").unwrap().as_arr().unwrap();
            for (i, g) in slice.iter().enumerate() {
                let got = acts[i] as f64;
                let want = g.as_f64().unwrap();
                assert!((got - want).abs() < 1e-4, "acts[{i}] {got} vs {want}");
            }
        }

        #[test]
        fn adapter_step_matches_golden_loss() {
            let rt = tiny();
            let cfg = rt.manifest.config.clone();
            let golden_text =
                std::fs::read_to_string(rt.manifest.dir.join("golden.json")).unwrap();
            let golden = crate::util::json::Json::parse(&golden_text).unwrap();
            let tokens: Vec<i32> = golden.get("tokens").unwrap().as_arr().unwrap()
                .iter().map(|v| v.as_f64().unwrap() as i32).collect();
            let labels: Vec<i32> = golden.get("labels").unwrap().as_arr().unwrap()
                .iter().map(|v| v.as_f64().unwrap() as i32).collect();
            let lr = golden.get("lr").unwrap().as_f64().unwrap() as f32;

            // backbone fwd -> acts
            let mut binputs = rt.load_params("backbone").unwrap();
            binputs.push(Tensor::I32(tokens, vec![cfg.batch, cfg.seq_len]));
            let acts = rt.execute("backbone_fwd", &binputs).unwrap().remove(0);

            // adapter step on cached acts
            let mut ainputs = rt.load_params("adapter_gaussian").unwrap();
            ainputs.push(acts);
            ainputs.push(Tensor::I32(labels, vec![cfg.batch]));
            ainputs.push(Tensor::F32(vec![lr], vec![]));
            let out = rt.execute("adapter_step", &ainputs).unwrap();
            let loss = out.last().unwrap().scalar_f32().unwrap();
            let want = golden.get("adapter_step_loss").unwrap().as_f64().unwrap();
            assert!(
                (loss as f64 - want).abs() < 1e-3,
                "loss {loss} vs golden {want}"
            );
        }

        #[test]
        fn rejects_bad_inputs() {
            let rt = tiny();
            assert!(rt.execute("backbone_fwd", &[]).is_err());
            let mut inputs = rt.load_params("backbone").unwrap();
            inputs.push(Tensor::I32(vec![0; 10], vec![10])); // wrong shape
            assert!(rt.execute("backbone_fwd", &inputs).is_err());
        }
    }
}
