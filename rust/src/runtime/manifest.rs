//! AOT manifest parsing — the contract between `python/compile/aot.py`
//! and the Rust runtime (artifact IO specs + parameter-dump layouts).

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor dtypes crossing the AOT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    /// f16 storage (Table VII's FP16 backbone row). Host-side it is kept
    /// as raw u16 bits — compute always happens in f32 inside the HLO.
    F16,
    I32,
    I8,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f16" => Ok(Dtype::F16),
            "i32" => Ok(Dtype::I32),
            "i8" => Ok(Dtype::I8),
            other => bail!("unknown dtype {other:?}"),
        }
    }

    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F16 => 2,
            Dtype::I8 => 1,
        }
    }
}

/// One tensor's shape/dtype (manifest "inputs"/"outputs" entries).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype.bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One HLO artifact's IO contract.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One entry in a binary parameter dump.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub offset: usize,
    pub nbytes: usize,
}

/// A parameter dump file (`params_<tag>.bin`).
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub file: String,
    pub entries: Vec<ParamEntry>,
    pub total_bytes: usize,
}

/// The model configuration the artifacts were lowered with.
#[derive(Debug, Clone)]
pub struct AotConfig {
    pub name: String,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub reduction: usize,
    pub n_classes: usize,
    pub params_backbone: u64,
    pub params_adapter: u64,
}

/// Parsed `manifest.json` + artifact directory handle.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: AotConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: BTreeMap<String, ParamSet>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let c = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let u = |k: &str| -> Result<usize> {
            c.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config missing {k}"))
        };
        let config = AotConfig {
            name: c.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            layers: u("layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            d_ff: u("d_ff")?,
            vocab: u("vocab")?,
            seq_len: u("seq_len")?,
            batch: u("batch")?,
            reduction: u("reduction")?,
            n_classes: u("n_classes")?,
            params_backbone: c.get("params_backbone").and_then(Json::as_u64).unwrap_or(0),
            params_adapter: c.get("params_adapter").and_then(Json::as_u64).unwrap_or(0),
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec { file, inputs: parse_list("inputs")?, outputs: parse_list("outputs")? },
            );
        }

        let mut params = BTreeMap::new();
        if let Some(psets) = j.get("params").and_then(Json::as_obj) {
            for (tag, p) in psets {
                let file = p
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("param set {tag} missing file"))?
                    .to_string();
                let entries = p
                    .get("entries")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("param set {tag} missing entries"))?
                    .iter()
                    .map(|e| {
                        let t = TensorSpec::from_json(e)?;
                        Ok(ParamEntry {
                            name: t.name,
                            shape: t.shape,
                            dtype: t.dtype,
                            offset: e
                                .get("offset")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| anyhow!("missing offset"))?,
                            nbytes: e
                                .get("nbytes")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| anyhow!("missing nbytes"))?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let total_bytes =
                    p.get("total_bytes").and_then(Json::as_usize).unwrap_or(0);
                params.insert(tag.clone(), ParamSet { file, entries, total_bytes });
            }
        }

        Ok(Manifest { dir, config, artifacts, params })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn param_set(&self, tag: &str) -> Result<&ParamSet> {
        self.params
            .get(tag)
            .ok_or_else(|| anyhow!("param set {tag:?} not in manifest"))
    }

    /// Read a parameter dump into raw per-entry byte buffers.
    pub fn read_param_bytes(&self, tag: &str) -> Result<Vec<Vec<u8>>> {
        let set = self.param_set(tag)?;
        let raw = fs::read(self.dir.join(&set.file))
            .with_context(|| format!("reading {}", set.file))?;
        if set.total_bytes != 0 && raw.len() != set.total_bytes {
            bail!("{}: file is {} bytes, manifest says {}", set.file, raw.len(), set.total_bytes);
        }
        set.entries
            .iter()
            .map(|e| {
                if e.offset + e.nbytes > raw.len() {
                    bail!("{}: entry {} overruns file", set.file, e.name);
                }
                Ok(raw[e.offset..e.offset + e.nbytes].to_vec())
            })
            .collect()
    }

    /// Available stage sizes (`stage_fwd_k<K>` artifacts).
    pub fn stage_sizes(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|n| n.strip_prefix("stage_fwd_k").and_then(|k| k.parse().ok()))
            .collect();
        ks.sort();
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
    }

    #[test]
    fn loads_tiny_manifest() {
        let m = Manifest::load(tiny_dir()).expect("run `make artifacts` first");
        assert_eq!(m.config.layers, 2);
        assert_eq!(m.config.d_model, 32);
        assert!(m.artifacts.contains_key("backbone_fwd"));
        assert!(m.artifacts.contains_key("adapter_step"));
        assert_eq!(m.stage_sizes(), vec![1, 2]);
    }

    #[test]
    fn artifact_specs_consistent() {
        let m = Manifest::load(tiny_dir()).unwrap();
        let a = m.artifact("adapter_step").unwrap();
        // inputs: 24 adapter params + acts + labels + lr
        assert_eq!(a.inputs.len(), 27);
        // outputs: 24 updated params + loss
        assert_eq!(a.outputs.len(), 25);
        let acts = &a.inputs[24];
        assert_eq!(acts.name, "acts");
        assert_eq!(acts.shape, vec![3, 4, 16, 32]);
        assert_eq!(acts.dtype, Dtype::F32);
    }

    #[test]
    fn param_bytes_roundtrip() {
        let m = Manifest::load(tiny_dir()).unwrap();
        let bytes = m.read_param_bytes("backbone").unwrap();
        let set = m.param_set("backbone").unwrap();
        assert_eq!(bytes.len(), set.entries.len());
        for (b, e) in bytes.iter().zip(&set.entries) {
            assert_eq!(b.len(), e.nbytes);
            assert_eq!(e.nbytes, e.shape.iter().product::<usize>() * e.dtype.bytes());
        }
    }

    #[test]
    fn quantized_params_have_i8() {
        let m = Manifest::load(tiny_dir()).unwrap();
        let set = m.param_set("backbone_int8").unwrap();
        assert!(set.entries.iter().any(|e| e.dtype == Dtype::I8));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::load(tiny_dir()).unwrap();
        assert!(m.artifact("nonexistent").is_err());
        assert!(m.param_set("nonexistent").is_err());
    }
}
