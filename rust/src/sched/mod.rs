//! Hybrid-parallel execution schedule: 1F1B micro-batch ordering (paper
//! §V-A, Fig. 10(b)) and a discrete-event simulator that replays a plan
//! over the device/network models to produce mini-batch latency, bubble
//! fraction, and peak in-flight memory.
//!
//! The simulator is the timing backend for every registered
//! [`crate::strategy`] implementation (pure DP = 1 stage × n devices;
//! pure PP = n stages × 1 device), so all Table V / Fig. 12 / Fig. 16
//! comparisons run through the same machinery; [`training`] turns a
//! simulated mini-batch into epoch- and run-level reports for any plan.

pub mod timeline;
pub mod training;

use crate::planner::Plan;
use crate::profiler::Profile;
use crate::cluster::Network;

/// One operation in a stage's 1F1B order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Forward pass of micro-batch `m`.
    F(usize),
    /// Backward pass of micro-batch `m`.
    B(usize),
}

/// Construct the 1F1B order for stage `i` of `s` stages with `m` micro-
/// batches: warmup forwards, steady 1F1B pairs, cooldown backwards
/// (PipeDream-Flush schedule [40]).
pub fn one_f_one_b(i: usize, s: usize, m: usize) -> Vec<Op> {
    let warmup = (s - i - 1).min(m);
    let mut ops = Vec::with_capacity(2 * m);
    for mb in 0..warmup {
        ops.push(Op::F(mb));
    }
    let steady = m - warmup;
    for k in 0..steady {
        ops.push(Op::F(warmup + k));
        ops.push(Op::B(k));
    }
    for mb in steady..m {
        ops.push(Op::B(mb));
    }
    ops
}

/// A simulated timeline entry (for reporting / debugging).
#[derive(Debug, Clone)]
pub struct Slot {
    pub stage: usize,
    pub op: Op,
    pub start: f64,
    pub end: f64,
}

/// Result of simulating one mini-batch through the pipeline.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock of the mini-batch including the final AllReduce.
    pub minibatch_time: f64,
    /// Makespan of compute only (before AllReduce).
    pub compute_span: f64,
    /// Fraction of device-time idle inside the compute span (pipeline
    /// bubbles + communication stalls).
    pub bubble_fraction: f64,
    /// Peak number of in-flight (forwarded, not yet backwarded) micro-
    /// batches per stage — validates the planner's 1F1B memory model.
    pub peak_in_flight: Vec<usize>,
    pub timeline: Vec<Slot>,
}

/// Discrete-event simulation of `plan` for one mini-batch.
///
/// Dependencies: `F(i, m)` needs `F(i-1, m)` + forward transfer;
/// `B(i, m)` needs `B(i+1, m)` + backward transfer (for stage `s-1`,
/// `B` follows its own `F`). Each stage executes its 1F1B op list in
/// order. AllReduce of every stage's trainable parameters happens after
/// its last backward; the mini-batch completes when the slowest stage's
/// AllReduce finishes (Fig. 10(b)).
pub fn simulate_minibatch(plan: &Plan, profile: &Profile, net: &Network) -> SimResult {
    let s = plan.n_stages();
    let m = plan.microbatches;
    let orders: Vec<Vec<Op>> = (0..s).map(|i| one_f_one_b(i, s, m)).collect();

    let c_f = net.transfer_time(profile.boundary_bytes_fwd(plan.microbatch_size));
    let c_b = net.transfer_time(profile.boundary_bytes_bwd(plan.microbatch_size));

    let mut f_done = vec![vec![f64::NAN; m]; s];
    let mut b_done = vec![vec![f64::NAN; m]; s];
    let mut next_op = vec![0usize; s];
    let mut stage_free = vec![0.0f64; s];
    let mut timeline = Vec::with_capacity(2 * s * m);

    let ready = |op: Op, i: usize, f_done: &Vec<Vec<f64>>, b_done: &Vec<Vec<f64>>| -> Option<f64> {
        match op {
            Op::F(mb) => {
                if i == 0 {
                    Some(0.0)
                } else {
                    let d = f_done[i - 1][mb];
                    if d.is_nan() {
                        None
                    } else {
                        Some(d + c_f)
                    }
                }
            }
            Op::B(mb) => {
                if i == s - 1 {
                    let d = f_done[i][mb];
                    if d.is_nan() {
                        None
                    } else {
                        Some(d)
                    }
                } else {
                    let d = b_done[i + 1][mb];
                    if d.is_nan() {
                        None
                    } else {
                        Some(d + c_b)
                    }
                }
            }
        }
    };

    let total_ops: usize = orders.iter().map(|o| o.len()).sum();
    let mut executed = 0;
    while executed < total_ops {
        // pick the stage whose head op can start earliest
        let mut best: Option<(f64, usize)> = None;
        for i in 0..s {
            if next_op[i] >= orders[i].len() {
                continue;
            }
            if let Some(r) = ready(orders[i][next_op[i]], i, &f_done, &b_done) {
                let start = r.max(stage_free[i]);
                if best.map(|(t, _)| start < t).unwrap_or(true) {
                    best = Some((start, i));
                }
            }
        }
        let (start, i) = best.expect("deadlock in 1F1B simulation");
        let op = orders[i][next_op[i]];
        let dur = match op {
            Op::F(_) => plan.stages[i].e_f,
            Op::B(_) => plan.stages[i].e_b,
        };
        let end = start + dur;
        match op {
            Op::F(mb) => f_done[i][mb] = end,
            Op::B(mb) => b_done[i][mb] = end,
        }
        stage_free[i] = end;
        next_op[i] += 1;
        executed += 1;
        timeline.push(Slot { stage: i, op, start, end });
    }

    let compute_span = stage_free.iter().cloned().fold(0.0, f64::max);

    // AllReduce after each stage's last backward (overlappable across stages).
    let minibatch_time = (0..s)
        .map(|i| stage_free[i] + plan.stages[i].allreduce)
        .fold(0.0, f64::max);

    // busy time / (span × stages) → bubbles
    let busy: f64 = timeline.iter().map(|t| t.end - t.start).sum();
    let bubble_fraction = 1.0 - busy / (compute_span * s as f64);

    // peak in-flight per stage
    let mut peak_in_flight = vec![0usize; s];
    for i in 0..s {
        let mut events: Vec<(f64, i32)> = Vec::new();
        for mb in 0..m {
            events.push((f_done[i][mb], 1));
            events.push((b_done[i][mb], -1));
        }
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(b.1.cmp(&a.1)));
        let mut cur = 0i32;
        for (_, delta) in events {
            cur += delta;
            peak_in_flight[i] = peak_in_flight[i].max(cur.max(0) as usize);
        }
    }

    SimResult { minibatch_time, compute_span, bubble_fraction, peak_in_flight, timeline }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Env;
    use crate::model::graph::LayerGraph;
    use crate::model::{Method, ModelSpec, Precision};
    use crate::planner::{plan, PlannerOptions};

    fn setup(n_dev: usize, method: Method) -> (Profile, Plan, Env) {
        let profile = Profile::new(
            LayerGraph::new(ModelSpec::t5_base()),
            method,
            Precision::FP32,
            128,
        );
        let env = Env::nanos(n_dev);
        let opts = PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() };
        let p = plan(&profile, &env, &opts).unwrap();
        (profile, p, env)
    }

    #[test]
    fn schedule_shape() {
        // stage 0 of 4 stages, 6 microbatches: 3 warmup F, then 1F1B
        let ops = one_f_one_b(0, 4, 6);
        assert_eq!(ops.len(), 12);
        assert_eq!(&ops[..3], &[Op::F(0), Op::F(1), Op::F(2)]);
        assert_eq!(ops[3], Op::F(3));
        assert_eq!(ops[4], Op::B(0));
        // last stage alternates immediately
        let last = one_f_one_b(3, 4, 6);
        assert_eq!(&last[..2], &[Op::F(0), Op::B(0)]);
    }

    #[test]
    fn schedule_covers_all_microbatches() {
        for s in 1..5 {
            for i in 0..s {
                for m in 1..8 {
                    let ops = one_f_one_b(i, s, m);
                    let fs: Vec<usize> = ops.iter().filter_map(|o| match o {
                        Op::F(x) => Some(*x),
                        _ => None,
                    }).collect();
                    let bs: Vec<usize> = ops.iter().filter_map(|o| match o {
                        Op::B(x) => Some(*x),
                        _ => None,
                    }).collect();
                    assert_eq!(fs, (0..m).collect::<Vec<_>>());
                    assert_eq!(bs, (0..m).collect::<Vec<_>>());
                }
            }
        }
    }

    #[test]
    fn simulation_legal_and_finite() {
        let (profile, p, env) = setup(4, Method::pa(false));
        let r = simulate_minibatch(&p, &profile, &env.network);
        assert!(r.minibatch_time.is_finite() && r.minibatch_time > 0.0);
        assert!(r.compute_span <= r.minibatch_time);
        assert!((0.0..1.0).contains(&r.bubble_fraction), "{}", r.bubble_fraction);
        // per-stage ops never overlap
        for i in 0..p.n_stages() {
            let mut slots: Vec<&Slot> = r.timeline.iter().filter(|t| t.stage == i).collect();
            slots.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            for w in slots.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12);
            }
        }
    }

    #[test]
    fn in_flight_bounded_by_1f1b() {
        let (profile, p, env) = setup(4, Method::pa(false));
        let r = simulate_minibatch(&p, &profile, &env.network);
        let s = p.n_stages();
        for (i, &peak) in r.peak_in_flight.iter().enumerate() {
            assert!(
                peak <= (s - i).min(p.microbatches),
                "stage {i}: in-flight {peak} exceeds 1F1B bound {}",
                (s - i).min(p.microbatches)
            );
        }
    }

    #[test]
    fn sim_close_to_planner_estimate() {
        let (profile, p, env) = setup(4, Method::pa(false));
        let r = simulate_minibatch(&p, &profile, &env.network);
        let est = p.minibatch_time;
        let ratio = r.minibatch_time / est;
        assert!(
            (0.5..1.6).contains(&ratio),
            "simulated {} vs planned {est}",
            r.minibatch_time
        );
    }

    #[test]
    fn fwd_precedes_bwd_per_microbatch() {
        let (profile, p, env) = setup(4, Method::FullFT);
        let r = simulate_minibatch(&p, &profile, &env.network);
        for i in 0..p.n_stages() {
            for mb in 0..p.microbatches {
                let f = r.timeline.iter().find(|t| t.stage == i && t.op == Op::F(mb)).unwrap();
                let b = r.timeline.iter().find(|t| t.stage == i && t.op == Op::B(mb)).unwrap();
                assert!(b.start >= f.end - 1e-12);
            }
        }
    }
}
