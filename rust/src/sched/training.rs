//! Epoch- and run-level fine-tuning time models: hybrid-parallel epoch 1,
//! the cache redistribution step, and the data-parallel cached epochs
//! (paper §V, Fig. 11) — the timing backend for Table V, Fig. 12 and
//! Fig. 18.
//!
//! This module is strategy-agnostic: any [`crate::strategy`]
//! implementation can turn a finished [`Plan`] into a [`RunReport`]
//! through [`report_from_plan`] (the default
//! `ParallelismStrategy::run`), while [`finetune`] remains the
//! plan-then-report shorthand used by the PAC planner family.

use super::simulate_minibatch;
use crate::cluster::Env;
use crate::model::cost;
use crate::model::Method;
use crate::planner::{plan, Plan, PlanError, PlannerOptions};
use crate::profiler::Profile;

/// Sustained embedded-flash read bandwidth for cache reloads (§V-B:
/// "reloaded from disk per microbatch ... no more than tens of
/// milliseconds on embedded flash storage").
pub const FLASH_READ_BPS: f64 = 300e6;

/// A full fine-tuning run's time breakdown.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub plan: Plan,
    /// Wall-clock of the first (hybrid-parallel) epoch.
    pub epoch1: f64,
    /// One-time cache + adapter redistribution between phases (§V-B).
    pub redistribution: f64,
    /// Wall-clock of one cached (pure-DP) epoch.
    pub epoch_cached: f64,
    /// Number of epochs in the run.
    pub epochs: usize,
    /// Total run time.
    pub total: f64,
}

/// Simulated wall-clock of one hybrid-parallel epoch over `samples`.
pub fn epoch_time_hybrid(p: &Plan, profile: &Profile, env: &Env, samples: usize) -> f64 {
    let per_minibatch = simulate_minibatch(p, profile, &env.network).minibatch_time;
    let minibatches = samples.div_ceil(p.minibatch_samples());
    per_minibatch * minibatches as f64
}

/// Phase-2 epoch: pure data parallelism over the cached activations —
/// only the Parallel Adapter executes (paper §V-B). Heterogeneity-aware
/// proportional sample split; cache reload overlaps compute (double
/// buffering), AllReduce of adapter gradients per mini-batch.
pub fn epoch_time_cached(
    profile: &Profile,
    env: &Env,
    samples: usize,
    minibatch: usize,
) -> f64 {
    let spec = &profile.graph.spec;
    let seq = profile.seq;
    let fa = cost::flops_fwd_adapter_per_token(spec, seq);
    let adapter_flops_per_sample = 3.0 * fa * seq as f64;

    // proportional dispatch of each mini-batch
    let total_speed = env.total_effective_flops();
    let slowest_time = env
        .devices
        .iter()
        .map(|d| {
            let share = (minibatch as f64 * d.kind.effective_flops() / total_speed).ceil();
            d.compute_time(share * adapter_flops_per_sample)
        })
        .fold(0.0, f64::max);

    // cache reload per mini-batch (overlapped with compute)
    let cache_bytes = cost::cache_entry_bytes(spec, seq) * minibatch as u64
        / env.n().max(1) as u64;
    let reload = cache_bytes as f64 / FLASH_READ_BPS;

    let adapter_bytes =
        Method::pa(true).trainable_params(spec) * 4;
    let allreduce = env.network.allreduce_time(adapter_bytes, env.n());

    let per_minibatch = slowest_time.max(reload) + allreduce;
    per_minibatch * samples.div_ceil(minibatch) as f64
}

/// One-time redistribution between epoch 1 and the cached phase (§V-B):
/// every device must end up with the full adapter parameters and the
/// cached activations of its assigned sample shard.
pub fn redistribution_time(profile: &Profile, env: &Env, samples: usize) -> f64 {
    let spec = &profile.graph.spec;
    let cache_total = cost::cache_entry_bytes(spec, profile.seq) * samples as u64;
    let per_device = cache_total / env.n().max(1) as u64;
    let adapter_bytes = Method::pa(true).trainable_params(spec) * 4;
    env.network.allgather_time(per_device, env.n())
        + env.network.broadcast_time(adapter_bytes, env.n())
}

/// Extend an already-constructed plan to a full `epochs`-epoch run:
/// simulated hybrid epoch 1, then — with
/// `Method::ParallelAdapters{cache: true}` — the one-time redistribution
/// and the cached data-parallel epochs; any other method repeats epoch 1.
pub fn report_from_plan(
    plan: Plan,
    profile: &Profile,
    env: &Env,
    samples: usize,
    epochs: usize,
) -> RunReport {
    let epoch1 = epoch_time_hybrid(&plan, profile, env, samples);
    let minibatch = plan.minibatch_samples();

    let (redistribution, epoch_cached) = if profile.method.skips_backbone_with_cache()
        && epochs > 1
    {
        (
            redistribution_time(profile, env, samples),
            epoch_time_cached(profile, env, samples, minibatch),
        )
    } else {
        (0.0, epoch1)
    };

    let total = epoch1 + redistribution + epoch_cached * (epochs - 1) as f64;
    RunReport { plan, epoch1, redistribution, epoch_cached, epochs, total }
}

/// Plan + simulate a complete PAC+ fine-tuning run of `epochs` epochs.
pub fn finetune(
    profile: &Profile,
    env: &Env,
    opts: &PlannerOptions,
    samples: usize,
    epochs: usize,
) -> Result<RunReport, PlanError> {
    Ok(report_from_plan(plan(profile, env, opts)?, profile, env, samples, epochs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::LayerGraph;
    use crate::model::{ModelSpec, Precision};

    fn profile(method: Method) -> Profile {
        Profile::new(LayerGraph::new(ModelSpec::t5_base()), method, Precision::FP32, 128)
    }

    fn opts() -> PlannerOptions {
        PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() }
    }

    #[test]
    fn cached_epoch_much_faster() {
        let p = profile(Method::pa(true));
        let env = Env::env_a();
        let r = finetune(&p, &env, &opts(), 1000, 3).unwrap();
        assert!(
            r.epoch_cached < 0.25 * r.epoch1,
            "cached {} vs epoch1 {}",
            r.epoch_cached,
            r.epoch1
        );
        assert!(r.total < 3.0 * r.epoch1);
    }

    #[test]
    fn without_cache_epochs_repeat() {
        let p = profile(Method::pa(false));
        let env = Env::env_a();
        let r = finetune(&p, &env, &opts(), 500, 3).unwrap();
        assert_eq!(r.redistribution, 0.0);
        assert!((r.total - 3.0 * r.epoch1).abs() < 1e-9);
    }

    /// §V-B: redistribution ≈ 8% of a 3-epoch BART-Large MRPC run.
    #[test]
    fn redistribution_overhead_small() {
        let p = Profile::new(
            LayerGraph::new(ModelSpec::bart_large()),
            Method::pa(true),
            Precision::FP32,
            128,
        );
        let env = Env::env_a();
        let r = finetune(&p, &env, &opts(), 3668, 3).unwrap();
        let frac = r.redistribution / r.total;
        assert!(frac < 0.25, "redistribution fraction {frac}");
        assert!(frac > 0.001);
    }

    /// Fig. 18 shape: latency reduction from the cache grows with epochs
    /// (T5-Large: 39% at 2 epochs → 71% at 10).
    #[test]
    fn fig18_cache_saving_grows_with_epochs() {
        let cached = profile(Method::pa(true));
        let uncached = profile(Method::pa(false));
        let env = Env::env_a();
        let reduction = |e: usize| {
            let with = finetune(&cached, &env, &opts(), 1000, e).unwrap().total;
            let without = finetune(&uncached, &env, &opts(), 1000, e).unwrap().total;
            1.0 - with / without
        };
        let r2 = reduction(2);
        let r10 = reduction(10);
        assert!(r10 > r2, "r2={r2} r10={r10}");
        assert!(r2 > 0.2 && r10 < 0.95, "r2={r2} r10={r10}");
    }

    #[test]
    fn epoch_time_scales_with_samples() {
        let p = profile(Method::pa(false));
        let env = Env::env_a();
        let a = finetune(&p, &env, &opts(), 1000, 1).unwrap().total;
        let b = finetune(&p, &env, &opts(), 2000, 1).unwrap().total;
        assert!((b / a - 2.0).abs() < 0.1);
    }
}
