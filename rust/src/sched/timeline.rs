//! ASCII rendering of a simulated 1F1B pipeline timeline — the textual
//! equivalent of the paper's Fig. 10(b), used by `pacpp timeline` and
//! the planning examples to make schedules inspectable.
//!
//! ```text
//! stage 0 |F0|F1|F2|F3|B0|F4|B1|...        |AR|
//! stage 1    |F0|F1|B0|F2|B1|...        |AR|
//! ```

use super::{Op, SimResult};

/// Render a simulated mini-batch as fixed-width ASCII art.
///
/// `width` is the target character width of the time axis; each slot is
/// labeled `F<mb>`/`B<mb>` and positioned proportionally to its start
/// time. Overlapping labels degrade to `#` fill.
pub fn render(sim: &SimResult, n_stages: usize, width: usize) -> String {
    let span = sim
        .timeline
        .iter()
        .map(|s| s.end)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let scale = (width.max(20) as f64 - 1.0) / span;

    let mut rows: Vec<Vec<char>> = vec![vec![' '; width.max(20)]; n_stages];
    for slot in &sim.timeline {
        let row = &mut rows[slot.stage];
        let a = (slot.start * scale) as usize;
        let b = ((slot.end * scale) as usize).max(a + 1).min(row.len());
        let label = match slot.op {
            Op::F(mb) => format!("F{mb}"),
            Op::B(mb) => format!("B{mb}"),
        };
        let chars: Vec<char> = label.chars().collect();
        for (i, cell) in row[a..b].iter_mut().enumerate() {
            let fill = if i < chars.len() { chars[i] } else { '·' };
            *cell = if *cell == ' ' { fill } else { '#' };
        }
        if b - a >= 1 {
            row[b - 1] = '|';
        }
    }

    let mut out = String::new();
    out.push_str(&format!(
        "1F1B timeline ({} stages, {:.3}s span, {:.0}% bubbles)\n",
        n_stages,
        span,
        sim.bubble_fraction * 100.0
    ));
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!("stage {i} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Env;
    use crate::model::graph::LayerGraph;
    use crate::model::{Method, ModelSpec, Precision};
    use crate::planner::{plan, PlannerOptions};
    use crate::profiler::Profile;
    use crate::sched::simulate_minibatch;

    fn sim() -> (SimResult, usize) {
        let profile = Profile::new(
            LayerGraph::new(ModelSpec::t5_base()),
            Method::pa(false),
            Precision::FP32,
            128,
        );
        let env = Env::nanos(4);
        let opts = PlannerOptions {
            microbatch: 2,
            n_microbatches: 4,
            ..Default::default()
        };
        let p = plan(&profile, &env, &opts).unwrap();
        (simulate_minibatch(&p, &profile, &env.network), p.n_stages())
    }

    #[test]
    fn renders_all_stages() {
        let (s, n) = sim();
        let art = render(&s, n, 100);
        assert_eq!(art.lines().count(), n + 1);
        for i in 0..n {
            assert!(art.contains(&format!("stage {i}")));
        }
    }

    #[test]
    fn labels_present() {
        let (s, n) = sim();
        let art = render(&s, n, 160);
        assert!(art.contains('F'), "{art}");
        assert!(art.contains('B'), "{art}");
        assert!(art.contains("bubbles"));
    }

    #[test]
    fn width_respected() {
        let (s, n) = sim();
        for w in [40usize, 80, 200] {
            let art = render(&s, n, w);
            for line in art.lines().skip(1) {
                assert!(line.chars().count() <= w + 10, "line too wide for {w}");
            }
        }
    }
}
