//! The open planning layer: every parallelisation scheme — the paper's
//! PAC+ hybrid data+pipeline planner and all the baseline systems it is
//! compared against — implements the [`ParallelismStrategy`] trait and is
//! looked up by name through a [`StrategyRegistry`].
//!
//! Before this layer existed, PAC+ planning lived in a free function
//! (`planner::dp::plan`) while every baseline's plan construction was
//! hand-rolled inside a closed `System` enum match ladder in `baselines`.
//! Adding a scenario (device churn, multi-tenant adapters, split
//! placement à la PrivateLoRA — see PAPERS.md) meant editing that ladder.
//! Now a scheme is one trait impl plus one `register` call; the
//! conformance suite (`tests/strategy_conformance.rs`) and the experiment
//! harnesses pick registered strategies up automatically.
//!
//! A strategy answers three questions:
//!
//! 1. **[`options`](ParallelismStrategy::options)** — how a [`TrainJob`]
//!    maps onto planner knobs (micro-batching policy, stage/group
//!    constraints);
//! 2. **[`plan`](ParallelismStrategy::plan)** — how to place the model on
//!    the cluster for one mini-batch (a [`Plan`]);
//! 3. **[`run`](ParallelismStrategy::run)** — how a whole fine-tuning run
//!    unfolds (default: plan once, then the shared epoch/cache timing
//!    model in [`sched::training`](crate::sched::training)).
//!
//! All strategies share one profile/cost substrate and the same 1F1B
//! event simulator, so measured differences come purely from
//! architecture — the property the paper's §VI comparisons rely on.

mod registry;
mod systems;

pub use registry::StrategyRegistry;
pub use systems::{
    Asteroid, DataParallel, HetPipe, PacHomo, PacPlus, PipelineParallel, Standalone,
};

use crate::cluster::Env;
use crate::planner::{Plan, PlanError, PlannerOptions};
use crate::profiler::Profile;
use crate::sched::training::{self, RunReport};

/// Shared experiment shape: GLUE-style task on an edge cluster.
#[derive(Debug, Clone, Copy)]
pub struct TrainJob {
    pub samples: usize,
    pub epochs: usize,
    pub seq: usize,
    pub minibatch: usize,
}

impl TrainJob {
    pub fn new(samples: usize, epochs: usize, seq: usize, minibatch: usize) -> TrainJob {
        TrainJob { samples, epochs, seq, minibatch }
    }
}

/// A pluggable parallel fine-tuning scheme.
///
/// Implementations must be stateless (or internally synchronized):
/// the registry hands out shared references and the experiment harnesses
/// call strategies from worker threads.
pub trait ParallelismStrategy: Send + Sync {
    /// Canonical display name (stable: used in tables, JSON and the CLI).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`StrategyRegistry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `pacpp strategies` and docs.
    fn description(&self) -> &str {
        ""
    }

    /// The planner configuration this strategy uses for `job` on `env`
    /// (micro-batching policy, stage/group constraints).
    fn options(&self, env: &Env, job: &TrainJob) -> PlannerOptions;

    /// Construct the per-mini-batch execution plan.
    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError>;

    /// Simulate a complete fine-tuning run of `job` on `env`.
    ///
    /// The default implementation plans once and extends to epochs with
    /// the shared timing model (hybrid epoch 1, then the cached
    /// data-parallel phase when the method supports it). Strategies whose
    /// run-level semantics differ from their plan (replicated DP,
    /// asynchronous parameter servers) override this.
    fn run(&self, profile: &Profile, env: &Env, job: TrainJob) -> Result<RunReport, PlanError> {
        let opts = self.options(env, &job);
        let plan = self.plan(profile, env, &opts)?;
        Ok(training::report_from_plan(plan, profile, env, job.samples, job.epochs))
    }
}
