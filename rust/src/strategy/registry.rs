//! Lookup-by-name registry of [`ParallelismStrategy`] implementations.

use std::sync::Arc;

use super::{
    Asteroid, DataParallel, HetPipe, PacHomo, PacPlus, ParallelismStrategy, PipelineParallel,
    Standalone,
};
use crate::util::registry::Registry;

impl crate::util::registry::Registered for dyn ParallelismStrategy {
    fn name(&self) -> &str {
        ParallelismStrategy::name(self)
    }
    fn aliases(&self) -> &'static [&'static str] {
        ParallelismStrategy::aliases(self)
    }
    fn describe(&self) -> &str {
        self.description()
    }
}

/// An ordered, name-addressed collection of strategies — a
/// [`Registry`] instantiation (uniform resolution semantics; see
/// [`crate::util::registry`]).
///
/// Registration order is preserved (it is the column order of the
/// experiment tables). Canonical names are matched case-insensitively;
/// each strategy may additionally expose lowercase
/// [`aliases`](ParallelismStrategy::aliases) for CLI ergonomics
/// (`"dp"`, `"eddl"`, `"pac-homo"`, ...).
pub type StrategyRegistry = Registry<dyn ParallelismStrategy>;

impl StrategyRegistry {
    /// An empty registry (build-your-own experiment line-ups).
    pub fn empty() -> StrategyRegistry {
        Registry::new("strategy")
    }

    /// All seven systems of the paper's evaluation, in Table V / Fig. 12
    /// order: Standalone, DP (EDDL), PP (Eco-FL), PAC+, PAC+ (Homo),
    /// Asteroid, HetPipe.
    pub fn with_defaults() -> StrategyRegistry {
        let mut r = StrategyRegistry::empty();
        r.register(Arc::new(Standalone));
        r.register(Arc::new(DataParallel));
        r.register(Arc::new(PipelineParallel));
        r.register(Arc::new(PacPlus));
        r.register(Arc::new(PacHomo));
        r.register(Arc::new(Asteroid));
        r.register(Arc::new(HetPipe));
        r
    }
}

impl Default for StrategyRegistry {
    fn default() -> Self {
        StrategyRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Env;
    use crate::planner::{Plan, PlanError, PlannerOptions};
    use crate::profiler::Profile;
    use crate::strategy::TrainJob;

    #[test]
    fn defaults_cover_the_paper_lineup() {
        let r = StrategyRegistry::with_defaults();
        assert_eq!(
            r.names(),
            vec![
                "Standalone",
                "DP (EDDL)",
                "PP (Eco-FL)",
                "PAC+",
                "PAC+ (Homo)",
                "Asteroid",
                "HetPipe"
            ]
        );
    }

    #[test]
    fn lookup_by_name_and_alias() {
        let r = StrategyRegistry::with_defaults();
        for (query, want) in [
            ("pac+", "PAC+"),
            ("PAC+", "PAC+"),
            ("pacplus", "PAC+"),
            ("dp", "DP (EDDL)"),
            ("eddl", "DP (EDDL)"),
            ("pp", "PP (Eco-FL)"),
            ("eco-fl", "PP (Eco-FL)"),
            ("standalone", "Standalone"),
            ("pac-homo", "PAC+ (Homo)"),
            ("asteroid", "Asteroid"),
            ("HetPipe", "HetPipe"),
        ] {
            assert_eq!(r.get(query).map(|s| s.name()), Some(want), "query {query:?}");
        }
        assert!(r.get("zero-3").is_none());
    }

    #[test]
    fn register_replaces_by_name() {
        struct Shadow;
        impl crate::strategy::ParallelismStrategy for Shadow {
            fn name(&self) -> &str {
                "PAC+"
            }
            fn options(&self, _env: &Env, _job: &TrainJob) -> PlannerOptions {
                PlannerOptions::default()
            }
            fn plan(
                &self,
                _profile: &Profile,
                _env: &Env,
                _opts: &PlannerOptions,
            ) -> Result<Plan, PlanError> {
                Err(PlanError::NoDevices)
            }
        }
        let mut r = StrategyRegistry::with_defaults();
        let n = r.len();
        r.register(Arc::new(Shadow));
        assert_eq!(r.len(), n, "replace, not append");
        let p = Profile::new(
            crate::model::graph::LayerGraph::new(crate::model::ModelSpec::tiny()),
            crate::model::Method::pa(false),
            crate::model::Precision::FP32,
            16,
        );
        let err = r
            .get("pac+")
            .unwrap()
            .plan(&p, &Env::env_a(), &PlannerOptions::default())
            .unwrap_err();
        assert_eq!(err, PlanError::NoDevices);
    }
}
