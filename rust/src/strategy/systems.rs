//! The seven built-in strategies (paper §VI-A "Baseline Methods" and
//! §VI-C), ported from the former closed `System` match ladder in
//! `baselines`:
//!
//! * [`Standalone`] — one edge device hosting the whole model.
//! * [`DataParallel`] (EDDL \[38\]) — classic data parallelism: every
//!   device holds a full replica; the mini-batch is split across devices;
//!   gradients are AllReduced. Mini-batch granularity (no
//!   micro-batching).
//! * [`PipelineParallel`] (Eco-FL \[39\]) — pure pipeline parallelism:
//!   |D| stages, one device each, 4 micro-batches per mini-batch.
//! * [`PacPlus`] — the paper's hybrid planner (this repo's `planner`).
//! * [`PacHomo`] — PAC+ without heterogeneity awareness (ablation).
//! * [`Asteroid`] \[48\] — hybrid pipeline parallelism like PAC+, but
//!   designed for full-parameter fine-tuning (no PEFT co-design, no
//!   activation cache).
//! * [`HetPipe`] \[49\] — virtual workers (intra-worker PP) +
//!   asynchronous inter-worker DP through a parameter server; the async
//!   PS traffic of full-model gradients is its bottleneck on a LAN.
//!
//! The plan/run arithmetic is moved, not rewritten — the port preserved
//! each system's numbers by carrying the code over verbatim. What the
//! tests enforce continuously: enum-adapter and registry lookups
//! dispatch to the same strategy (`baselines` golden test), the σ-search
//! is bitwise threading-invariant (`planner::dp` golden test), and the
//! paper-shape orderings / OOM patterns hold (`baselines`, `exp`
//! tests). Absolute pre-refactor outputs are not pinned.

use crate::cluster::{Device, DeviceKind, Env};
use crate::planner::{self, Plan, PlanError, PlannerOptions, StagePlan};
use crate::profiler::Profile;
use crate::sched::simulate_minibatch;
use crate::sched::training::{self, RunReport};

use super::{ParallelismStrategy, TrainJob};

/// Micro-batches per mini-batch used by every pipelined system (§VI-B).
const MICROBATCHES: usize = 4;

fn pipelined_options(job: &TrainJob, hetero_aware: bool) -> PlannerOptions {
    PlannerOptions {
        microbatch: (job.minibatch / MICROBATCHES).max(1),
        n_microbatches: MICROBATCHES,
        hetero_aware,
        // strategy-driven runs are fanned out at the cell level by the
        // experiment harnesses (util::par_map), so the inner σ-search
        // stays serial to avoid cores × σ thread oversubscription (and
        // one t_memo allocation per worker); callers wanting a threaded
        // search for a single plan override search_threads explicitly
        // (the CLI's --threads, the planner benches)
        search_threads: Some(1),
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// replicated execution (Standalone / EDDL-DP)
// ---------------------------------------------------------------------------

/// Synthesize the replicated (whole-model-per-device) plan: the first `n`
/// devices each host the **entire** model and process whole mini-batches
/// independently. Returns the single-replica reporting plan plus the
/// numbers the epoch model needs: (plan, slowest replica time, AllReduce
/// time).
fn replicated_plan(
    profile: &Profile,
    env: &Env,
    minibatch: usize,
    n: usize,
) -> Result<(Plan, f64, f64), PlanError> {
    if env.devices.is_empty() || n == 0 {
        return Err(PlanError::NoDevices);
    }
    let l = profile.graph.len();
    let devices: Vec<_> = env.devices.iter().take(n).cloned().collect();
    // OOM check: every replica hosts all blocks with a full mini-batch.
    let mem = profile.span_mem_bytes(0, l, minibatch, 1);
    for d in &devices {
        if mem > d.mem_budget() {
            return Err(PlanError::InsufficientMemory);
        }
    }
    // per-replica mini-batch compute time; the round is paced by the
    // slowest replica (synchronous DP).
    let slowest = devices
        .iter()
        .map(|d| profile.span_time(d, 0, l, minibatch))
        .fold(0.0f64, f64::max);
    let trainable = profile.graph.span_trainable_bytes(0, l, profile.method);
    let allreduce = env.network.allreduce_time(trainable, n);

    let stages = devices
        .iter()
        .map(|d| StagePlan {
            range: (0, l),
            devices: vec![d.clone()],
            dispatch: vec![minibatch],
            e_f: slowest,
            e_b: slowest,
            peak_mem: mem,
            allreduce,
        })
        .take(1)
        .collect();
    let plan = Plan {
        stages,
        microbatches: 1,
        microbatch_size: minibatch,
        phase_latency: (0.0, slowest, allreduce),
        minibatch_time: slowest + allreduce,
    };
    Ok((plan, slowest, allreduce))
}

/// Standalone / EDDL-DP run model: adapter/trainable gradients are
/// AllReduced after every round; throughput scales with replicas, memory
/// per device does not.
fn replicated_run(
    profile: &Profile,
    env: &Env,
    job: TrainJob,
    n: usize,
) -> Result<RunReport, PlanError> {
    let (plan, slowest, allreduce) = replicated_plan(profile, env, job.minibatch, n)?;
    let rounds = (job.samples as f64 / (n * job.minibatch) as f64).ceil();
    let epoch1 = rounds * (slowest + allreduce);

    let (redistribution, epoch_cached) = if profile.method.skips_backbone_with_cache()
        && job.epochs > 1
    {
        let redis = training::redistribution_time(profile, env, job.samples);
        let cached = training::epoch_time_cached(profile, env, job.samples, job.minibatch);
        (redis, cached)
    } else {
        (0.0, epoch1)
    };

    Ok(RunReport {
        plan,
        epoch1,
        redistribution,
        epoch_cached,
        epochs: job.epochs,
        total: epoch1 + redistribution + epoch_cached * (job.epochs - 1) as f64,
    })
}

/// One edge device hosting the whole model.
#[derive(Debug, Clone, Copy, Default)]
pub struct Standalone;

impl ParallelismStrategy for Standalone {
    fn name(&self) -> &str {
        "Standalone"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["standalone", "solo", "single"]
    }

    fn description(&self) -> &str {
        "one edge device hosts and fine-tunes the whole model"
    }

    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        PlannerOptions { microbatch: job.minibatch, n_microbatches: 1, ..Default::default() }
    }

    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        replicated_plan(profile, env, opts.microbatch, 1).map(|(p, _, _)| p)
    }

    fn run(&self, profile: &Profile, env: &Env, job: TrainJob) -> Result<RunReport, PlanError> {
        replicated_run(profile, env, job, 1)
    }
}

/// EDDL-style data parallelism: full replica per device, mini-batch
/// granularity ("fine-tuned strictly at the mini-batch granularity",
/// §VI-B).
#[derive(Debug, Clone, Copy, Default)]
pub struct DataParallel;

impl ParallelismStrategy for DataParallel {
    fn name(&self) -> &str {
        "DP (EDDL)"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["dp", "eddl", "data-parallel"]
    }

    fn description(&self) -> &str {
        "full replica per device, gradients AllReduced every mini-batch (EDDL [38])"
    }

    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        PlannerOptions { microbatch: job.minibatch, n_microbatches: 1, ..Default::default() }
    }

    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        replicated_plan(profile, env, opts.microbatch, env.n()).map(|(p, _, _)| p)
    }

    fn run(&self, profile: &Profile, env: &Env, job: TrainJob) -> Result<RunReport, PlanError> {
        replicated_run(profile, env, job, env.n())
    }
}

// ---------------------------------------------------------------------------
// pure pipeline parallelism (Eco-FL)
// ---------------------------------------------------------------------------

/// Eco-FL-style even split: the block chain is cut into |D| **even**
/// contiguous stages (Eco-FL balances layer counts, not profiled times),
/// one device per stage. OOM if any stage exceeds its device's budget at
/// its 1F1B in-flight depth.
fn even_pp_plan(
    profile: &Profile,
    env: &Env,
    beta: usize,
    m: usize,
) -> Result<Plan, PlanError> {
    if env.devices.is_empty() {
        return Err(PlanError::NoDevices);
    }
    let l = profile.graph.len();
    let n = env.n().min(l);

    // even split: base blocks per stage, remainder spread from the front
    let base = l / n;
    let rem = l % n;
    let mut stages = Vec::with_capacity(n);
    let mut cur = 0usize;
    for (i, d) in env.devices.iter().take(n).enumerate() {
        let k = base + usize::from(i < rem);
        let (x, y) = (cur, cur + k);
        cur = y;
        let in_flight = (n - i).min(m);
        let mem = profile.span_mem_bytes(x, y, beta, in_flight);
        if mem > d.mem_budget() {
            return Err(PlanError::InsufficientMemory);
        }
        let e_f: f64 = (x..y).map(|b| profile.t_f(d, b, beta)).sum();
        let e_b: f64 = (x..y).map(|b| profile.t_b(d, b, beta)).sum();
        let allreduce = 0.0; // single device per stage: nothing to reduce
        stages.push(StagePlan {
            range: (x, y),
            devices: vec![d.clone()],
            dispatch: vec![beta],
            e_f,
            e_b,
            peak_mem: mem,
            allreduce,
        });
    }
    Ok(Plan {
        stages,
        microbatches: m,
        microbatch_size: beta,
        phase_latency: (0.0, 0.0, 0.0),
        minibatch_time: 0.0,
    })
}

/// Pure pipeline parallelism with 1F1B scheduling (Eco-FL \[39\]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineParallel;

impl ParallelismStrategy for PipelineParallel {
    fn name(&self) -> &str {
        "PP (Eco-FL)"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pp", "eco-fl", "pipeline-parallel"]
    }

    fn description(&self) -> &str {
        "even layer split, one device per stage, 4 micro-batches, 1F1B (Eco-FL [39])"
    }

    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        pipelined_options(job, true)
    }

    /// The run model is the trait default (plan + shared epoch/cache
    /// report): `even_pp_plan` already prices the even split, and the
    /// simulated mini-batch time recorded here is exactly what
    /// `report_from_plan`'s hybrid epoch model re-derives.
    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        let mut plan = even_pp_plan(profile, env, opts.microbatch, opts.n_microbatches)?;
        plan.minibatch_time = simulate_minibatch(&plan, profile, &env.network).minibatch_time;
        Ok(plan)
    }
}

// ---------------------------------------------------------------------------
// the PAC planner family
// ---------------------------------------------------------------------------

/// The paper's hybrid data+pipeline planner (Eq. 3–7, Algorithm 1).
#[derive(Debug, Clone, Copy, Default)]
pub struct PacPlus;

impl ParallelismStrategy for PacPlus {
    fn name(&self) -> &str {
        "PAC+"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pac+", "pac", "pacplus", "pac-plus", "hybrid"]
    }

    fn description(&self) -> &str {
        "hybrid data+pipeline DP planner with heterogeneity-aware dispatch (this paper)"
    }

    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        pipelined_options(job, true)
    }

    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        planner::plan(profile, env, opts)
    }
}

/// PAC+ without heterogeneity awareness (the Fig. 12 ablation): samples
/// are dispatched evenly and every group member is priced at the slowest
/// member's speed.
#[derive(Debug, Clone, Copy, Default)]
pub struct PacHomo;

impl ParallelismStrategy for PacHomo {
    fn name(&self) -> &str {
        "PAC+ (Homo)"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pac-homo", "pac+homo", "homo"]
    }

    fn description(&self) -> &str {
        "PAC+ with heterogeneity-blind even dispatch (ablation)"
    }

    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        pipelined_options(job, false)
    }

    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        planner::plan(profile, env, opts)
    }
}

/// Asteroid \[48\]: hybrid pipeline parallelism like PAC+, but designed
/// for full-parameter fine-tuning — callers pair it with a
/// `Method::FullFT` profile (no PEFT co-design, no activation cache).
#[derive(Debug, Clone, Copy, Default)]
pub struct Asteroid;

impl ParallelismStrategy for Asteroid {
    fn name(&self) -> &str {
        "Asteroid"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["asteroid"]
    }

    fn description(&self) -> &str {
        "hybrid pipeline planner for full-parameter fine-tuning (Asteroid [48])"
    }

    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        pipelined_options(job, true)
    }

    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        planner::plan(profile, env, opts)
    }
}

// ---------------------------------------------------------------------------
// HetPipe
// ---------------------------------------------------------------------------

/// Group `env`'s devices by kind into virtual workers (max 4 per worker),
/// preserving HetPipe's evaluation grouping order.
fn hetpipe_groups(env: &Env) -> Vec<Vec<Device>> {
    let mut groups: Vec<Vec<Device>> = Vec::new();
    for kind in [DeviceKind::Tx2H, DeviceKind::Tx2L, DeviceKind::NanoH, DeviceKind::NanoL] {
        let ds: Vec<_> = env.devices.iter().filter(|d| d.kind == kind).cloned().collect();
        for chunk in ds.chunks(4) {
            if !chunk.is_empty() {
                groups.push(chunk.to_vec());
            }
        }
    }
    groups
}

fn hetpipe_worker_env(env: &Env, group: &[Device]) -> Env {
    Env {
        name: format!("hetpipe-worker-{}", group[0].kind.name()),
        devices: group
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, mut d)| {
                d.id = i;
                d
            })
            .collect(),
        network: env.network,
    }
}

/// HetPipe \[49\]: virtual workers run pure PP internally; workers train
/// asynchronously against a parameter server that serializes full
/// trainable-gradient push/pull on the LAN. Wave-based staleness costs a
/// utilization factor.
#[derive(Debug, Clone, Copy, Default)]
pub struct HetPipe;

impl HetPipe {
    const STALENESS_UTILIZATION: f64 = 0.85;

    fn worker_options(base: &PlannerOptions, worker: &Env) -> PlannerOptions {
        PlannerOptions {
            fixed_stages: Some(worker.n()),
            max_group: Some(1),
            ..base.clone()
        }
    }
}

impl ParallelismStrategy for HetPipe {
    fn name(&self) -> &str {
        "HetPipe"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["hetpipe"]
    }

    fn description(&self) -> &str {
        "virtual-worker PP + async parameter-server DP with staleness (HetPipe [49])"
    }

    /// Per-worker stage/group constraints are applied internally (each
    /// virtual worker plans over its own sub-environment).
    fn options(&self, _env: &Env, job: &TrainJob) -> PlannerOptions {
        pipelined_options(job, true)
    }

    /// The reporting plan of the first virtual worker able to host the
    /// model (the run model aggregates all workers' throughput).
    fn plan(
        &self,
        profile: &Profile,
        env: &Env,
        opts: &PlannerOptions,
    ) -> Result<Plan, PlanError> {
        if env.devices.is_empty() {
            return Err(PlanError::NoDevices);
        }
        for g in hetpipe_groups(env) {
            let sub = hetpipe_worker_env(env, &g);
            if let Ok(p) = planner::plan(profile, &sub, &Self::worker_options(opts, &sub)) {
                return Ok(p);
            }
        }
        Err(PlanError::InsufficientMemory)
    }

    fn run(&self, profile: &Profile, env: &Env, job: TrainJob) -> Result<RunReport, PlanError> {
        if env.devices.is_empty() {
            return Err(PlanError::NoDevices);
        }
        let groups = hetpipe_groups(env);

        let mut agg_throughput = 0.0; // samples/s across workers
        let mut any_plan: Option<RunReport> = None;
        for g in &groups {
            let sub = hetpipe_worker_env(env, g);
            let opts = Self::worker_options(&pipelined_options(&job, true), &sub);
            match training::finetune(profile, &sub, &opts, job.samples, 1) {
                Ok(r) => {
                    let mb_samples = r.plan.minibatch_samples() as f64;
                    let mb_time = r.epoch1 / (job.samples as f64 / mb_samples).ceil();
                    agg_throughput += mb_samples / mb_time;
                    if any_plan.is_none() {
                        any_plan = Some(r);
                    }
                }
                Err(_) => continue, // this worker cannot host the model
            }
        }
        let template = any_plan.ok_or(PlanError::InsufficientMemory)?;

        // parameter-server traffic: push grads + pull params per worker
        // mini-batch. HetPipe shards the PS across the cluster, so each
        // link carries 2 x trainable / n bytes per sync.
        let trainable_bytes = profile.method.trainable_params(&profile.graph.spec) * 4;
        let minibatches_per_epoch = (job.samples as f64 / job.minibatch as f64).ceil();
        let ps_epoch = minibatches_per_epoch * groups.len() as f64
            * (2.0 * trainable_bytes as f64 / env.n().max(1) as f64 / env.network.bandwidth);

        let compute_epoch =
            job.samples as f64 / (agg_throughput * Self::STALENESS_UTILIZATION);
        let epoch = compute_epoch.max(ps_epoch);
        Ok(RunReport {
            plan: template.plan,
            epoch1: epoch,
            redistribution: 0.0,
            epoch_cached: epoch,
            epochs: job.epochs,
            total: epoch * job.epochs as f64,
        })
    }
}
