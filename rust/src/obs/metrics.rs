//! `obs::metrics` — a typed registry of named counters, gauges and
//! streaming histograms.
//!
//! The simulators used to staple observe counters straight onto their
//! metrics structs (`events`, `oracle_hits`, …). Those fields survive —
//! they are the public accounting surface — but the *live* values now
//! flow through this registry: each run constructs one [`Metrics`],
//! registers its counters by name (or adopts counters owned by a
//! collaborator like [`crate::fleet::StrategyOracle`]), and reads the
//! registry back when assembling its metrics struct. The registry is a
//! pure accounting layer: it never influences simulation decisions, so
//! same-seed runs stay bit-identical whether or not anyone looks.
//!
//! Counters are shared handles ([`Counter`], an `Rc<Cell<u64>>`): the
//! hot loop increments through the same cell the registry reads, so
//! there is no sync point and no double bookkeeping. Histograms reuse
//! [`QuantileSketch`] — exact below [`SKETCH_EXACT_LIMIT`]
//! observations, streaming P² above it — so a million-sample run never
//! materialises its sample vector.

use crate::util::json::Json;
use crate::util::stats::{QuantileSketch, SKETCH_EXACT_LIMIT};
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

/// The quantiles every registry histogram tracks.
pub const HIST_QUANTILES: [f64; 3] = [0.50, 0.95, 0.99];

/// A named monotone counter: a cheap shared handle (`Rc<Cell<u64>>`)
/// that both the hot loop and the [`Metrics`] registry can hold.
/// Cloning shares the underlying cell.
#[derive(Clone, Debug, Default)]
pub struct Counter(Rc<Cell<u64>>);

impl Counter {
    /// A detached counter at zero (adopt it into a registry with
    /// [`Metrics::adopt_counter`] to make it readable by name).
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.0.set(self.0.get() + 1);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.set(self.0.get() + n);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.get()
    }
}

/// One run's registry of named counters, gauges and histograms.
///
/// Interior-mutable (`&self` everywhere) so a registry can be threaded
/// through code that already borrows the simulator state; not `Sync` —
/// each parallel worker builds its own.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: RefCell<BTreeMap<String, Counter>>,
    gauges: RefCell<BTreeMap<String, f64>>,
    hists: RefCell<BTreeMap<String, QuantileSketch>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Register-or-get the counter called `name`, returning a shared
    /// handle to it.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters
            .borrow_mut()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Adopt an externally owned counter under `name`: the registry
    /// holds a handle to the *same* cell, so later increments through
    /// either handle are visible to both. Replaces any counter already
    /// registered under that name.
    pub fn adopt_counter(&self, name: &str, counter: &Counter) {
        self.counters
            .borrow_mut()
            .insert(name.to_string(), counter.clone());
    }

    /// Current value of the counter called `name` (0 if unregistered).
    pub fn value(&self, name: &str) -> u64 {
        self.counters.borrow().get(name).map_or(0, Counter::get)
    }

    /// Set the gauge called `name` (last write wins).
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.borrow_mut().insert(name.to_string(), value);
    }

    /// Current value of the gauge called `name`.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.borrow().get(name).copied()
    }

    /// Feed one observation into the histogram called `name`
    /// (registered on first use, tracking [`HIST_QUANTILES`]).
    pub fn observe(&self, name: &str, x: f64) {
        self.hists
            .borrow_mut()
            .entry(name.to_string())
            .or_insert_with(|| QuantileSketch::new(&HIST_QUANTILES, SKETCH_EXACT_LIMIT))
            .add(x);
    }

    /// Number of observations the histogram called `name` has seen.
    pub fn hist_len(&self, name: &str) -> usize {
        self.hists.borrow().get(name).map_or(0, QuantileSketch::len)
    }

    /// Fold another registry's counters and gauges into this one:
    /// counter values are *added* (so repeated runs accumulate), gauges
    /// are overwritten. Histograms are per-run state and do not merge.
    pub fn absorb(&self, other: &Metrics) {
        for (name, c) in other.counters.borrow().iter() {
            self.counter(name).add(c.get());
        }
        for (name, &v) in other.gauges.borrow().iter() {
            self.set_gauge(name, v);
        }
    }

    /// The registry as JSON: `{"counters": {..}, "gauges": {..},
    /// "histograms": {name: {count, p50, p95, p99}}}` — deterministic
    /// key order courtesy of the BTreeMaps.
    pub fn snapshot(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .borrow()
            .iter()
            .map(|(k, c)| (k.clone(), Json::from(c.get())))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .borrow()
            .iter()
            .map(|(k, &v)| (k.clone(), Json::from(v)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .hists
            .borrow()
            .iter()
            .map(|(k, sketch)| {
                let qs = sketch.quantile_many(&HIST_QUANTILES);
                let mut h = vec![("count".to_string(), Json::from(sketch.len()))];
                for (&q, v) in HIST_QUANTILES.iter().zip(qs) {
                    let key = format!("p{:02}", (q * 100.0).round() as u64);
                    h.push((key, v.map_or(Json::Null, Json::from)));
                }
                (k.clone(), Json::Obj(h.into_iter().collect()))
            })
            .collect();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(hists)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_share_their_cell() {
        let m = Metrics::new();
        let a = m.counter("events");
        let b = m.counter("events");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(m.value("events"), 3);
        assert_eq!(m.value("missing"), 0);
    }

    #[test]
    fn adopted_counters_stay_live() {
        let owned = Counter::new();
        owned.add(5);
        let m = Metrics::new();
        m.adopt_counter("oracle_hits", &owned);
        owned.inc();
        assert_eq!(m.value("oracle_hits"), 6);
    }

    #[test]
    fn absorb_adds_counters_and_overwrites_gauges() {
        let a = Metrics::new();
        a.counter("events").add(10);
        a.set_gauge("pool", 4.0);
        let b = Metrics::new();
        b.counter("events").add(7);
        b.set_gauge("pool", 8.0);
        a.absorb(&b);
        assert_eq!(a.value("events"), 17);
        assert_eq!(a.gauge("pool"), Some(8.0));
    }

    #[test]
    fn snapshot_has_stable_shape() {
        let m = Metrics::new();
        m.counter("events").add(3);
        m.set_gauge("devices", 8.0);
        for i in 0..100 {
            m.observe("latency", i as f64);
        }
        let snap = m.snapshot();
        let text = snap.to_string_compact();
        let back = Json::parse(&text).unwrap();
        let at = |path: &[&str]| -> f64 {
            path.iter()
                .fold(&back, |j, k| j.get(k).unwrap_or_else(|| panic!("missing {k}")))
                .as_f64()
                .unwrap()
        };
        assert_eq!(at(&["counters", "events"]), 3.0);
        assert_eq!(at(&["gauges", "devices"]), 8.0);
        assert_eq!(at(&["histograms", "latency", "count"]), 100.0);
        assert!(at(&["histograms", "latency", "p50"]) > 0.0);
        assert_eq!(m.hist_len("latency"), 100);
    }
}
