//! `obs` — the deterministic observability layer: typed metrics
//! ([`metrics`]), virtual-time span/event tracing ([`trace`]) and
//! wall-clock phase timers ([`timer`]), tied together by the
//! zero-cost-when-disabled [`Observer`] handle the simulators thread
//! through their loops. The consumer side lives in [`analyze`]
//! (offline trace analysis: per-category aggregates, critical paths,
//! gap accounting — `pacpp trace summarize`) and [`regress`]
//! (benchmark history + deterministic regression gating —
//! `pacpp bench record|compare|trend`).
//!
//! Design rules, in priority order:
//!
//! 1. **Never perturb the run.** Observation is read-only: no RNG
//!    draws, no float arithmetic feeding back into decisions, no
//!    reordering. Tracing on vs. off yields bit-identical
//!    [`crate::fleet::FleetMetrics`] / [`crate::fed::FedMetrics`]
//!    (property-pinned in `tests/prop_invariants.rs`).
//! 2. **Free when off.** [`Observer::disabled`] is a `None`; every
//!    recording call is one predictable branch. The `bench_fleet`
//!    `fleet_event_loop_100k_jobs` case gates the disabled path.
//! 3. **Bounded when on.** The trace ring has fixed capacity and a
//!    sampling knob ([`Observer::with`]), so a 1M-job run traces its
//!    tail instead of exhausting memory.
//!
//! Entry points: `pacpp fleet|fed|learn --trace-out FILE
//! --trace-sample N` on the CLI, or the library's `*_observed`
//! variants ([`crate::fleet::simulate_fleet_observed`],
//! [`crate::fed::simulate_fed_observed`],
//! [`crate::learn::train_observed`]). See the crate docs ("Adding an
//! instrumentation point") for how to record from new code.

pub mod analyze;
pub mod metrics;
pub mod regress;
pub mod timer;
pub mod trace;

pub use analyze::{analyze, Analysis, TraceDoc};
pub use metrics::{Counter, Metrics, HIST_QUANTILES};
pub use regress::{compare_to_baseline, compare_to_history, Baseline, BenchHistory};
pub use timer::{PhaseGuard, PhaseStat, Timers};
pub use trace::{TraceEvent, TraceRing, DEFAULT_TRACE_CAPACITY};

use crate::util::json::Json;
use std::cell::RefCell;

/// The handle the simulators carry: either disabled (a `None`, every
/// call a no-op branch) or an enabled recorder owning a trace ring,
/// phase timers and an accumulating metrics registry.
#[derive(Debug, Default)]
pub struct Observer {
    state: Option<Box<ObsState>>,
}

#[derive(Debug)]
struct ObsState {
    /// Record subjects whose id satisfies `id % sample == 0` (≥ 1).
    sample: u64,
    ring: RefCell<TraceRing>,
    timers: Timers,
    /// Per-run registries absorbed here ([`Observer::absorb`]) so a
    /// multi-run CLI invocation exports one combined snapshot.
    metrics: Metrics,
}

impl Observer {
    /// The no-op observer: records nothing, costs one branch per call.
    pub fn disabled() -> Observer {
        Observer { state: None }
    }

    /// An enabled observer recording every subject at default capacity.
    pub fn enabled() -> Observer {
        Observer::with(1, DEFAULT_TRACE_CAPACITY)
    }

    /// An enabled observer keeping 1-in-`sample` subjects (job ids,
    /// round numbers, …; clamped to ≥ 1) in a `capacity`-event ring.
    pub fn with(sample: u64, capacity: usize) -> Observer {
        Observer {
            state: Some(Box::new(ObsState {
                sample: sample.max(1),
                ring: RefCell::new(TraceRing::new(capacity)),
                timers: Timers::new(),
                metrics: Metrics::new(),
            })),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.state.is_some()
    }

    /// Whether subject `id` falls in the sampled set (false when
    /// disabled) — the gate every recording call applies itself; call
    /// it directly only to skip *building* expensive event arguments.
    #[inline]
    pub fn sampled(&self, id: u64) -> bool {
        match &self.state {
            Some(s) => id % s.sample == 0,
            None => false,
        }
    }

    /// Record an instant event at virtual time `ts` if `id` is sampled.
    #[inline]
    pub fn instant(&self, cat: &'static str, name: &'static str, id: u64, ts: f64) {
        if let Some(s) = &self.state {
            if id % s.sample == 0 {
                s.ring
                    .borrow_mut()
                    .record(TraceEvent { ts, dur: None, cat, name, id });
            }
        }
    }

    /// Record a span `[ts, ts + dur]` of virtual time if `id` is
    /// sampled.
    #[inline]
    pub fn span(&self, cat: &'static str, name: &'static str, id: u64, ts: f64, dur: f64) {
        if let Some(s) = &self.state {
            if id % s.sample == 0 {
                s.ring
                    .borrow_mut()
                    .record(TraceEvent { ts, dur: Some(dur), cat, name, id });
            }
        }
    }

    /// Run `f` under the wall-clock timer for `phase` (runs `f`
    /// untimed when disabled).
    #[inline]
    pub fn time<T>(&self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        match &self.state {
            Some(s) => {
                let _guard = s.timers.start(phase);
                f()
            }
            None => f(),
        }
    }

    /// An RAII wall-clock guard for `phase` (a no-op guard when
    /// disabled) — for phases that span `?`-bearing code.
    pub fn timer(&self, phase: &'static str) -> PhaseGuard<'_> {
        match &self.state {
            Some(s) => s.timers.start(phase),
            None => PhaseGuard::noop(),
        }
    }

    /// Fold a run's metrics registry into the observer's accumulator
    /// (counters add, gauges overwrite); no-op when disabled.
    pub fn absorb(&self, m: &Metrics) {
        if let Some(s) = &self.state {
            s.metrics.absorb(m);
        }
    }

    /// Count of trace events held, total recorded and overwritten.
    pub fn trace_counts(&self) -> (usize, u64, u64) {
        match &self.state {
            Some(s) => {
                let ring = s.ring.borrow();
                (ring.len(), ring.recorded(), ring.dropped())
            }
            None => (0, 0, 0),
        }
    }

    /// Wall-clock phase snapshot (empty when disabled).
    pub fn wall_phases(&self) -> Vec<(&'static str, PhaseStat)> {
        match &self.state {
            Some(s) => s.timers.snapshot(),
            None => Vec::new(),
        }
    }

    /// Everything recorded so far as Chrome trace-event JSON: the ring
    /// plus `otherData` carrying the sampling knob, the absorbed
    /// metrics snapshot and the wall-clock phases.
    pub fn to_chrome_json(&self) -> Json {
        match &self.state {
            Some(s) => {
                let timers: Json = crate::util::json::obj(
                    s.timers
                        .snapshot()
                        .iter()
                        .map(|(phase, stat)| {
                            (
                                *phase,
                                crate::util::json::obj(vec![
                                    ("secs", Json::from(stat.secs)),
                                    ("count", Json::from(stat.count)),
                                ]),
                            )
                        })
                        .collect(),
                );
                s.ring.borrow().to_chrome(vec![
                    ("sample", Json::from(s.sample)),
                    ("metrics", s.metrics.snapshot()),
                    ("wall", timers),
                ])
            }
            None => TraceRing::new(1).to_chrome(Vec::new()),
        }
    }

    /// Everything recorded so far as JSONL (empty when disabled).
    pub fn to_jsonl(&self) -> String {
        match &self.state {
            Some(s) => s.ring.borrow().to_jsonl(),
            None => String::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_observer_records_nothing() {
        let obs = Observer::disabled();
        assert!(!obs.is_enabled());
        assert!(!obs.sampled(0));
        obs.instant("cat", "name", 0, 1.0);
        obs.span("cat", "name", 0, 1.0, 2.0);
        let ran = obs.time("phase", || 42);
        assert_eq!(ran, 42);
        drop(obs.timer("phase"));
        assert_eq!(obs.trace_counts(), (0, 0, 0));
        assert!(obs.wall_phases().is_empty());
        assert!(obs.to_jsonl().is_empty());
        let chrome = obs.to_chrome_json();
        assert!(chrome.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn sampling_keeps_one_in_n_subjects() {
        let obs = Observer::with(3, 64);
        for id in 0..10u64 {
            obs.instant("sim.event", "tick", id, id as f64);
        }
        // ids 0, 3, 6, 9
        assert_eq!(obs.trace_counts().0, 4);
        assert!(obs.sampled(6) && !obs.sampled(7));
    }

    #[test]
    fn timers_and_metrics_surface_in_chrome_export() {
        let obs = Observer::enabled();
        obs.time("plan_search", || std::hint::black_box(17));
        let m = Metrics::new();
        m.counter("events").add(9);
        obs.absorb(&m);
        obs.span("fleet.job", "run", 4, 10.0, 5.0);
        let chrome = obs.to_chrome_json();
        let other = chrome.get("otherData").unwrap();
        assert_eq!(other.get("sample").unwrap().as_f64(), Some(1.0));
        let events = other
            .get("metrics")
            .unwrap()
            .get("counters")
            .unwrap()
            .get("events")
            .unwrap();
        assert_eq!(events.as_f64(), Some(9.0));
        let wall = other.get("wall").unwrap().get("plan_search").unwrap();
        assert_eq!(wall.get("count").unwrap().as_f64(), Some(1.0));
    }
}
