//! `obs::trace` — a bounded ring-buffer span/event recorder keyed by
//! **virtual** simulation time.
//!
//! Every recorded [`TraceEvent`] carries the sim clock (`ts`, seconds
//! of virtual time), an optional duration (present ⇒ a span, absent ⇒
//! an instant), a static category/name pair and a numeric id (job id,
//! round number, episode index, …). The buffer is a fixed-capacity
//! ring: once full, the oldest events are overwritten and tallied in
//! `dropped`, so a 1M-job run records the *tail* of its history in
//! bounded memory. Sampling lives one level up, in
//! [`crate::obs::Observer`] — the ring itself keeps everything it is
//! handed.
//!
//! Two export formats, both via [`crate::util::json`]:
//!
//! * [`TraceRing::to_chrome`] — Chrome trace-event JSON
//!   (`{"traceEvents": [...]}`), loadable in Perfetto / `chrome://
//!   tracing`; spans become `ph: "X"` complete events, instants
//!   `ph: "i"`, with the virtual clock mapped onto microseconds;
//! * [`TraceRing::to_jsonl`] — one compact JSON object per line, for
//!   `grep`/`jq`-style processing.

use crate::util::json::{obj, Json};

/// Default ring capacity: enough for every event of a mid-size run,
/// ~5 MB worst case at scale.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// One recorded span (with `dur`) or instant (without), stamped in
/// virtual seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Virtual-time start, seconds.
    pub ts: f64,
    /// Virtual duration in seconds; `None` marks an instant event.
    pub dur: Option<f64>,
    /// Coarse grouping (`"fleet.job"`, `"fed.round"`, `"sim.event"`, …).
    pub cat: &'static str,
    /// The specific transition or phase (`"dispatch"`, `"upload"`, …).
    pub name: &'static str,
    /// Subject id: job id, round number, episode index, event seq.
    pub id: u64,
}

/// Fixed-capacity ring of [`TraceEvent`]s: O(1) record, oldest-first
/// iteration, overwrite-on-full with a `dropped` tally.
#[derive(Debug)]
pub struct TraceRing {
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Next overwrite position once `buf.len() == cap`.
    head: usize,
    recorded: u64,
    dropped: u64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing {
            cap: capacity.max(1),
            buf: Vec::new(),
            head: 0,
            recorded: 0,
            dropped: 0,
        }
    }

    /// Record one event, overwriting the oldest if the ring is full.
    pub fn record(&mut self, ev: TraceEvent) {
        self.recorded += 1;
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events ever recorded (dropped ones included).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Held events, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    /// The ring as Chrome trace-event JSON. `other_data` lands in the
    /// top-level `otherData` object (run metadata, metric snapshots).
    /// The virtual clock maps to trace microseconds (1 sim second =
    /// 1 s of trace time), instants carry thread scope.
    pub fn to_chrome(&self, other_data: Vec<(&str, Json)>) -> Json {
        let events: Json = self
            .iter()
            .map(|ev| {
                let mut fields = vec![
                    ("name", Json::from(ev.name)),
                    ("cat", Json::from(ev.cat)),
                    ("ph", Json::from(if ev.dur.is_some() { "X" } else { "i" })),
                    ("ts", Json::from(ev.ts * 1e6)),
                    ("pid", Json::from(0usize)),
                    ("tid", Json::from(0usize)),
                    ("args", obj(vec![("id", Json::from(ev.id))])),
                ];
                match ev.dur {
                    Some(d) => fields.push(("dur", Json::from(d * 1e6))),
                    None => fields.push(("s", Json::from("t"))),
                }
                obj(fields)
            })
            .collect();
        let mut other = vec![
            ("recorded", Json::from(self.recorded)),
            ("dropped", Json::from(self.dropped)),
        ];
        other.extend(other_data);
        obj(vec![
            ("traceEvents", events),
            ("displayTimeUnit", Json::from("ms")),
            ("otherData", obj(other)),
        ])
    }

    /// The ring as JSONL: one compact object per held event, oldest
    /// first, closed by one trailing metadata line
    /// `{"dropped": M, "recorded": N}` — the same `recorded`/`dropped`
    /// tallies `to_chrome` embeds in `otherData`, so a JSONL consumer
    /// (`obs::analyze`) can tell a complete export from a truncated
    /// one. Event lines carry `ts`; the metadata line does not.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.iter() {
            let mut fields = vec![
                ("ts", Json::from(ev.ts)),
                ("cat", Json::from(ev.cat)),
                ("name", Json::from(ev.name)),
                ("id", Json::from(ev.id)),
            ];
            if let Some(d) = ev.dur {
                fields.push(("dur", Json::from(d)));
            }
            out.push_str(&obj(fields).to_string_compact());
            out.push('\n');
        }
        out.push_str(
            &obj(vec![
                ("recorded", Json::from(self.recorded)),
                ("dropped", Json::from(self.dropped)),
            ])
            .to_string_compact(),
        );
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: f64, id: u64) -> TraceEvent {
        TraceEvent { ts, dur: None, cat: "test", name: "tick", id }
    }

    #[test]
    fn ring_overwrites_oldest_and_tallies_drops() {
        let mut r = TraceRing::new(3);
        for i in 0..5u64 {
            r.record(ev(i as f64, i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 2);
        let ids: Vec<u64> = r.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest-first, oldest two overwritten");
    }

    #[test]
    fn chrome_export_reparses_with_span_and_instant_shapes() {
        let mut r = TraceRing::new(16);
        r.record(ev(1.0, 7));
        r.record(TraceEvent { ts: 2.0, dur: Some(0.5), cat: "fleet.job", name: "run", id: 7 });
        let json = r.to_chrome(vec![("seed", Json::from(42usize))]);
        let back = Json::parse(&json.to_string_compact()).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(events[0].get("ts").unwrap().as_f64(), Some(1e6));
        assert_eq!(events[1].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[1].get("dur").unwrap().as_f64(), Some(0.5e6));
        let other = back.get("otherData").unwrap();
        assert_eq!(other.get("seed").unwrap().as_f64(), Some(42.0));
        assert_eq!(other.get("recorded").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn jsonl_export_is_one_object_per_line_plus_meta_trailer() {
        let mut r = TraceRing::new(16);
        r.record(ev(1.0, 0));
        r.record(ev(2.0, 1));
        let jsonl = r.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3, "two events + one metadata trailer");
        for line in &lines[..2] {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("cat").unwrap().as_str(), Some("test"));
        }
        let meta = Json::parse(lines[2]).unwrap();
        assert_eq!(meta.get("recorded").unwrap().as_u64(), Some(2));
        assert_eq!(meta.get("dropped").unwrap().as_u64(), Some(0));
        assert!(meta.get("ts").is_none(), "the trailer is not an event");
        // an empty ring still exports a self-describing trailer
        let empty = TraceRing::new(4).to_jsonl();
        let meta = Json::parse(empty.trim_end()).unwrap();
        assert_eq!(meta.get("recorded").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn jsonl_trailer_reports_overwrites() {
        let mut r = TraceRing::new(2);
        for i in 0..7u64 {
            r.record(ev(i as f64, i));
        }
        let jsonl = r.to_jsonl();
        let last = jsonl.lines().last().unwrap();
        let meta = Json::parse(last).unwrap();
        assert_eq!(meta.get("recorded").unwrap().as_u64(), Some(7));
        assert_eq!(meta.get("dropped").unwrap().as_u64(), Some(5));
    }
}
