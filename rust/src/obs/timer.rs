//! `obs::timer` — wall-clock phase timers.
//!
//! Virtual time (what [`crate::obs::trace`] records) says what the
//! *simulated* system did; these timers say where the *simulator's*
//! wall-clock went — planner σ-search vs the event loop vs training.
//! Each phase accumulates total seconds and an invocation count, so
//! "plan_search: 1.2 s over 37 calls" falls straight out. Wall-clock
//! readings are inherently non-deterministic, so they surface only in
//! report *metadata* and CLI footers — never inside the
//! equality-tested metrics structs.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

/// Accumulated wall-clock for one phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseStat {
    /// Total seconds across all invocations.
    pub secs: f64,
    /// Number of timed invocations.
    pub count: u64,
}

/// A set of named phase accumulators (interior-mutable, single-thread).
#[derive(Debug, Default)]
pub struct Timers {
    phases: RefCell<BTreeMap<&'static str, PhaseStat>>,
}

impl Timers {
    pub fn new() -> Timers {
        Timers::default()
    }

    /// Fold `secs` of wall-clock into `phase`.
    pub fn record(&self, phase: &'static str, secs: f64) {
        let mut phases = self.phases.borrow_mut();
        let stat = phases.entry(phase).or_default();
        stat.secs += secs;
        stat.count += 1;
    }

    /// Start a guard that records into `phase` when dropped.
    pub fn start<'a>(&'a self, phase: &'static str) -> PhaseGuard<'a> {
        PhaseGuard { timers: Some((self, phase, Instant::now())) }
    }

    /// All phases with their accumulated stats, name-ordered.
    pub fn snapshot(&self) -> Vec<(&'static str, PhaseStat)> {
        self.phases.borrow().iter().map(|(&k, &v)| (k, v)).collect()
    }
}

/// RAII handle from [`Timers::start`]: measures from construction to
/// drop, so early returns and `?` still get timed.
pub struct PhaseGuard<'a> {
    timers: Option<(&'a Timers, &'static str, Instant)>,
}

impl PhaseGuard<'_> {
    /// A guard that times nothing — the disabled-observer arm.
    pub fn noop() -> PhaseGuard<'static> {
        PhaseGuard { timers: None }
    }
}

impl Drop for PhaseGuard<'_> {
    fn drop(&mut self) {
        if let Some((timers, phase, start)) = self.timers.take() {
            timers.record(phase, start.elapsed().as_secs_f64());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_time_and_count() {
        let t = Timers::new();
        t.record("plan_search", 0.5);
        t.record("plan_search", 0.25);
        t.record("event_loop", 1.0);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 2);
        // BTreeMap: name-ordered
        assert_eq!(snap[0].0, "event_loop");
        assert_eq!(snap[0].1, PhaseStat { secs: 1.0, count: 1 });
        assert_eq!(snap[1].0, "plan_search");
        assert_eq!(snap[1].1, PhaseStat { secs: 0.75, count: 2 });
    }

    #[test]
    fn guard_records_on_drop_and_noop_does_not() {
        let t = Timers::new();
        {
            let _g = t.start("scoped");
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1.count, 1);
        assert!(snap[0].1.secs >= 0.0);
        drop(PhaseGuard::noop());
        assert_eq!(t.snapshot().len(), 1);
    }
}
