//! `obs::regress` — benchmark history and deterministic regression
//! gating over the `BENCH_*.json` artifacts.
//!
//! Three pieces:
//!
//! * [`extract`] — a declarative key-path walk (via
//!   [`crate::util::json::Json::path_str`]) that flattens any artifact
//!   the toolchain emits — an [`crate::exp::Report`] JSON, an array of
//!   them, a `BENCH_OUT` bench-suite dump, a Chrome trace — into named
//!   scalar series (`fleet_summary.meta.goodput`,
//!   `bench.fleet.oracle.mean`, `trace.counter.events`, …);
//! * [`BenchHistory`] — an append-only JSONL file of
//!   `{label, source, series, value}` points, one line per series per
//!   `pacpp bench record`, so trends live in the repo instead of in
//!   whoever last eyeballed a CI log;
//! * [`compare_to_baseline`] / [`compare_to_history`] — a deterministic
//!   verdict: each series is checked against a reference (a committed
//!   [`Baseline`], or the median of its last *N* history points) with
//!   a relative tolerance and an explicit better-direction, rendered as
//!   a typed [`Report`] with a machine-readable pass/fail row per
//!   series. `pacpp bench compare` exits non-zero iff any gated series
//!   regressed.
//!
//! What gets *gated* vs merely *recorded*: simulator outputs are
//! deterministic (same seed ⇒ bit-identical metrics, pinned by the
//! `tracing_never_changes_the_metrics` / shard-invariance property
//! tests), so goodput, counters and rounds-per-hour regress exactly and
//! a committed baseline transfers across machines. Wall-clock series
//! (`*.wall.*`, `bench.*`) are machine-dependent: they are recorded
//! into history for trending but excluded from
//! [`Baseline::from_series`] gating by default.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::exp::report::{Cell, ColType, Report};
use crate::util::json::{obj, Json};
use crate::util::stats::percentile;

/// Comparison tolerance floor: differences below this are noise from
/// the JSON round-trip, never a regression.
const EPS: f64 = 1e-12;

/// Which way a series is supposed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Bigger is better (goodput, events/sec, hit rates).
    Higher,
    /// Smaller is better (latencies, misses, lost work).
    Lower,
}

impl Direction {
    pub fn as_str(self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
        }
    }

    pub fn parse(s: &str) -> Option<Direction> {
        match s {
            "higher" => Some(Direction::Higher),
            "lower" => Some(Direction::Lower),
            _ => None,
        }
    }

    /// Infer a series' better-direction from its name. Suffix/stem
    /// heuristic over the vocabulary the report emitters actually use;
    /// a [`Baseline`] entry can override per series.
    pub fn infer(series: &str) -> Direction {
        const LOWER_MARKS: [&str; 18] = [
            "p50",
            "p95",
            "p99",
            "mean",
            "min",
            "max",
            "miss",
            "makespan",
            "elapsed_secs",
            "work_lost",
            "migration",
            "ckpt_overhead",
            "to_target",
            "stale",
            "dropped",
            "failed",
            "gap",
            "bubble",
        ];
        let tail = series.rsplit('.').next().unwrap_or(series);
        if LOWER_MARKS.iter().any(|m| tail.contains(m)) {
            Direction::Lower
        } else {
            Direction::Higher
        }
    }
}

/// One recorded observation of one series.
#[derive(Debug, Clone, PartialEq)]
pub struct HistoryPoint {
    /// Run label (commit sha, date, "local") — opaque, newest last.
    pub label: String,
    /// Artifact the value came from (`BENCH_fleet.json`, …).
    pub source: String,
    pub series: String,
    pub value: f64,
}

/// Append-only series store: the parsed view of `bench_history.jsonl`.
#[derive(Debug, Clone, Default)]
pub struct BenchHistory {
    /// File order — append order — which is chronological by contract.
    pub points: Vec<HistoryPoint>,
}

impl BenchHistory {
    /// Parse the JSONL text (blank lines ignored, order preserved).
    pub fn parse(text: &str) -> Result<BenchHistory> {
        let mut points = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line).with_context(|| format!("bench history: line {}", i + 1))?;
            let field = |k: &str| {
                j.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .with_context(|| format!("bench history: line {}: missing {k}", i + 1))
            };
            points.push(HistoryPoint {
                label: field("label")?,
                source: field("source")?,
                series: field("series")?,
                value: j
                    .get("value")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("bench history: line {}: missing value", i + 1))?,
            });
        }
        Ok(BenchHistory { points })
    }

    /// The JSONL lines for `points` — what `pacpp bench record` appends.
    pub fn render(points: &[HistoryPoint]) -> String {
        let mut out = String::new();
        for p in points {
            out.push_str(
                &obj(vec![
                    ("label", Json::from(p.label.as_str())),
                    ("source", Json::from(p.source.as_str())),
                    ("series", Json::from(p.series.as_str())),
                    ("value", Json::from(p.value)),
                ])
                .to_string_compact(),
            );
            out.push('\n');
        }
        out
    }

    /// All values of one series, file (= chronological) order.
    pub fn values(&self, series: &str) -> Vec<f64> {
        self.points.iter().filter(|p| p.series == series).map(|p| p.value).collect()
    }

    /// Distinct series names, sorted.
    pub fn series(&self) -> Vec<String> {
        let set: std::collections::BTreeSet<String> =
            self.points.iter().map(|p| p.series.clone()).collect();
        set.into_iter().collect()
    }
}

/// Per-series baseline entry: the reference value plus optional
/// overrides for tolerance and direction.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesSpec {
    pub value: f64,
    /// Overrides [`Baseline::tolerance`] when set.
    pub tolerance: Option<f64>,
    /// Overrides [`Direction::infer`] when set.
    pub better: Option<Direction>,
}

/// A committed regression gate: reference values with a default
/// relative tolerance.
#[derive(Debug, Clone, PartialEq)]
pub struct Baseline {
    /// Default relative tolerance (0.05 = 5% of |reference|).
    pub tolerance: f64,
    pub series: BTreeMap<String, SeriesSpec>,
}

impl Baseline {
    /// A series is *gated* (checked against the baseline / failing CI)
    /// only when deterministic: wall-clock series — `.wall.` segments
    /// and `bench.`-suite timings — are recorded for trending but vary
    /// by machine, so they never gate.
    pub fn gated(series: &str) -> bool {
        !series.contains(".wall.") && !series.starts_with("bench.")
    }

    /// Build a baseline from freshly extracted series, keeping only the
    /// gated (deterministic) ones — what `--baseline-out` writes.
    pub fn from_series(series: &[(String, f64)], tolerance: f64) -> Baseline {
        Baseline {
            tolerance,
            series: series
                .iter()
                .filter(|(name, _)| Baseline::gated(name))
                .map(|(name, value)| {
                    (name.clone(), SeriesSpec { value: *value, tolerance: None, better: None })
                })
                .collect(),
        }
    }

    pub fn from_json(j: &Json) -> Result<Baseline> {
        let tolerance = j
            .get("tolerance")
            .and_then(Json::as_f64)
            .context("baseline: missing tolerance")?;
        if tolerance.is_nan() || tolerance < 0.0 {
            bail!("baseline: tolerance must be >= 0, got {tolerance}");
        }
        let mut series = BTreeMap::new();
        for (name, spec) in j
            .get("series")
            .and_then(Json::as_obj)
            .context("baseline: missing series object")?
        {
            let value = spec
                .get("value")
                .and_then(Json::as_f64)
                .with_context(|| format!("baseline: series {name}: missing value"))?;
            let better = match spec.get("better").and_then(Json::as_str) {
                Some(s) => Some(
                    Direction::parse(s)
                        .with_context(|| format!("baseline: series {name}: bad direction {s}"))?,
                ),
                None => None,
            };
            series.insert(
                name.clone(),
                SeriesSpec {
                    value,
                    tolerance: spec.get("tolerance").and_then(Json::as_f64),
                    better,
                },
            );
        }
        Ok(Baseline { tolerance, series })
    }

    pub fn to_json(&self) -> Json {
        let series: Vec<(&str, Json)> = self
            .series
            .iter()
            .map(|(name, s)| {
                let mut fields = vec![("value", Json::from(s.value))];
                if let Some(t) = s.tolerance {
                    fields.push(("tolerance", Json::from(t)));
                }
                if let Some(b) = s.better {
                    fields.push(("better", Json::from(b.as_str())));
                }
                (name.as_str(), obj(fields))
            })
            .collect();
        obj(vec![
            ("tolerance", Json::from(self.tolerance)),
            (
                "series",
                Json::Obj(series.into_iter().map(|(k, v)| (k.to_string(), v)).collect()),
            ),
        ])
    }
}

/// Flatten one artifact into named scalar series. `name_hint` prefixes
/// report-derived series when the artifact is a bare report (reports
/// carry their own name, so the hint only matters for collision-free
/// trace series).
///
/// Recognized shapes:
///
/// * **Report JSON** (`{"name", "columns", "rows", "meta"}`), or an
///   array of them: every numeric meta entry becomes
///   `<report>.meta.<key>` (except `elapsed_secs` →
///   `<report>.wall.elapsed_secs`), plus the derived
///   `<report>.wall.events_per_sec` (events_total / elapsed) and
///   `<report>.meta.oracle_hit_rate` (hits / (hits + misses)) when the
///   inputs are present. Every numeric row cell becomes
///   `<report>.row.<label>.<column>`, where the label joins the row's
///   `Str` cells with `/` (duplicate labels get `#2`, `#3`, …);
/// * **bench suite** (`{"suite", "cases"}`, a `BENCH_OUT` dump):
///   `bench.<suite>.<case>.<mean|p50|p99|min|max>`;
/// * **Chrome trace** (`{"traceEvents", "otherData"}`):
///   `trace.recorded`, `trace.dropped`, `trace.counter.<name>`.
pub fn extract(j: &Json, name_hint: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    collect(j, name_hint, &mut out);
    out
}

fn collect(j: &Json, hint: &str, out: &mut Vec<(String, f64)>) {
    if let Some(arr) = j.as_arr() {
        for item in arr {
            collect(item, hint, out);
        }
        return;
    }
    if j.get("suite").is_some() && j.get("cases").is_some() {
        collect_bench(j, out);
    } else if j.get("traceEvents").is_some() {
        collect_trace(j, hint, out);
    } else if j.get("columns").is_some() && j.get("rows").is_some() {
        collect_report(j, out);
    }
}

fn collect_bench(j: &Json, out: &mut Vec<(String, f64)>) {
    let suite = j.get("suite").and_then(Json::as_str).unwrap_or("unnamed");
    let Some(cases) = j.get("cases").and_then(Json::as_arr) else { return };
    for case in cases {
        let name = case.get("name").and_then(Json::as_str).unwrap_or("unnamed");
        for stat in ["mean", "p50", "p99", "min", "max"] {
            if let Some(v) = case.get(stat).and_then(Json::as_f64) {
                out.push((format!("bench.{suite}.{name}.{stat}"), v));
            }
        }
    }
}

fn collect_trace(j: &Json, hint: &str, out: &mut Vec<(String, f64)>) {
    let prefix = if hint.is_empty() { "trace".to_string() } else { format!("trace.{hint}") };
    for tally in ["recorded", "dropped"] {
        if let Some(v) = j.path_str(&format!("otherData.{tally}")).and_then(Json::as_f64) {
            out.push((format!("{prefix}.{tally}"), v));
        }
    }
    if let Some(counters) =
        j.path_str("otherData.metrics.counters").and_then(Json::as_obj)
    {
        for (k, v) in counters {
            if let Some(v) = v.as_f64() {
                out.push((format!("{prefix}.counter.{k}"), v));
            }
        }
    }
}

fn collect_report(j: &Json, out: &mut Vec<(String, f64)>) {
    let Ok(report) = Report::from_json(j) else { return };
    let name = report.name.clone();
    let mut hits = None;
    let mut misses = None;
    let mut events = None;
    let mut elapsed = None;
    for (k, v) in &report.meta {
        let Ok(v) = v.parse::<f64>() else { continue };
        if !v.is_finite() {
            continue;
        }
        match k.as_str() {
            "elapsed_secs" => {
                elapsed = Some(v);
                out.push((format!("{name}.wall.elapsed_secs"), v));
            }
            _ => {
                if k == "oracle_hits_total" {
                    hits = Some(v);
                }
                if k == "oracle_misses_total" {
                    misses = Some(v);
                }
                if k == "events_total" {
                    events = Some(v);
                }
                out.push((format!("{name}.meta.{k}"), v));
            }
        }
    }
    if let (Some(e), Some(t)) = (events, elapsed) {
        if t > 0.0 {
            out.push((format!("{name}.wall.events_per_sec"), e / t));
        }
    }
    if let (Some(h), Some(m)) = (hits, misses) {
        if h + m > 0.0 {
            out.push((format!("{name}.meta.oracle_hit_rate"), h / (h + m)));
        }
    }
    // rows: label from the Str cells, values from the numeric ones
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    for row in report.rows() {
        let labels: Vec<&str> = row
            .iter()
            .filter_map(|cell| match cell {
                Cell::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        let mut label = if labels.is_empty() { "row".to_string() } else { labels.join("/") };
        let n = seen.entry(label.clone()).or_insert(0);
        *n += 1;
        if *n > 1 {
            label = format!("{label}#{n}");
        }
        for (col, cell) in report.columns().iter().zip(row) {
            let Some(v) = cell.as_f64() else { continue };
            out.push((format!("{name}.row.{label}.{}", col.name), v));
        }
    }
}

/// One series' comparison outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesVerdict {
    pub series: String,
    pub current: Option<f64>,
    pub reference: Option<f64>,
    pub tolerance: f64,
    pub better: Direction,
    /// `"pass"`, `"FAIL"`, `"new"` (no reference yet) or `"missing"`
    /// (reference exists, current run did not produce the series).
    pub status: &'static str,
}

impl SeriesVerdict {
    fn judge(
        series: String,
        current: Option<f64>,
        reference: Option<f64>,
        tolerance: f64,
        better: Direction,
    ) -> SeriesVerdict {
        let status = match (current, reference) {
            (None, _) => "missing",
            (Some(_), None) => "new",
            (Some(c), Some(r)) => {
                // delta thresholds handle negative references correctly
                // (a plain ratio flips the inequality for r < 0)
                let allowed = tolerance * r.abs();
                let regressed = match better {
                    Direction::Higher => c < r - allowed - EPS,
                    Direction::Lower => c > r + allowed + EPS,
                };
                if regressed {
                    "FAIL"
                } else {
                    "pass"
                }
            }
        };
        SeriesVerdict { series, current, reference, tolerance, better, status }
    }

    pub fn failed(&self) -> bool {
        self.status == "FAIL" || self.status == "missing"
    }
}

/// A full comparison: the per-series table plus the failing names.
#[derive(Debug, Clone)]
pub struct Verdict {
    pub rows: Vec<SeriesVerdict>,
}

impl Verdict {
    /// Series that regressed (or went missing) — non-empty ⇒ CI fails.
    pub fn regressions(&self) -> Vec<&str> {
        self.rows.iter().filter(|r| r.failed()).map(|r| r.series.as_str()).collect()
    }

    /// The typed report: one row per series, pass/fail in `status`.
    pub fn report(&self, title: &str) -> Report {
        let mut r = Report::new("bench_regress", title)
            .column("series", ColType::Str)
            .column("current", ColType::Float)
            .column("reference", ColType::Float)
            .column("delta_pct", ColType::Float)
            .column("tolerance", ColType::Float)
            .column("better", ColType::Str)
            .column("status", ColType::Str)
            .meta("checked", self.rows.len())
            .meta("regressed", self.regressions().len());
        for row in &self.rows {
            let delta = match (row.current, row.reference) {
                (Some(c), Some(r)) if r.abs() > EPS => Some(100.0 * (c - r) / r.abs()),
                _ => None,
            };
            r.push(vec![
                Cell::Str(row.series.clone()),
                Cell::opt(row.current, Cell::Float),
                Cell::opt(row.reference, Cell::Float),
                Cell::opt(delta, Cell::Float),
                Cell::Float(row.tolerance),
                Cell::Str(row.better.as_str().into()),
                Cell::Str(row.status.into()),
            ]);
        }
        r
    }
}

/// Gate freshly extracted series against a committed [`Baseline`].
/// Every baseline series must appear (else `"missing"`); extracted
/// series the baseline does not know are reported as `"new"` and never
/// fail; ungated (wall-clock) extractions are skipped entirely.
pub fn compare_to_baseline(current: &[(String, f64)], baseline: &Baseline) -> Verdict {
    let cur: BTreeMap<&str, f64> =
        current.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let mut rows = Vec::new();
    for (name, spec) in &baseline.series {
        rows.push(SeriesVerdict::judge(
            name.clone(),
            cur.get(name.as_str()).copied(),
            Some(spec.value),
            spec.tolerance.unwrap_or(baseline.tolerance),
            spec.better.unwrap_or_else(|| Direction::infer(name)),
        ));
    }
    for (name, value) in current {
        if Baseline::gated(name) && !baseline.series.contains_key(name) {
            rows.push(SeriesVerdict::judge(
                name.clone(),
                Some(*value),
                None,
                baseline.tolerance,
                Direction::infer(name),
            ));
        }
    }
    Verdict { rows }
}

/// Gate each series' newest history point against the median of its up
/// to `window` preceding points. A series with no preceding points is
/// `"new"`. All recorded series participate — history comparisons run
/// on one machine, so wall-clock series are meaningful here.
pub fn compare_to_history(hist: &BenchHistory, window: usize, tolerance: f64) -> Verdict {
    let mut rows = Vec::new();
    for series in hist.series() {
        let values = hist.values(&series);
        let (&current, prior) = values.split_last().expect("series() implies >= 1 point");
        let start = prior.len().saturating_sub(window.max(1));
        let mut refs: Vec<f64> = prior[start..].to_vec();
        refs.sort_by(f64::total_cmp);
        let reference = percentile(&refs, 0.5);
        let better = Direction::infer(&series);
        rows.push(SeriesVerdict::judge(series, Some(current), reference, tolerance, better));
    }
    Verdict { rows }
}

/// Trend table: per-series first/median/last over the trailing
/// `window`, newest-label column included. `filter` is a substring
/// match on the series name (empty keeps everything).
pub fn trend_report(hist: &BenchHistory, filter: &str, window: usize) -> Report {
    let mut r = Report::new("bench_trend", "Benchmark history trend")
        .column("series", ColType::Str)
        .column("points", ColType::Int)
        .column("first", ColType::Float)
        .column("median", ColType::Float)
        .column("last", ColType::Float)
        .column("change_pct", ColType::Float)
        .meta("window", window)
        .meta("labels", {
            let set: std::collections::BTreeSet<&str> =
                hist.points.iter().map(|p| p.label.as_str()).collect();
            set.len()
        });
    for series in hist.series() {
        if !filter.is_empty() && !series.contains(filter) {
            continue;
        }
        let all = hist.values(&series);
        let start = all.len().saturating_sub(window.max(1));
        let vals = &all[start..];
        let mut sorted = vals.to_vec();
        sorted.sort_by(f64::total_cmp);
        let first = vals[0];
        let last = *vals.last().expect("series() implies >= 1 point");
        let change =
            (first.abs() > EPS).then(|| 100.0 * (last - first) / first.abs());
        r.push(vec![
            Cell::Str(series),
            Cell::Int(vals.len() as i64),
            Cell::Float(first),
            Cell::opt(percentile(&sorted, 0.5), Cell::Float),
            Cell::Float(last),
            Cell::opt(change, Cell::Float),
        ]);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_json() -> Json {
        let mut r = Report::new("fleet_summary", "t")
            .column("env", ColType::Str)
            .column("policy", ColType::Str)
            .column("goodput", ColType::Float)
            .meta("jobs", 100)
            .meta("events_total", 5000)
            .meta("oracle_hits_total", 90)
            .meta("oracle_misses_total", 10)
            .meta("elapsed_secs", 2.0)
            .meta("trace", "mixed"); // non-numeric meta: ignored
        r.push(vec![Cell::Str("edge".into()), Cell::Str("fifo".into()), Cell::Float(0.9)]);
        r.push(vec![Cell::Str("edge".into()), Cell::Str("edf".into()), Cell::Float(0.95)]);
        r.to_json()
    }

    #[test]
    fn extract_flattens_report_meta_rows_and_derived_series() {
        let series = extract(&report_json(), "");
        let get = |name: &str| {
            series
                .iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| panic!("missing series {name} in {series:?}"))
        };
        assert_eq!(get("fleet_summary.meta.jobs"), 100.0);
        assert_eq!(get("fleet_summary.wall.elapsed_secs"), 2.0);
        assert_eq!(get("fleet_summary.wall.events_per_sec"), 2500.0);
        assert_eq!(get("fleet_summary.meta.oracle_hit_rate"), 0.9);
        assert_eq!(get("fleet_summary.row.edge/fifo.goodput"), 0.9);
        assert_eq!(get("fleet_summary.row.edge/edf.goodput"), 0.95);
        assert!(!series.iter().any(|(k, _)| k.contains("trace")), "non-numeric meta skipped");
    }

    #[test]
    fn extract_handles_bench_dumps_arrays_and_duplicate_row_labels() {
        let bench = Json::parse(
            r#"{"suite": "fleet", "cases": [{"name": "oracle", "mean": 0.01, "p50": 0.009}]}"#,
        )
        .unwrap();
        let series = extract(&Json::Arr(vec![bench, report_json()]), "");
        assert!(series.iter().any(|(k, v)| k == "bench.fleet.oracle.mean" && *v == 0.01));
        assert!(series.iter().any(|(k, _)| k == "fleet_summary.meta.jobs"), "array recurses");

        // duplicate labels disambiguate instead of colliding
        let mut r = Report::new("dup", "t")
            .column("env", ColType::Str)
            .column("x", ColType::Int);
        r.push(vec![Cell::Str("a".into()), Cell::Int(1)]);
        r.push(vec![Cell::Str("a".into()), Cell::Int(2)]);
        let series = extract(&r.to_json(), "");
        assert!(series.iter().any(|(k, v)| k == "dup.row.a.x" && *v == 1.0));
        assert!(series.iter().any(|(k, v)| k == "dup.row.a#2.x" && *v == 2.0));
    }

    #[test]
    fn extract_reads_chrome_trace_tallies_and_counters() {
        let mut ring = crate::obs::trace::TraceRing::new(4);
        ring.record(crate::obs::trace::TraceEvent {
            ts: 0.0,
            dur: None,
            cat: "sim.event",
            name: "tick",
            id: 0,
        });
        let json = ring.to_chrome(vec![(
            "metrics",
            obj(vec![("counters", obj(vec![("events", Json::from(12u64))]))]),
        )]);
        let series = extract(&json, "fleet");
        assert!(series.iter().any(|(k, v)| k == "trace.fleet.recorded" && *v == 1.0));
        assert!(series.iter().any(|(k, v)| k == "trace.fleet.counter.events" && *v == 12.0));
    }

    #[test]
    fn direction_inference_knows_the_vocabulary() {
        assert_eq!(Direction::infer("fleet_summary.meta.goodput"), Direction::Higher);
        assert_eq!(Direction::infer("fed_summary.meta.rounds_per_hour"), Direction::Higher);
        assert_eq!(Direction::infer("fleet_summary.row.edge/fifo.p95"), Direction::Lower);
        assert_eq!(Direction::infer("bench.fleet.oracle.mean"), Direction::Lower);
        assert_eq!(Direction::infer("x.wall.elapsed_secs"), Direction::Lower);
        assert_eq!(Direction::infer("x.meta.deadline_miss_rate"), Direction::Lower);
        assert_eq!(Direction::infer("trace.dropped"), Direction::Lower);
    }

    #[test]
    fn baseline_gate_passes_within_tolerance_and_fails_outside() {
        let base = Baseline::from_series(
            &[
                ("a.meta.goodput".to_string(), 1.0),
                ("a.row.x.p95".to_string(), 10.0),
                ("a.wall.elapsed_secs".to_string(), 5.0), // ungated, never stored
                ("bench.s.c.mean".to_string(), 0.1),      // ungated
            ],
            0.05,
        );
        assert_eq!(base.series.len(), 2, "wall/bench series excluded from the gate");

        // within tolerance both ways: pass
        let v = compare_to_baseline(
            &[("a.meta.goodput".to_string(), 0.96), ("a.row.x.p95".to_string(), 10.4)],
            &base,
        );
        assert!(v.regressions().is_empty(), "{:?}", v.rows);

        // goodput (higher-better) sinking past 5%: FAIL
        let v = compare_to_baseline(
            &[("a.meta.goodput".to_string(), 0.90), ("a.row.x.p95".to_string(), 10.0)],
            &base,
        );
        assert_eq!(v.regressions(), vec!["a.meta.goodput"]);

        // p95 (lower-better) growing past 5%: FAIL
        let v = compare_to_baseline(
            &[("a.meta.goodput".to_string(), 1.0), ("a.row.x.p95".to_string(), 11.0)],
            &base,
        );
        assert_eq!(v.regressions(), vec!["a.row.x.p95"]);

        // improvements never fail
        let v = compare_to_baseline(
            &[("a.meta.goodput".to_string(), 2.0), ("a.row.x.p95".to_string(), 1.0)],
            &base,
        );
        assert!(v.regressions().is_empty());
    }

    #[test]
    fn baseline_gate_flags_missing_and_reports_new() {
        let base = Baseline::from_series(&[("a.meta.goodput".to_string(), 1.0)], 0.05);
        let v = compare_to_baseline(&[("a.meta.fresh".to_string(), 3.0)], &base);
        assert_eq!(v.regressions(), vec!["a.meta.goodput"], "missing series regress");
        let new = v.rows.iter().find(|r| r.series == "a.meta.fresh").unwrap();
        assert_eq!(new.status, "new");
        assert!(!new.failed());
        // the report renders a row per series with the verdict pinned
        let rep = v.report("gate");
        assert_eq!(rep.n_rows(), 2);
        assert_eq!(rep.cell(0, "status"), Some(&Cell::Str("missing".into())));
        assert_eq!(rep.meta.get("regressed"), Some(&"1".to_string()));
    }

    #[test]
    fn baseline_handles_negative_references() {
        let mut base = Baseline::from_series(&[("a.meta.reward".to_string(), -10.0)], 0.10);
        base.series.get_mut("a.meta.reward").unwrap().better = Some(Direction::Higher);
        // -10.5 is within 10% of |-10|: pass; -12 is not: FAIL
        let v = compare_to_baseline(&[("a.meta.reward".to_string(), -10.5)], &base);
        assert!(v.regressions().is_empty(), "{:?}", v.rows);
        let v = compare_to_baseline(&[("a.meta.reward".to_string(), -12.0)], &base);
        assert_eq!(v.regressions(), vec!["a.meta.reward"]);
    }

    #[test]
    fn baseline_json_round_trips() {
        let mut base = Baseline::from_series(
            &[("a.meta.goodput".to_string(), 1.5), ("a.row.x.p95".to_string(), 9.0)],
            0.05,
        );
        base.series.get_mut("a.row.x.p95").unwrap().tolerance = Some(0.2);
        base.series.get_mut("a.meta.goodput").unwrap().better = Some(Direction::Higher);
        let back = Baseline::from_json(&Json::parse(&base.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, base);
        assert!(Baseline::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn history_round_trips_and_compares_newest_to_median() {
        let mk = |label: &str, value: f64| HistoryPoint {
            label: label.to_string(),
            source: "BENCH_fleet.json".to_string(),
            series: "fleet_summary.meta.goodput".to_string(),
            value,
        };
        let points: Vec<HistoryPoint> = [1.0, 1.1, 0.9, 1.0, 0.5]
            .iter()
            .enumerate()
            .map(|(i, &v)| mk(&format!("c{i}"), v))
            .collect();
        let hist = BenchHistory::parse(&BenchHistory::render(&points)).unwrap();
        assert_eq!(hist.points, points);
        assert_eq!(hist.series(), vec!["fleet_summary.meta.goodput".to_string()]);

        // newest (0.5) vs median of [1.0, 1.1, 0.9, 1.0] = 1.0: FAIL at 5%
        let v = compare_to_history(&hist, 8, 0.05);
        assert_eq!(v.regressions(), vec!["fleet_summary.meta.goodput"]);
        let row = &v.rows[0];
        assert_eq!(row.reference, Some(1.0));
        assert_eq!(row.current, Some(0.5));

        // a single point has no reference: "new", not a failure
        let one = BenchHistory::parse(&BenchHistory::render(&points[..1])).unwrap();
        let v = compare_to_history(&one, 8, 0.05);
        assert_eq!(v.rows[0].status, "new");
        assert!(v.regressions().is_empty());

        // window=1 compares against the immediately preceding point only
        let v = compare_to_history(&hist, 1, 0.05);
        assert_eq!(v.rows[0].reference, Some(1.0), "median of [1.0]");
    }

    #[test]
    fn history_parser_rejects_malformed_lines() {
        assert!(BenchHistory::parse("not json\n").is_err());
        assert!(BenchHistory::parse("{\"label\": \"x\"}\n").is_err());
        let empty = BenchHistory::parse("\n\n").unwrap();
        assert!(empty.points.is_empty());
        assert!(empty.series().is_empty());
    }

    #[test]
    fn trend_report_filters_and_windows() {
        let mut points = Vec::new();
        for (i, v) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            points.push(HistoryPoint {
                label: format!("c{i}"),
                source: "s".into(),
                series: "a.meta.goodput".into(),
                value: *v,
            });
            points.push(HistoryPoint {
                label: format!("c{i}"),
                source: "s".into(),
                series: "b.meta.rounds".into(),
                value: 10.0,
            });
        }
        let hist = BenchHistory::parse(&BenchHistory::render(&points)).unwrap();
        let r = trend_report(&hist, "goodput", 3);
        assert_eq!(r.n_rows(), 1, "filter keeps only the matching series");
        // window 3 of [1,2,3,4] = [2,3,4]: first 2, last 4, +100%
        assert_eq!(r.cell(0, "first"), Some(&Cell::Float(2.0)));
        assert_eq!(r.cell(0, "last"), Some(&Cell::Float(4.0)));
        assert_eq!(r.cell(0, "change_pct"), Some(&Cell::Float(100.0)));
        let all = trend_report(&hist, "", 8);
        assert_eq!(all.n_rows(), 2);
    }
}
