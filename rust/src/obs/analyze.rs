//! `obs::analyze` — deterministic offline analysis of trace artifacts.
//!
//! The consumer side of the trace ring: [`TraceDoc`] loads either
//! export format `--trace-out` writes (Chrome trace-event JSON or
//! JSONL, both via [`crate::util::json`]) and [`analyze`] reduces the
//! event stream to four products, all pure functions of the input:
//!
//! * **per-(category, name) aggregates** ([`SpanAgg`]): event count
//!   and, for spans, total/mean/p50/p95 virtual duration through the
//!   same [`QuantileSketch`] the metric assemblers use at fleet scale;
//! * **critical-path groups** ([`GroupPath`]): span events grouped by
//!   `(category, id)` — one fed round, one fleet job lifecycle, one
//!   learn episode — with start/end extent, busy time and the
//!   *dominant phase* (the span name holding the largest share), so
//!   the longest group per category names the straggler and the phase
//!   that made it one;
//! * **gap/bubble accounting** ([`CatTimeline`]): per category, the
//!   merged-interval busy time vs the first-to-last window — the
//!   fraction of the window no span covers is the pipeline bubble;
//! * **coverage** ([`Coverage`]): held/recorded/dropped from the ring
//!   tallies, so a truncated export reads as "the tail of the run",
//!   never silently as the whole run.
//!
//! Each product renders as a typed [`Report`]
//! ([`summary_report`]/[`critical_report`]/[`gaps_report`]), so
//! text/JSON/CSV come free via the usual `--format`/`--out` plumbing.
//! Entry point: `pacpp trace summarize <FILE>`.
//!
//! Determinism note: span aggregates and critical paths depend on
//! which events the ring kept (sampling, overwrites), but the
//! `counter_*` summary metadata comes from the *metrics snapshot*
//! embedded in the Chrome export's `otherData`, which `--trace-sample`
//! never perturbs — the sampling-invariance property test pins this.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::exp::report::{Cell, ColType, Report};
use crate::util::json::Json;
use crate::util::stats::{QuantileSketch, SKETCH_EXACT_LIMIT};

use super::trace::TraceRing;

/// One event loaded from a trace artifact — [`super::TraceEvent`] with
/// owned strings (the names come from a file, not from static data).
#[derive(Debug, Clone, PartialEq)]
pub struct OwnedEvent {
    /// Virtual-time start, seconds.
    pub ts: f64,
    /// Virtual duration in seconds; `None` marks an instant.
    pub dur: Option<f64>,
    pub cat: String,
    pub name: String,
    pub id: u64,
}

/// A loaded trace artifact: the events plus whatever run metadata the
/// export carried (ring tallies, sampling knob, metrics counters).
#[derive(Debug, Clone, Default)]
pub struct TraceDoc {
    pub events: Vec<OwnedEvent>,
    /// Total events the run recorded (held + overwritten), when known.
    pub recorded: Option<u64>,
    /// Events the ring overwrote after filling, when known.
    pub dropped: Option<u64>,
    /// The `--trace-sample` knob the run used, when known.
    pub sample: Option<u64>,
    /// The metrics-registry counter snapshot from `otherData.metrics`
    /// (Chrome exports only) — sampling-invariant aggregates.
    pub counters: BTreeMap<String, u64>,
}

impl TraceDoc {
    /// Load either export format, sniffing by shape: a single JSON
    /// document with a `traceEvents` array is a Chrome export,
    /// anything else is treated as JSONL.
    pub fn load(text: &str) -> Result<TraceDoc> {
        if let Ok(json) = Json::parse(text) {
            if json.get("traceEvents").is_some() {
                return TraceDoc::from_chrome(&json);
            }
            // a one-line JSONL file parses as a single object too;
            // fall through to the line-oriented loader
        }
        TraceDoc::from_jsonl(text)
    }

    /// Load a Chrome trace-event export ([`TraceRing::to_chrome`]).
    pub fn from_chrome(json: &Json) -> Result<TraceDoc> {
        let raw = json
            .get("traceEvents")
            .and_then(Json::as_arr)
            .context("chrome trace: missing traceEvents array")?;
        let mut events = Vec::with_capacity(raw.len());
        for (i, ev) in raw.iter().enumerate() {
            let ctx = || format!("chrome trace: event {i}");
            let ts = ev
                .get("ts")
                .and_then(Json::as_f64)
                .with_context(|| format!("{}: missing ts", ctx()))?;
            events.push(OwnedEvent {
                ts: ts / 1e6, // trace microseconds back to virtual seconds
                dur: ev.get("dur").and_then(Json::as_f64).map(|d| d / 1e6),
                cat: ev
                    .get("cat")
                    .and_then(Json::as_str)
                    .with_context(|| format!("{}: missing cat", ctx()))?
                    .to_string(),
                name: ev
                    .get("name")
                    .and_then(Json::as_str)
                    .with_context(|| format!("{}: missing name", ctx()))?
                    .to_string(),
                id: ev.path_str("args.id").and_then(Json::as_u64).unwrap_or(0),
            });
        }
        let other = json.get("otherData");
        let meta = |key: &str| other.and_then(|o| o.get(key)).and_then(Json::as_u64);
        let mut counters = BTreeMap::new();
        if let Some(c) = other
            .and_then(|o| o.path_str("metrics.counters"))
            .and_then(Json::as_obj)
        {
            for (k, v) in c {
                counters.insert(k.clone(), v.as_u64().unwrap_or(0));
            }
        }
        Ok(TraceDoc {
            events,
            recorded: meta("recorded"),
            dropped: meta("dropped"),
            sample: meta("sample"),
            counters,
        })
    }

    /// Load a JSONL export ([`TraceRing::to_jsonl`]): one object per
    /// event (keyed by `ts`) plus the trailing `recorded`/`dropped`
    /// metadata line.
    pub fn from_jsonl(text: &str) -> Result<TraceDoc> {
        let mut doc = TraceDoc::default();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let json = Json::parse(line).with_context(|| format!("jsonl trace: line {}", i + 1))?;
            if json.get("ts").is_none() {
                // the metadata trailer (or a foreign annotation line)
                if let Some(r) = json.get("recorded").and_then(Json::as_u64) {
                    doc.recorded = Some(r);
                    doc.dropped = json.get("dropped").and_then(Json::as_u64);
                    continue;
                }
                bail!("jsonl trace: line {} has neither ts nor recorded", i + 1);
            }
            doc.events.push(OwnedEvent {
                ts: json.get("ts").and_then(Json::as_f64).unwrap_or(0.0),
                dur: json.get("dur").and_then(Json::as_f64),
                cat: json
                    .get("cat")
                    .and_then(Json::as_str)
                    .with_context(|| format!("jsonl trace: line {}: missing cat", i + 1))?
                    .to_string(),
                name: json
                    .get("name")
                    .and_then(Json::as_str)
                    .with_context(|| format!("jsonl trace: line {}: missing name", i + 1))?
                    .to_string(),
                id: json.get("id").and_then(Json::as_u64).unwrap_or(0),
            });
        }
        Ok(doc)
    }

    /// Build directly from an in-memory ring (unit tests, in-process
    /// analysis without an export round-trip).
    pub fn from_ring(ring: &TraceRing) -> TraceDoc {
        TraceDoc {
            events: ring
                .iter()
                .map(|ev| OwnedEvent {
                    ts: ev.ts,
                    dur: ev.dur,
                    cat: ev.cat.to_string(),
                    name: ev.name.to_string(),
                    id: ev.id,
                })
                .collect(),
            recorded: Some(ring.recorded()),
            dropped: Some(ring.dropped()),
            sample: None,
            counters: BTreeMap::new(),
        }
    }
}

/// Aggregate over one `(category, name)` event key.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanAgg {
    pub cat: String,
    pub name: String,
    /// All events with this key (spans and instants).
    pub count: u64,
    /// Events carrying a duration.
    pub spans: u64,
    /// Sum of span durations, virtual seconds.
    pub total: f64,
    pub mean: Option<f64>,
    pub p50: Option<f64>,
    pub p95: Option<f64>,
}

/// One `(category, id)` span group — a fed round, a fleet job
/// lifecycle, a learn episode — reduced to its critical-path shape.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupPath {
    pub cat: String,
    pub id: u64,
    /// Earliest span start, virtual seconds.
    pub start: f64,
    /// Latest span end.
    pub end: f64,
    /// Sum of span durations (may exceed `end - start` when phases
    /// overlap).
    pub busy: f64,
    pub spans: u64,
    /// The span name with the largest total duration in the group,
    /// ties broken lexicographically.
    pub dominant: String,
    pub dominant_dur: f64,
}

impl GroupPath {
    /// First-to-last extent of the group.
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Per-category busy/gap accounting over the merged span intervals.
#[derive(Debug, Clone, PartialEq)]
pub struct CatTimeline {
    pub cat: String,
    pub spans: u64,
    /// First span start to last span end.
    pub window: f64,
    /// Time covered by at least one span (intervals merged).
    pub busy: f64,
    /// `window - busy`: time inside the window no span covers.
    pub gap: f64,
}

impl CatTimeline {
    /// Gap share of the window — the pipeline-bubble fraction.
    pub fn bubble(&self) -> f64 {
        if self.window > 0.0 {
            self.gap / self.window
        } else {
            0.0
        }
    }
}

/// Ring coverage: how much of the run the held events represent.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Coverage {
    pub held: u64,
    pub recorded: Option<u64>,
    pub dropped: Option<u64>,
}

impl Coverage {
    /// Fraction of recorded events still held (`None` when the export
    /// carried no tallies; `1.0` for an empty but complete trace).
    pub fn fraction(&self) -> Option<f64> {
        let recorded = self.recorded?;
        let dropped = self.dropped?;
        if recorded == 0 {
            return Some(1.0);
        }
        Some((recorded - dropped.min(recorded)) as f64 / recorded as f64)
    }
}

/// Everything [`analyze`] computes from one [`TraceDoc`].
#[derive(Debug, Clone)]
pub struct Analysis {
    /// Sorted by `(cat, name)`.
    pub aggs: Vec<SpanAgg>,
    /// Sorted by extent, longest first (ties: `cat`, then `id`) — the
    /// head is the whole trace's critical group.
    pub groups: Vec<GroupPath>,
    /// Sorted by `cat`.
    pub timelines: Vec<CatTimeline>,
    pub coverage: Coverage,
    /// Metrics-registry counters carried by the export (sampling- and
    /// ring-capacity-invariant, unlike everything span-derived).
    pub counters: BTreeMap<String, u64>,
    pub sample: Option<u64>,
}

impl Analysis {
    /// The longest group in `cat` — its straggler — if any span group
    /// exists there.
    pub fn critical(&self, cat: &str) -> Option<&GroupPath> {
        self.groups.iter().find(|g| g.cat == cat)
    }
}

/// Reduce a loaded trace to its [`Analysis`]. Pure and deterministic:
/// same document, same analysis, bit for bit.
pub fn analyze(doc: &TraceDoc) -> Analysis {
    // per-(cat, name) aggregates
    struct Agg {
        count: u64,
        spans: u64,
        total: f64,
        sketch: QuantileSketch,
    }
    let mut aggs: BTreeMap<(String, String), Agg> = BTreeMap::new();
    // per-(cat, id) span groups
    struct Group {
        start: f64,
        end: f64,
        busy: f64,
        spans: u64,
        phases: BTreeMap<String, f64>,
    }
    let mut groups: BTreeMap<(String, u64), Group> = BTreeMap::new();
    // per-cat span intervals for gap accounting
    let mut intervals: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();

    for ev in &doc.events {
        let agg = aggs.entry((ev.cat.clone(), ev.name.clone())).or_insert_with(|| Agg {
            count: 0,
            spans: 0,
            total: 0.0,
            sketch: QuantileSketch::new(&[0.5, 0.95], SKETCH_EXACT_LIMIT),
        });
        agg.count += 1;
        let Some(dur) = ev.dur else { continue };
        agg.spans += 1;
        agg.total += dur;
        agg.sketch.add(dur);

        let g = groups.entry((ev.cat.clone(), ev.id)).or_insert_with(|| Group {
            start: f64::INFINITY,
            end: f64::NEG_INFINITY,
            busy: 0.0,
            spans: 0,
            phases: BTreeMap::new(),
        });
        g.start = g.start.min(ev.ts);
        g.end = g.end.max(ev.ts + dur);
        g.busy += dur;
        g.spans += 1;
        *g.phases.entry(ev.name.clone()).or_insert(0.0) += dur;

        intervals.entry(ev.cat.clone()).or_default().push((ev.ts, ev.ts + dur));
    }

    let aggs = aggs
        .into_iter()
        .map(|((cat, name), a)| {
            let qs = a.sketch.quantile_many(&[0.5, 0.95]);
            SpanAgg {
                cat,
                name,
                count: a.count,
                spans: a.spans,
                total: a.total,
                mean: (a.spans > 0).then(|| a.total / a.spans as f64),
                p50: qs[0],
                p95: qs[1],
            }
        })
        .collect();

    let mut groups: Vec<GroupPath> = groups
        .into_iter()
        .map(|((cat, id), g)| {
            // dominant phase: largest total, ties to the
            // lexicographically first name (BTreeMap order + strict >)
            let (dominant, dominant_dur) = g
                .phases
                .iter()
                .fold(("", 0.0), |best, (name, &dur)| {
                    if dur > best.1 {
                        (name.as_str(), dur)
                    } else {
                        best
                    }
                });
            GroupPath {
                cat,
                id,
                start: g.start,
                end: g.end,
                busy: g.busy,
                spans: g.spans,
                dominant: dominant.to_string(),
                dominant_dur,
            }
        })
        .collect();
    groups.sort_by(|a, b| {
        b.duration()
            .total_cmp(&a.duration())
            .then_with(|| a.cat.cmp(&b.cat))
            .then_with(|| a.id.cmp(&b.id))
    });

    let timelines = intervals
        .into_iter()
        .map(|(cat, mut iv)| {
            iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
            // max end, not last-by-start end: an early span can
            // contain every later one
            let end = iv.iter().fold(f64::NEG_INFINITY, |m, &(_, e)| m.max(e));
            let window = end - iv[0].0;
            let mut busy = 0.0;
            let (mut lo, mut hi) = iv[0];
            for &(s, e) in &iv[1..] {
                if s > hi {
                    busy += hi - lo;
                    (lo, hi) = (s, e);
                } else {
                    hi = hi.max(e);
                }
            }
            busy += hi - lo;
            CatTimeline {
                cat,
                spans: iv.len() as u64,
                window,
                busy,
                gap: (window - busy).max(0.0),
            }
        })
        .collect();

    Analysis {
        aggs,
        groups,
        timelines,
        coverage: Coverage {
            held: doc.events.len() as u64,
            recorded: doc.recorded,
            dropped: doc.dropped,
        },
        counters: doc.counters.clone(),
        sample: doc.sample,
    }
}

/// An id as an `Int` cell, `Missing` past the f64-exact range the
/// report schema enforces.
fn id_cell(id: u64) -> Cell {
    if id < 9_000_000_000_000_000 {
        Cell::Int(id as i64)
    } else {
        Cell::Missing
    }
}

/// Per-(category, name) aggregate table. The metadata carries the ring
/// coverage and every metrics counter (`counter_<name>`) — the
/// sampling-invariant part of the summary.
pub fn summary_report(a: &Analysis) -> Report {
    let mut r = Report::new("trace_summary", "Trace summary — per-category event aggregates")
        .column("cat", ColType::Str)
        .column("name", ColType::Str)
        .column("kind", ColType::Str)
        .column("count", ColType::Int)
        .column("total", ColType::Secs)
        .column("mean", ColType::Secs)
        .column("p50", ColType::Secs)
        .column("p95", ColType::Secs)
        .meta("held", a.coverage.held);
    if let Some(v) = a.coverage.recorded {
        r = r.meta("recorded", v);
    }
    if let Some(v) = a.coverage.dropped {
        r = r.meta("dropped", v);
    }
    if let Some(f) = a.coverage.fraction() {
        r = r.meta("coverage", format!("{f:.4}"));
    }
    if let Some(s) = a.sample {
        r = r.meta("sample", s);
    }
    for (k, v) in &a.counters {
        r = r.meta(format!("counter_{k}"), v);
    }
    for agg in &a.aggs {
        r.push(vec![
            Cell::Str(agg.cat.clone()),
            Cell::Str(agg.name.clone()),
            Cell::Str(if agg.spans > 0 { "span" } else { "instant" }.into()),
            Cell::Int(agg.count.min(9_000_000_000_000_000 - 1) as i64),
            Cell::opt((agg.spans > 0).then_some(agg.total), Cell::Secs),
            Cell::opt(agg.mean, Cell::Secs),
            Cell::opt(agg.p50, Cell::Secs),
            Cell::opt(agg.p95, Cell::Secs),
        ]);
    }
    r
}

/// Critical-path table: the `top` longest span groups, with each
/// category's longest group — its straggler — named in the metadata as
/// `critical_<cat> = <id>`. `groups_total` records how many groups the
/// cap hides.
pub fn critical_report(a: &Analysis, top: usize) -> Report {
    let mut r = Report::new(
        "trace_critical",
        "Trace critical paths — longest (category, id) span groups",
    )
    .column("cat", ColType::Str)
    .column("id", ColType::Int)
    .column("start", ColType::Secs)
    .column("duration", ColType::Secs)
    .column("spans", ColType::Int)
    .column("busy", ColType::Secs)
    .column("dominant", ColType::Str)
    .column("dominant_dur", ColType::Secs)
    .column("dominant_share", ColType::Float)
    .meta("groups_total", a.groups.len())
    .meta("shown", a.groups.len().min(top));
    // straggler attribution: one meta entry per category
    let mut seen = std::collections::BTreeSet::new();
    for g in &a.groups {
        if seen.insert(g.cat.clone()) {
            r = r.meta(format!("critical_{}", g.cat), g.id);
        }
    }
    for g in a.groups.iter().take(top) {
        let dur = g.duration();
        r.push(vec![
            Cell::Str(g.cat.clone()),
            id_cell(g.id),
            Cell::Secs(g.start),
            Cell::Secs(dur),
            Cell::Int(g.spans.min(9_000_000_000_000_000 - 1) as i64),
            Cell::Secs(g.busy),
            Cell::Str(g.dominant.clone()),
            Cell::Secs(g.dominant_dur),
            Cell::opt((dur > 0.0).then(|| g.dominant_dur / dur), Cell::Float),
        ]);
    }
    r
}

/// Gap/bubble table: per-category merged-interval busy time vs the
/// first-to-last window.
pub fn gaps_report(a: &Analysis) -> Report {
    let mut r = Report::new("trace_gaps", "Trace gaps — per-category busy vs window")
        .column("cat", ColType::Str)
        .column("spans", ColType::Int)
        .column("window", ColType::Secs)
        .column("busy", ColType::Secs)
        .column("gap", ColType::Secs)
        .column("bubble", ColType::Float);
    for t in &a.timelines {
        r.push(vec![
            Cell::Str(t.cat.clone()),
            Cell::Int(t.spans.min(9_000_000_000_000_000 - 1) as i64),
            Cell::Secs(t.window),
            Cell::Secs(t.busy),
            Cell::Secs(t.gap),
            Cell::Float(t.bubble()),
        ]);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceEvent;

    fn span(cat: &'static str, name: &'static str, id: u64, ts: f64, dur: f64) -> TraceEvent {
        TraceEvent { ts, dur: Some(dur), cat, name, id }
    }

    fn instant(cat: &'static str, name: &'static str, id: u64, ts: f64) -> TraceEvent {
        TraceEvent { ts, dur: None, cat, name, id }
    }

    /// An engineered two-round fed trace: round 2 is the straggler and
    /// its upload phase dominates.
    fn engineered_ring() -> TraceRing {
        let mut ring = TraceRing::new(64);
        ring.record(instant("fed.round", "select", 1, 0.0));
        ring.record(span("fed.round", "upload", 1, 0.0, 5.0));
        ring.record(span("fed.round", "aggregate", 1, 5.0, 1.0));
        ring.record(instant("fed.round", "select", 2, 10.0));
        ring.record(span("fed.round", "upload", 2, 10.0, 20.0));
        ring.record(span("fed.round", "aggregate", 2, 30.0, 2.0));
        ring
    }

    #[test]
    fn critical_path_names_the_straggler_round_and_its_phase() {
        let a = analyze(&TraceDoc::from_ring(&engineered_ring()));
        // two groups; round 2 ([10, 32], 22 s) beats round 1 ([0, 6], 6 s)
        assert_eq!(a.groups.len(), 2);
        let g = &a.groups[0];
        assert_eq!((g.cat.as_str(), g.id), ("fed.round", 2));
        assert_eq!(g.start, 10.0);
        assert_eq!(g.duration(), 22.0);
        assert_eq!(g.busy, 22.0);
        assert_eq!(g.spans, 2);
        assert_eq!(g.dominant, "upload");
        assert_eq!(g.dominant_dur, 20.0);
        assert_eq!(a.critical("fed.round").unwrap().id, 2);
        assert!(a.critical("fleet.job").is_none());

        let report = critical_report(&a, 10);
        assert_eq!(report.meta.get("critical_fed.round"), Some(&"2".to_string()));
        assert_eq!(report.cell(0, "id"), Some(&Cell::Int(2)));
        assert_eq!(report.cell(0, "dominant"), Some(&Cell::Str("upload".into())));
        assert_eq!(report.cell(1, "id"), Some(&Cell::Int(1)));
        // the top cap is visible, never silent
        let capped = critical_report(&a, 1);
        assert_eq!(capped.n_rows(), 1);
        assert_eq!(capped.meta.get("groups_total"), Some(&"2".to_string()));
        assert_eq!(capped.meta.get("shown"), Some(&"1".to_string()));
    }

    #[test]
    fn aggregates_split_spans_from_instants() {
        let a = analyze(&TraceDoc::from_ring(&engineered_ring()));
        // (fed.round, aggregate), (select), (upload) in BTreeMap order
        let names: Vec<&str> = a.aggs.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["aggregate", "select", "upload"]);
        let upload = &a.aggs[2];
        assert_eq!((upload.count, upload.spans), (2, 2));
        assert_eq!(upload.total, 25.0);
        assert_eq!(upload.mean, Some(12.5));
        assert_eq!(upload.p50, Some(12.5));
        let select = &a.aggs[1];
        assert_eq!((select.count, select.spans), (2, 0));
        assert_eq!(select.mean, None);
        let r = summary_report(&a);
        assert_eq!(r.n_rows(), 3);
        assert_eq!(r.cell(1, "kind"), Some(&Cell::Str("instant".into())));
        assert_eq!(r.cell(1, "total"), Some(&Cell::Missing));
    }

    #[test]
    fn gap_accounting_merges_overlaps_and_measures_bubbles() {
        let mut ring = TraceRing::new(16);
        // [0, 2] and [5, 6]: window 6, busy 3, gap 3, bubble 0.5
        ring.record(span("x", "a", 1, 0.0, 2.0));
        ring.record(span("x", "b", 2, 5.0, 1.0));
        // overlapping [0, 2] + [1, 3]: busy 3, no gap
        ring.record(span("y", "a", 1, 0.0, 2.0));
        ring.record(span("y", "b", 2, 1.0, 2.0));
        // containment: [0, 10] swallows [5, 6] — window is 10, not 6
        ring.record(span("z", "a", 1, 0.0, 10.0));
        ring.record(span("z", "b", 2, 5.0, 1.0));
        let a = analyze(&TraceDoc::from_ring(&ring));
        assert_eq!(a.timelines.len(), 3);
        let x = &a.timelines[0];
        assert_eq!((x.window, x.busy, x.gap), (6.0, 3.0, 3.0));
        assert_eq!(x.bubble(), 0.5);
        let y = &a.timelines[1];
        assert_eq!((y.window, y.busy, y.gap), (3.0, 3.0, 0.0));
        assert_eq!(y.bubble(), 0.0);
        let z = &a.timelines[2];
        assert_eq!((z.window, z.busy, z.gap), (10.0, 10.0, 0.0));
        let r = gaps_report(&a);
        assert_eq!(r.cell(0, "bubble"), Some(&Cell::Float(0.5)));
    }

    #[test]
    fn empty_ring_analyzes_to_empty_reports() {
        let ring = TraceRing::new(4);
        let a = analyze(&TraceDoc::from_ring(&ring));
        assert!(a.aggs.is_empty() && a.groups.is_empty() && a.timelines.is_empty());
        assert_eq!(a.coverage.held, 0);
        assert_eq!(a.coverage.fraction(), Some(1.0), "empty but complete");
        for r in [summary_report(&a), critical_report(&a, 10), gaps_report(&a)] {
            assert_eq!(r.n_rows(), 0);
        }
    }

    #[test]
    fn overwritten_ring_reports_partial_coverage() {
        let mut ring = TraceRing::new(2);
        for i in 0..10u64 {
            ring.record(instant("sim.event", "tick", i, i as f64));
        }
        let a = analyze(&TraceDoc::from_ring(&ring));
        assert_eq!(a.coverage.held, 2);
        assert_eq!(a.coverage.recorded, Some(10));
        assert_eq!(a.coverage.dropped, Some(8));
        assert_eq!(a.coverage.fraction(), Some(0.2));
        let r = summary_report(&a);
        assert_eq!(r.meta.get("dropped"), Some(&"8".to_string()));
        assert_eq!(r.meta.get("coverage"), Some(&"0.2000".to_string()));
        // only the held tail contributes to the aggregates
        assert_eq!(a.aggs[0].count, 2);
    }

    #[test]
    fn jsonl_round_trip_preserves_events_and_tallies() {
        let ring = engineered_ring();
        let doc = TraceDoc::load(&ring.to_jsonl()).unwrap();
        assert_eq!(doc.events.len(), 6);
        assert_eq!(doc.recorded, Some(6));
        assert_eq!(doc.dropped, Some(0));
        assert_eq!(doc.events[1].dur, Some(5.0));
        assert_eq!(doc.events[1].name, "upload");
        let direct = TraceDoc::from_ring(&ring);
        assert_eq!(doc.events, direct.events);
    }

    #[test]
    fn chrome_round_trip_preserves_events_counters_and_tallies() {
        let ring = engineered_ring();
        let json = ring.to_chrome(vec![
            ("sample", Json::from(3u64)),
            (
                "metrics",
                crate::util::json::obj(vec![(
                    "counters",
                    crate::util::json::obj(vec![("events", Json::from(42u64))]),
                )]),
            ),
        ]);
        let doc = TraceDoc::load(&json.to_string_pretty()).unwrap();
        assert_eq!(doc.events.len(), 6);
        assert_eq!(doc.events, TraceDoc::from_ring(&ring).events, "µs mapping inverts");
        assert_eq!(doc.sample, Some(3));
        assert_eq!(doc.counters.get("events"), Some(&42));
        let a = analyze(&doc);
        assert_eq!(
            summary_report(&a).meta.get("counter_events"),
            Some(&"42".to_string())
        );
    }

    #[test]
    fn loader_rejects_garbage() {
        assert!(TraceDoc::load("not json at all").is_err());
        assert!(TraceDoc::from_jsonl("{\"no_ts\": 1}\n").is_err());
        assert!(TraceDoc::from_chrome(&Json::parse("{}").unwrap()).is_err());
        // event lines missing required fields
        assert!(TraceDoc::from_jsonl("{\"ts\": 1.0, \"cat\": \"x\"}\n").is_err());
    }
}
