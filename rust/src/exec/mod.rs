//! Real execution engine: multi-threaded PAC+ training over the AOT
//! artifacts (no Python anywhere on this path; requires the `pjrt`
//! runtime feature at run time).
//!
//! Worker threads stand in for edge devices (DESIGN.md §2 — the network
//! timing is studied separately through the simulator and the
//! [`crate::strategy`] layer; this path proves the three layers compose
//! and produces real loss curves).
//!
//! Two engines are provided:
//!
//! * [`train_data_parallel`] — PAC+ phases on a data-parallel group:
//!   epoch 1 runs `backbone_fwd` (or the quantized variant) per
//!   micro-batch, stores the activation slab in the [`ActivationCache`],
//!   computes adapter gradients, and the leader AllReduces (averages) and
//!   applies the (clipped Adam) update. Epochs ≥ 2 skip the backbone entirely and
//!   read activations from the cache.
//! * [`train_pipelined`] — epoch 1 with the backbone forward split into
//!   pipeline stages across workers (`embed_fwd` + `stage_fwd_k*`
//!   artifacts), cache slabs streamed to the leader, adapter trained on
//!   assembled activations; later epochs fall back to the cached
//!   data-parallel path.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cache::ActivationCache;
use crate::data::SyntheticTask;
use crate::runtime::{Runtime, Tensor};

/// Training options for the real engine.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub epochs: usize,
    pub lr: f32,
    /// Worker threads acting as devices.
    pub workers: usize,
    /// Adapter init parameter-set tag (e.g. "adapter_prune").
    pub init_tag: String,
    /// Use the quantized backbone artifact ("int8"/"int4") for the
    /// cache-building forward passes.
    pub quant: Option<String>,
    /// Directory for the activation cache.
    pub cache_dir: std::path::PathBuf,
    /// Disable the activation cache (ablation): epochs > 1 recompute the
    /// backbone forward.
    pub use_cache: bool,
}

impl TrainOptions {
    pub fn new(cache_dir: impl Into<std::path::PathBuf>) -> TrainOptions {
        TrainOptions {
            epochs: 2,
            lr: 5e-3, // Adam
            workers: 2,
            init_tag: "adapter_prune".into(),
            quant: None,
            cache_dir: cache_dir.into(),
            use_cache: true,
        }
    }
}

/// One logged optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub epoch: usize,
    pub step: usize,
    pub loss: f32,
}

/// Full training run record.
#[derive(Debug, Clone)]
pub struct TrainLog {
    pub steps: Vec<StepLog>,
    pub epoch_times: Vec<f64>,
    pub eval_accuracy: Option<f64>,
    pub eval_loss: Option<f64>,
    /// Micro-batches served from the activation cache.
    pub cache_hits: usize,
    /// Micro-batches that ran the backbone forward.
    pub backbone_passes: usize,
}

impl TrainLog {
    pub fn mean_loss(&self, epoch: usize) -> f32 {
        let v: Vec<f32> =
            self.steps.iter().filter(|s| s.epoch == epoch).map(|s| s.loss).collect();
        v.iter().sum::<f32>() / v.len().max(1) as f32
    }

    pub fn final_loss(&self) -> f32 {
        self.steps.last().map(|s| s.loss).unwrap_or(f32::NAN)
    }
}

// ---------------------------------------------------------------------------
// Tensor math helpers (adapter update on the leader)
// ---------------------------------------------------------------------------

/// Global-norm gradient clipping threshold. Keeps the fixed-lr trainer
/// stable across model scales (the d=768 backbone's prune-init adapter
/// sees much larger early gradients than d=128).
pub const CLIP_NORM: f32 = 1.0;

fn grad_global_norm(grads: &[Tensor]) -> f32 {
    let mut sq = 0.0f64;
    for g in grads {
        if let Tensor::F32(gv, _) = g {
            for x in gv {
                sq += (*x as f64) * (*x as f64);
            }
        }
    }
    sq.sqrt() as f32
}

/// The coordinator-side optimizer. The paper's PEFT methods carry Adam
/// states for the (small) trainable set — exactly the L3 coordinator's
/// job here: the AOT artifacts emit raw gradients (`adapter_grads`) and
/// the leader owns momentum/variance and the update rule.
pub struct Adam {
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: i32,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Adam {
    pub fn new(params: &[Tensor], lr: f32) -> Adam {
        let shapes: Vec<usize> = params.iter().map(|p| p.numel()).collect();
        Adam {
            m: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            v: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    /// One clipped Adam step in place.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) -> Result<()> {
        let norm = grad_global_norm(grads);
        let clip = if norm > CLIP_NORM { CLIP_NORM / norm } else { 1.0 };
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            match (p, g) {
                (Tensor::F32(pv, _), Tensor::F32(gv, _)) => {
                    let (m, v) = (&mut self.m[i], &mut self.v[i]);
                    for j in 0..pv.len() {
                        let gj = gv[j] * clip;
                        m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gj;
                        v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gj * gj;
                        let mh = m[j] / bc1;
                        let vh = v[j] / bc2;
                        pv[j] -= self.lr * mh / (vh.sqrt() + self.eps);
                    }
                }
                _ => bail!("non-f32 parameter in update"),
            }
        }
        Ok(())
    }
}

fn accumulate(sum: &mut Vec<Tensor>, add: &[Tensor]) -> Result<()> {
    if sum.is_empty() {
        sum.extend_from_slice(add);
        return Ok(());
    }
    for (s, a) in sum.iter_mut().zip(add) {
        match (s, a) {
            (Tensor::F32(sv, _), Tensor::F32(av, _)) => {
                for (x, y) in sv.iter_mut().zip(av) {
                    *x += y;
                }
            }
            _ => bail!("non-f32 gradient"),
        }
    }
    Ok(())
}

fn scale(ts: &mut [Tensor], k: f32) {
    for t in ts {
        if let Tensor::F32(v, _) = t {
            for x in v.iter_mut() {
                *x *= k;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Data-parallel engine
// ---------------------------------------------------------------------------

/// Backbone-forward inputs for a micro-batch (full or quantized variant).
fn backbone_inputs(
    rt: &Runtime,
    quant: &Option<String>,
    tokens: Vec<i32>,
) -> Result<(String, Vec<Tensor>)> {
    let cfg = &rt.manifest.config;
    let tok = Tensor::I32(tokens, vec![cfg.batch, cfg.seq_len]);
    match quant {
        None => {
            let mut inp = rt.load_params("backbone")?;
            inp.push(tok);
            Ok(("backbone_fwd".into(), inp))
        }
        Some(bits) => {
            let mut inp = rt.load_params(&format!("backbone_{bits}"))?;
            inp.push(tok);
            Ok((format!("qbackbone_fwd_{bits}"), inp))
        }
    }
}

/// PAC+ data-parallel training (cache-enabled exclusive adapter tuning).
pub fn train_data_parallel(
    rt: &Arc<Runtime>,
    task: &SyntheticTask,
    opts: &TrainOptions,
) -> Result<TrainLog> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    if batches.is_empty() {
        bail!("dataset smaller than one micro-batch");
    }
    let entry_len = (cfg.layers + 1) * cfg.seq_len * cfg.d_model;
    let mut cache =
        ActivationCache::open(&opts.cache_dir, batches.len(), entry_len * cfg.batch)?;
    cache.clear()?; // fresh run

    // warm the executables once (compile outside the timed region)
    let backbone_name = match &opts.quant {
        None => "backbone_fwd".to_string(),
        Some(b) => format!("qbackbone_fwd_{b}"),
    };
    rt.executable(&backbone_name)?;
    rt.executable("adapter_grads")?;

    let mut adapter = rt.load_params(&opts.init_tag)?;
    let mut optimizer = Adam::new(&adapter, opts.lr);
    let n_adapter = adapter.len();
    let workers = opts.workers.max(1);

    let mut log = TrainLog {
        steps: Vec::new(),
        epoch_times: Vec::new(),
        eval_accuracy: None,
        eval_loss: None,
        cache_hits: 0,
        backbone_passes: 0,
    };

    let mut step_counter = 0usize;
    for epoch in 0..opts.epochs {
        let t0 = Instant::now();
        // process micro-batches in groups of `workers` (one group = one
        // data-parallel mini-batch; gradients averaged across the group)
        for (gi, group) in batches.chunks(workers).enumerate() {
            let use_cached = opts.use_cache && epoch > 0;
            // -- parallel part: per-worker acts + grads ------------------
            let results: Vec<(Vec<Tensor>, f32, Option<(usize, Vec<f32>)>)> =
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for (wi, (toks, labs)) in group.iter().enumerate() {
                        let rt = rt.clone();
                        let adapter_ref = &adapter;
                        let cache_ref = &cache;
                        let quant = opts.quant.clone();
                        let mb_id = gi * workers + wi;
                        handles.push(scope.spawn(move || -> Result<_> {
                            let acts = if use_cached && cache_ref.contains(mb_id) {
                                let data = cache_ref.get(mb_id)?;
                                Tensor::F32(
                                    data,
                                    vec![cfg.layers + 1, cfg.batch, cfg.seq_len, cfg.d_model],
                                )
                            } else {
                                let (name, inp) =
                                    backbone_inputs(&rt, &quant, toks.clone())?;
                                rt.execute(&name, &inp)?.remove(0)
                            };
                            let was_cached = use_cached && cache_ref.contains(mb_id);
                            // adapter grads on (possibly cached) acts
                            let mut ainp = adapter_ref.clone();
                            ainp.push(acts.clone());
                            ainp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
                            let mut out = rt.execute("adapter_grads", &ainp)?;
                            let loss = out.pop().unwrap().scalar_f32()?;
                            let store = if !was_cached {
                                Some((mb_id, acts.as_f32()?.to_vec()))
                            } else {
                                None
                            };
                            Ok((out, loss, store))
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("worker panicked"))
                        .collect::<Result<Vec<_>>>()
                })?;

            // -- leader part: cache writes + AllReduce + update ----------
            let mut grad_sum: Vec<Tensor> = Vec::new();
            let mut loss_sum = 0.0f32;
            let n = results.len() as f32;
            for (grads, loss, store) in results {
                if grads.len() != n_adapter {
                    bail!("gradient arity mismatch");
                }
                accumulate(&mut grad_sum, &grads)?;
                loss_sum += loss;
                match store {
                    Some((mb_id, acts)) => {
                        if opts.use_cache {
                            cache.put(mb_id, &acts)?;
                        }
                        log.backbone_passes += 1;
                    }
                    None => log.cache_hits += 1,
                }
            }
            scale(&mut grad_sum, 1.0 / n);
            optimizer.step(&mut adapter, &grad_sum)?;
            log.steps.push(StepLog { epoch, step: step_counter, loss: loss_sum / n });
            step_counter += 1;
        }
        log.epoch_times.push(t0.elapsed().as_secs_f64());
    }

    // hold the final adapter for evaluation by the caller
    FINAL_ADAPTER.with(|f| *f.borrow_mut() = Some(adapter));
    Ok(log)
}

thread_local! {
    static FINAL_ADAPTER: std::cell::RefCell<Option<Vec<Tensor>>> =
        const { std::cell::RefCell::new(None) };
}

/// Fetch the adapter parameters produced by the last training run on this
/// thread (used by evaluation and by tests).
pub fn take_final_adapter() -> Option<Vec<Tensor>> {
    FINAL_ADAPTER.with(|f| f.borrow_mut().take())
}

/// Evaluate an adapter on a held-out set: (mean loss, accuracy).
pub fn evaluate(
    rt: &Arc<Runtime>,
    adapter: &[Tensor],
    task: &SyntheticTask,
    quant: &Option<String>,
) -> Result<(f64, f64)> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    if batches.is_empty() {
        bail!("eval set smaller than one batch");
    }
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    let mut total = 0usize;
    for (toks, labs) in &batches {
        let (name, inp) = backbone_inputs(rt, quant, toks.clone())?;
        let acts = rt.execute(&name, &inp)?.remove(0);
        let mut ainp = adapter.to_vec();
        ainp.push(acts);
        ainp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
        let out = rt.execute("adapter_eval", &ainp)?;
        loss_sum += out[0].scalar_f32()? as f64;
        let c = match &out[1] {
            Tensor::I32(v, _) => v[0] as usize,
            t => t.scalar_f32()? as usize,
        };
        correct += c;
        total += cfg.batch;
    }
    Ok((loss_sum / batches.len() as f64, correct as f64 / total as f64))
}

// ---------------------------------------------------------------------------
// Pipelined cache-build engine
// ---------------------------------------------------------------------------

/// Partition `layers` into `stages` contiguous spans whose sizes all exist
/// as `stage_fwd_k*` artifacts.
pub fn partition_layers(layers: usize, stages: usize, available: &[usize]) -> Result<Vec<usize>> {
    if stages == 0 || stages > layers {
        bail!("cannot split {layers} layers into {stages} stages");
    }
    let base = layers / stages;
    let rem = layers % stages;
    let sizes: Vec<usize> =
        (0..stages).map(|i| base + usize::from(i < rem)).collect();
    for s in &sizes {
        if !available.contains(s) {
            bail!("no stage artifact for k={s} (available: {available:?})");
        }
    }
    Ok(sizes)
}

/// Epoch-1 pipelined backbone forward + adapter training on assembled
/// activations; epochs ≥ 2 delegate to the cached data-parallel path.
pub fn train_pipelined(
    rt: &Arc<Runtime>,
    task: &SyntheticTask,
    opts: &TrainOptions,
    stages: usize,
) -> Result<TrainLog> {
    let cfg = rt.manifest.config.clone();
    let sizes = partition_layers(cfg.layers, stages, &rt.manifest.stage_sizes())?;
    let batches = task.batches(cfg.batch);
    if batches.is_empty() {
        bail!("dataset smaller than one micro-batch");
    }
    let entry_len = (cfg.layers + 1) * cfg.seq_len * cfg.d_model * cfg.batch;
    let mut cache = ActivationCache::open(&opts.cache_dir, batches.len(), entry_len)?;
    cache.clear()?;

    let backbone = rt.load_params("backbone")?;
    // per-stage layer params: [2 + 8*a, 2 + 8*b)
    let mut bounds = vec![0usize];
    for s in &sizes {
        bounds.push(bounds.last().unwrap() + s);
    }
    // warm executables
    rt.executable("embed_fwd")?;
    for s in &sizes {
        rt.executable(&format!("stage_fwd_k{s}"))?;
    }
    rt.executable("adapter_grads")?;

    let mut adapter = rt.load_params(&opts.init_tag)?;
    let mut optimizer = Adam::new(&adapter, opts.lr);
    let mut log = TrainLog {
        steps: Vec::new(),
        epoch_times: Vec::new(),
        eval_accuracy: None,
        eval_loss: None,
        cache_hits: 0,
        backbone_passes: 0,
    };

    let t0 = Instant::now();
    // channels: stage i -> stage i+1 (x), every stage -> leader (slabs)
    let (slab_tx, slab_rx) = mpsc::channel::<(usize, usize, Vec<f32>)>(); // (mb, stage, data)
    let mut stage_txs: Vec<mpsc::Sender<(usize, Vec<f32>)>> = Vec::new();
    let mut stage_rxs: Vec<mpsc::Receiver<(usize, Vec<f32>)>> = Vec::new();
    for _ in 0..stages {
        let (tx, rx) = mpsc::channel();
        stage_txs.push(tx);
        stage_rxs.push(rx);
    }

    std::thread::scope(|scope| -> Result<()> {
        // stage workers
        let mut next_txs: Vec<Option<mpsc::Sender<(usize, Vec<f32>)>>> =
            stage_txs.iter().skip(1).cloned().map(Some).collect();
        next_txs.push(None);
        for (si, rx) in stage_rxs.into_iter().enumerate() {
            let rt = rt.clone();
            let slab_tx = slab_tx.clone();
            let next = next_txs[si].take();
            let lo = bounds[si];
            let hi = bounds[si + 1];
            let k = hi - lo;
            let params: Vec<Tensor> = backbone[2 + 8 * lo..2 + 8 * hi].to_vec();
            let cfg = cfg.clone();
            scope.spawn(move || {
                while let Ok((mb, x)) = rx.recv() {
                    let mut inp = params.clone();
                    inp.push(Tensor::F32(
                        x,
                        vec![cfg.batch, cfg.seq_len, cfg.d_model],
                    ));
                    let mut out = rt
                        .execute(&format!("stage_fwd_k{k}"), &inp)
                        .expect("stage execution failed");
                    let acts_k = out.pop().unwrap();
                    let x_out = out.pop().unwrap();
                    slab_tx
                        .send((mb, si, acts_k.as_f32().unwrap().to_vec()))
                        .ok();
                    if let Some(nx) = &next {
                        nx.send((mb, x_out.as_f32().unwrap().to_vec())).ok();
                    }
                }
            });
        }
        drop(slab_tx);

        // feeder: embed every micro-batch and push into stage 0
        let feeder_tx = stage_txs.remove(0);
        drop(stage_txs); // close remaining clones so stages terminate
        let rt_feed = rt.clone();
        let tok_emb = backbone[0].clone();
        let pos_emb = backbone[1].clone();
        let cfg_feed = cfg.clone();
        let batches_feed = batches.clone();
        let b0_slabs: std::thread::ScopedJoinHandle<Vec<Vec<f32>>> =
            scope.spawn(move || {
                let mut b0s = Vec::new();
                for (toks, _) in &batches_feed {
                    let inp = vec![
                        tok_emb.clone(),
                        pos_emb.clone(),
                        Tensor::I32(
                            toks.clone(),
                            vec![cfg_feed.batch, cfg_feed.seq_len],
                        ),
                    ];
                    let b0 = rt_feed
                        .execute("embed_fwd", &inp)
                        .expect("embed failed")
                        .remove(0);
                    let v = b0.as_f32().unwrap().to_vec();
                    feeder_tx.send((b0s.len(), v.clone())).ok();
                    b0s.push(v);
                }
                b0s
            });

        // leader: assemble slabs, cache, train adapter
        let per_layer = cfg.batch * cfg.seq_len * cfg.d_model;
        let mut assembled: Vec<Option<Vec<Option<Vec<f32>>>>> =
            vec![None; batches.len()];
        let mut done = 0usize;
        let mut pending_grads: Vec<Tensor> = Vec::new();
        let mut pending_losses = 0usize;
        let mut loss_acc = 0.0f32;
        while done < batches.len() {
            let (mb, si, slab) = slab_rx.recv().map_err(|_| anyhow!("pipeline closed early"))?;
            let entry =
                assembled[mb].get_or_insert_with(|| vec![None; stages]);
            entry[si] = Some(slab);
            if entry.iter().all(Option::is_some) {
                // full stack available once the feeder's b0 exists too —
                // feeder finishes before slabs of its own mb, join lazily.
                done += 1;
                assembled[mb].as_mut().unwrap().push(None); // marker reuse
            }
        }
        let b0s = b0_slabs.join().expect("feeder panicked");

        for (mb, (_, labs)) in batches.iter().enumerate() {
            let parts = assembled[mb].take().unwrap();
            let mut acts = Vec::with_capacity((cfg.layers + 1) * per_layer);
            acts.extend_from_slice(&b0s[mb]);
            for p in parts.into_iter().flatten() {
                acts.extend_from_slice(&p);
            }
            debug_assert_eq!(acts.len(), (cfg.layers + 1) * per_layer);
            if opts.use_cache {
                cache.put(mb, &acts)?;
            }
            log.backbone_passes += 1;

            let acts_t = Tensor::F32(
                acts,
                vec![cfg.layers + 1, cfg.batch, cfg.seq_len, cfg.d_model],
            );
            let mut ainp = adapter.clone();
            ainp.push(acts_t);
            ainp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
            let mut out = rt.execute("adapter_grads", &ainp)?;
            let loss = out.pop().unwrap().scalar_f32()?;
            accumulate(&mut pending_grads, &out)?;
            loss_acc += loss;
            pending_losses += 1;
            if pending_losses == opts.workers.max(1) || mb + 1 == batches.len() {
                scale(&mut pending_grads, 1.0 / pending_losses as f32);
                optimizer.step(&mut adapter, &pending_grads)?;
                log.steps.push(StepLog {
                    epoch: 0,
                    step: log.steps.len(),
                    loss: loss_acc / pending_losses as f32,
                });
                pending_grads.clear();
                pending_losses = 0;
                loss_acc = 0.0;
            }
        }
        Ok(())
    })?;
    log.epoch_times.push(t0.elapsed().as_secs_f64());

    // epochs >= 2: cached data-parallel phase reusing the same cache dir
    if opts.epochs > 1 {
        let mut rest = opts.clone();
        rest.epochs = opts.epochs - 1;
        rest.init_tag = opts.init_tag.clone();
        // continue from current adapter: run the DP loop manually
        let sub =
            train_cached_only(rt, task, &rest, &mut adapter, &mut optimizer, &cache, &mut log)?;
        let _ = sub;
    }
    FINAL_ADAPTER.with(|f| *f.borrow_mut() = Some(adapter));
    Ok(log)
}

/// Cached-only epochs over an existing complete cache (phase 2 proper).
fn train_cached_only(
    rt: &Arc<Runtime>,
    task: &SyntheticTask,
    opts: &TrainOptions,
    adapter: &mut Vec<Tensor>,
    optimizer: &mut Adam,
    cache: &ActivationCache,
    log: &mut TrainLog,
) -> Result<()> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    let base_epoch = log.epoch_times.len();
    for e in 0..opts.epochs {
        let t0 = Instant::now();
        for (gi, group) in batches.chunks(opts.workers.max(1)).enumerate() {
            let mut grad_sum: Vec<Tensor> = Vec::new();
            let mut loss_sum = 0.0;
            for (wi, (_, labs)) in group.iter().enumerate() {
                let mb = gi * opts.workers.max(1) + wi;
                let data = cache.get(mb)?;
                log.cache_hits += 1;
                let acts = Tensor::F32(
                    data,
                    vec![cfg.layers + 1, cfg.batch, cfg.seq_len, cfg.d_model],
                );
                let mut ainp = adapter.clone();
                ainp.push(acts);
                ainp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
                let mut out = rt.execute("adapter_grads", &ainp)?;
                loss_sum += out.pop().unwrap().scalar_f32()?;
                accumulate(&mut grad_sum, &out)?;
            }
            let n = group.len() as f32;
            scale(&mut grad_sum, 1.0 / n);
            optimizer.step(adapter, &grad_sum)?;
            log.steps.push(StepLog {
                epoch: base_epoch + e,
                step: log.steps.len(),
                loss: loss_sum / n,
            });
        }
        log.epoch_times.push(t0.elapsed().as_secs_f64());
    }
    Ok(())
}
