//! Analytic FLOPs / memory cost model, calibrated against the paper's
//! measurements (Table I memory breakdown, Fig. 3 FLOPs comparison).
//!
//! Calibration notes (see tests at the bottom for the asserted targets):
//!
//! * **Weights** — `params * precision.bytes_per_param()` (Table I: T5-Large
//!   FP32 = 2.75 GB).
//! * **Intermediate activations** — the classic no-flash training estimate
//!   of ~13.2·d floats per token per transformer block reproduces Table I's
//!   5.33 GB for full fine-tuning of T5-Large at batch 16 / seq 128
//!   (the paper's "Activations" column folds optimizer states in; full FT
//!   uses plain SGD, PEFT methods carry Adam states on their small
//!   trainable sets).
//! * **PEFT keep-fractions** — Adapters / LoRA cannot release most
//!   backbone activations because backprop traverses the backbone; the
//!   paper measures ≤28.15% activation reduction. Parallel Adapters keep
//!   only the layer-boundary activations plus the adapter's own working
//!   set.
//! * **FLOPs** — fwd ≈ 2·params/token (+ attention's 4·s·d); bwd-through-
//!   backbone ≈ 2× fwd for full FT and ≈ 1× fwd + trainable-fraction for
//!   Adapters/LoRA (gradient w.r.t. activations must still be chained
//!   through every layer even when weights are frozen). This reproduces
//!   Fig. 3's ~30% FLOPs reduction for Adapters/LoRA vs Full and the
//!   ~54% forward share.

use super::config::ModelSpec;
use super::peft::{Method, Precision};

/// Floats of intermediate activation per token per block (calibrated).
pub const ACT_FLOATS_PER_TOKEN: f64 = 13.2;

/// Fraction of backbone activations PEFT methods must retain for backprop.
pub const KEEP_ADAPTERS: f64 = 0.75; // Table I: 4.04/5.33 ≈ 0.76
pub const KEEP_LORA: f64 = 0.81; // Table I: 4.31/5.33 ≈ 0.81

/// Adam keeps 2 f32 states per trainable param (PEFT methods); full FT
/// uses plain SGD (no state) — matching Table I's totals.
const ADAM_STATES: f64 = 2.0;

/// A training workload shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    pub batch: usize,
    pub seq: usize,
}

impl Workload {
    pub fn new(batch: usize, seq: usize) -> Workload {
        Workload { batch, seq }
    }

    pub fn tokens(&self) -> u64 {
        (self.batch * self.seq) as u64
    }

    /// The paper's default evaluation shape (mini-batch 16, seq 128).
    pub fn paper_default() -> Workload {
        Workload::new(16, 128)
    }
}

// ---------------------------------------------------------------------------
// FLOPs
// ---------------------------------------------------------------------------

/// Forward FLOPs per token for one encoder block.
pub fn flops_fwd_enc_block(spec: &ModelSpec, seq: usize) -> f64 {
    2.0 * spec.params_enc_layer() as f64 + 4.0 * seq as f64 * spec.d_model as f64
}

/// Forward FLOPs per token for one decoder block (adds cross-attention).
pub fn flops_fwd_dec_block(spec: &ModelSpec, seq: usize) -> f64 {
    2.0 * spec.params_dec_layer() as f64 + 8.0 * seq as f64 * spec.d_model as f64
}

/// Forward FLOPs per token across the whole backbone.
pub fn flops_fwd_backbone_per_token(spec: &ModelSpec, seq: usize) -> f64 {
    spec.enc_layers as f64 * flops_fwd_enc_block(spec, seq)
        + spec.dec_layers as f64 * flops_fwd_dec_block(spec, seq)
}

/// Forward FLOPs per token of the Parallel Adapter side network
/// (adapter blocks at width d/r + the W_down/W_up projections).
pub fn flops_fwd_adapter_per_token(spec: &ModelSpec, seq: usize) -> f64 {
    let d = spec.d_model as f64;
    let da = spec.d_adapter() as f64;
    let dff_a = (spec.d_ff / spec.reduction).max(4) as f64;
    let l = spec.n_blocks() as f64;
    let block = 2.0 * (4.0 * da * da + 2.0 * da * dff_a) + 4.0 * seq as f64 * da;
    let proj = 2.0 * (l + 1.0) * d * da + 2.0 * da * d; // W_down_i + W_up
    l * block + proj
}

/// Per-token training FLOPs for a method (fwd + bwd), **epoch 1** (no
/// cache benefit yet).
pub fn flops_train_per_token(spec: &ModelSpec, method: Method, seq: usize) -> f64 {
    let f = flops_fwd_backbone_per_token(spec, seq);
    let fa = flops_fwd_adapter_per_token(spec, seq);
    match method {
        Method::FullFT => 3.0 * f,
        Method::Adapters { .. } | Method::LoRA { .. } => {
            // fwd + activation-gradient chain (≈1×fwd) + weight grads for
            // the small trainable set (≈ trainable fraction of fwd).
            let frac = method.trainable_params(spec) as f64 / spec.params_total() as f64;
            let peft_fwd = 0.05 * f; // the inserted modules' own compute
            (2.0 + frac) * f + 3.0 * peft_fwd
        }
        Method::ParallelAdapters { .. } => f + 3.0 * fa,
    }
}

/// Per-token training FLOPs in **epoch >= 2** (activation cache warm):
/// Parallel Adapters skip the backbone forward entirely.
pub fn flops_train_cached_per_token(spec: &ModelSpec, method: Method, seq: usize) -> f64 {
    match method {
        Method::ParallelAdapters { cache: true } => {
            3.0 * flops_fwd_adapter_per_token(spec, seq)
        }
        _ => flops_train_per_token(spec, method, seq),
    }
}

/// Inference (single forward) FLOPs per token.
pub fn flops_inference_per_token(spec: &ModelSpec, seq: usize) -> f64 {
    flops_fwd_backbone_per_token(spec, seq)
}

// ---------------------------------------------------------------------------
// Memory
// ---------------------------------------------------------------------------

/// Memory footprint breakdown in bytes (Table I columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryBreakdown {
    /// Model weights resident in memory (backbone at `precision`
    /// + trainable modules at FP32).
    pub weights: u64,
    /// Intermediate activations + optimizer states (Table I convention).
    pub activations: u64,
    /// Gradient buffers for the trainable parameters.
    pub gradients: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.activations + self.gradients
    }
}

/// Backbone activation bytes per token per block for a method (the part
/// proportional to the forward working set).
pub fn act_bytes_per_token_block(spec: &ModelSpec, method: Method) -> f64 {
    let d = spec.d_model as f64;
    let da = spec.d_adapter() as f64;
    let full = ACT_FLOATS_PER_TOKEN * d * 4.0;
    match method {
        Method::FullFT => full,
        Method::Adapters { .. } => KEEP_ADAPTERS * full,
        Method::LoRA { .. } => KEEP_LORA * full,
        Method::ParallelAdapters { cache } => {
            // layer-boundary activation (the cache input) + the adapter's
            // own training working set at width d/r
            let boundary = d * 4.0;
            let adapter = ACT_FLOATS_PER_TOKEN * da * 4.0;
            if cache {
                // backbone forward skipped: boundary slab is streamed from
                // the cache per microbatch, adapter set unchanged
                boundary + adapter
            } else {
                boundary + adapter
            }
        }
    }
}

/// Full memory breakdown for fine-tuning `spec` with `method` on one
/// device hosting the entire model (Table I / Fig. 13(b) / Fig. 15).
///
/// `cache_warm` selects the phase-2 state for `ParallelAdapters{cache}`
/// where the backbone weights are released from memory entirely.
pub fn memory(
    spec: &ModelSpec,
    method: Method,
    precision: Precision,
    wl: Workload,
) -> MemoryBreakdown {
    let trainable = method.trainable_params(spec) as f64;
    let tokens = wl.tokens() as f64;
    let blocks = spec.n_blocks() as f64;

    let cache_warm = method.skips_backbone_with_cache();
    let backbone_bytes = if cache_warm {
        0.0 // paper §IV-B: cache allows releasing the LLM parameters
    } else {
        spec.params_total() as f64 * precision.bytes_per_param()
    };
    let trainable_bytes = match method {
        Method::FullFT => 0.0, // already counted in backbone_bytes
        _ => trainable * 4.0,
    };

    let act = act_bytes_per_token_block(spec, method) * tokens * blocks;
    let opt = match method {
        Method::FullFT => 0.0, // plain SGD (Table I calibration)
        _ => ADAM_STATES * trainable * 4.0,
    };

    MemoryBreakdown {
        weights: (backbone_bytes + trainable_bytes) as u64,
        activations: (act + opt) as u64,
        gradients: (trainable * 4.0) as u64,
    }
}

/// Inference memory (weights only) — Table I's last row.
pub fn memory_inference(spec: &ModelSpec, precision: Precision) -> u64 {
    (spec.params_total() as f64 * precision.bytes_per_param()) as u64
}

/// Bytes crossing a pipeline-stage boundary per micro-batch (forward:
/// boundary activation; for Parallel Adapters the adapter state d/r and
/// the backbone activation both cross).
pub fn stage_boundary_bytes(spec: &ModelSpec, method: Method, wl: Workload) -> u64 {
    let d = spec.d_model as u64;
    let base = wl.tokens() * d * 4;
    match method {
        Method::ParallelAdapters { .. } => base + wl.tokens() * spec.d_adapter() as u64 * 4,
        _ => base,
    }
}

/// Per-sequence activation-cache entry size in bytes (paper §V-B storage
/// analysis: s × h × l floats — plus the embedding layer boundary).
pub fn cache_entry_bytes(spec: &ModelSpec, seq: usize) -> u64 {
    (seq * spec.d_model * (spec.n_blocks() + 1) * 4) as u64
}

const GB: f64 = 1e9;

/// Convenience: bytes -> GB (decimal, as the paper reports).
pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / GB
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t5l() -> ModelSpec {
        ModelSpec::t5_large()
    }

    /// Table I, row "Full": 2.75 / 5.33 / 2.75 GB, total 10.83.
    #[test]
    fn table1_full() {
        let m = memory(&t5l(), Method::FullFT, Precision::FP32, Workload::paper_default());
        assert!((gb(m.weights) - 2.75).abs() < 0.25, "weights {}", gb(m.weights));
        assert!((gb(m.activations) - 5.33).abs() < 0.65, "act {}", gb(m.activations));
        assert!((gb(m.gradients) - 2.75).abs() < 0.25, "grads {}", gb(m.gradients));
        assert!((gb(m.total()) - 10.83).abs() < 1.0, "total {}", gb(m.total()));
    }

    /// Table I, row "Adapters": total 6.89 GB; "LoRA": total 7.13 GB.
    #[test]
    fn table1_peft_rows() {
        let wl = Workload::paper_default();
        let ad = memory(&t5l(), Method::adapters_default(), Precision::FP32, wl);
        assert!((gb(ad.total()) - 6.89).abs() < 0.7, "adapters {}", gb(ad.total()));
        assert!(gb(ad.gradients) < 0.1);
        let lo = memory(&t5l(), Method::lora_default(), Precision::FP32, wl);
        assert!((gb(lo.total()) - 7.13).abs() < 0.7, "lora {}", gb(lo.total()));
    }

    /// Table I, row "Inference": 2.75 GB.
    #[test]
    fn table1_inference() {
        let b = memory_inference(&t5l(), Precision::FP32);
        assert!((gb(b) - 2.75).abs() < 0.25);
    }

    /// Fig. 3 shape: Adapters/LoRA reduce training FLOPs by only ~30%;
    /// forward pass is ~half the PEFT total.
    #[test]
    fn fig3_flops_shape() {
        let spec = ModelSpec::t5_base();
        let full = flops_train_per_token(&spec, Method::FullFT, 128);
        let lora = flops_train_per_token(&spec, Method::lora_default(), 128);
        let ad = flops_train_per_token(&spec, Method::adapters_default(), 128);
        let reduction_lora = 1.0 - lora / full;
        let reduction_ad = 1.0 - ad / full;
        assert!(reduction_lora > 0.2 && reduction_lora < 0.4, "{reduction_lora}");
        assert!(reduction_ad > 0.2 && reduction_ad < 0.4, "{reduction_ad}");
        let fwd = flops_inference_per_token(&spec, 128);
        let share = fwd / ad;
        assert!(share > 0.45 && share < 0.60, "fwd share {share}");
    }

    /// Parallel Adapters cut epoch-1 compute roughly in half vs LoRA and
    /// with a warm cache drop >90% of full-FT compute (Fig. 13(a) shape).
    #[test]
    fn parallel_adapters_flops() {
        let spec = t5l();
        let full = flops_train_per_token(&spec, Method::FullFT, 128);
        let lora = flops_train_per_token(&spec, Method::lora_default(), 128);
        let pa = flops_train_per_token(&spec, Method::pa(false), 128);
        let pa_cached = flops_train_cached_per_token(&spec, Method::pa(true), 128);
        assert!(pa < 0.65 * lora, "pa {pa} vs lora {lora}");
        assert!(pa_cached < 0.1 * full, "cached {pa_cached} vs full {full}");
        // backward through the backbone is eliminated: pa - inference ≈ adapter only
        let inf = flops_inference_per_token(&spec, 128);
        assert!((pa - inf) / (full - inf) < 0.15);
    }

    /// Fig. 13(b)/§VI-D shape: PA reduces memory 25–65% without cache and
    /// 74–89% with cache, vs the strongest baseline.
    #[test]
    fn pa_memory_reductions() {
        let wl = Workload::paper_default();
        for spec in ModelSpec::paper_models() {
            let baseline = [
                memory(&spec, Method::FullFT, Precision::FP32, wl).total(),
                memory(&spec, Method::adapters_default(), Precision::FP32, wl).total(),
                memory(&spec, Method::lora_default(), Precision::FP32, wl).total(),
            ];
            let best_baseline = *baseline.iter().min().unwrap() as f64;
            let pa = memory(&spec, Method::pa(false), Precision::FP32, wl).total() as f64;
            let pa_cache = memory(&spec, Method::pa(true), Precision::FP32, wl).total() as f64;
            let red = 1.0 - pa / best_baseline;
            let red_cache = 1.0 - pa_cache / *baseline.iter().max().unwrap() as f64;
            assert!(red > 0.20 && red < 0.70, "{}: w/o cache {red}", spec.name);
            assert!(red_cache > 0.70, "{}: with cache {red_cache}", spec.name);
        }
    }

    /// §VI-F: INT4 Parallel Adapters cut memory by up to ~88% vs full FT.
    #[test]
    fn quantized_memory_reduction() {
        let wl = Workload::paper_default();
        let spec = t5l();
        let full = memory(&spec, Method::FullFT, Precision::FP32, wl).total() as f64;
        let pa4 = memory(&spec, Method::pa(false), Precision::INT4, wl).total() as f64;
        let red = 1.0 - pa4 / full;
        assert!(red > 0.75, "INT4 PA reduction {red}");
    }

    /// §V-B storage analysis: T5-Base cache for 500 samples of seq 30
    /// is "less than 1 GB" (paper counts s·h·l floats; we add the
    /// embedding boundary slab, landing within ~15% of their bound).
    #[test]
    fn cache_storage_cost() {
        let spec = ModelSpec::t5_base();
        let total = 500 * cache_entry_bytes(&spec, 30);
        assert!(gb(total) < 1.2, "cache {} GB", gb(total));
        assert!(gb(total) > 0.01);
    }

    #[test]
    fn boundary_bytes_monotone_in_batch() {
        let spec = ModelSpec::t5_base();
        let a = stage_boundary_bytes(&spec, Method::FullFT, Workload::new(1, 128));
        let b = stage_boundary_bytes(&spec, Method::FullFT, Workload::new(4, 128));
        assert_eq!(b, 4 * a);
        // PA sends the adapter state too
        let pa = stage_boundary_bytes(&spec, Method::pa(false), Workload::new(1, 128));
        assert!(pa > a);
    }
}
