//! Fine-tuning methods and storage precisions.
//!
//! The paper compares Full model fine-tuning, serial Adapters [10], LoRA
//! [11], and its own Parallel Adapters (with/without the activation cache,
//! with FP32/FP16/INT8/INT4 backbone storage).

use super::config::ModelSpec;

/// Backbone storage precision (paper §IV-D; compute is always FP32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    FP32,
    FP16,
    INT8,
    INT4,
}

impl Precision {
    /// Storage bytes per parameter, including the block-wise scale
    /// overhead for the integer formats (one f32 scale per 64 values).
    pub fn bytes_per_param(self) -> f64 {
        match self {
            Precision::FP32 => 4.0,
            Precision::FP16 => 2.0,
            Precision::INT8 => 1.0 + 4.0 / 64.0,
            Precision::INT4 => 0.5 + 4.0 / 64.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::FP32 => "FP32",
            Precision::FP16 => "FP16",
            Precision::INT8 => "INT8",
            Precision::INT4 => "INT4",
        }
    }

    pub fn all() -> [Precision; 4] {
        [Precision::FP32, Precision::FP16, Precision::INT8, Precision::INT4]
    }
}

/// A fine-tuning algorithm, with its method-specific hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Update every backbone parameter.
    FullFT,
    /// Serial (Houlsby) adapters: bottleneck width m inserted after each
    /// transformer layer. Trainable modules sit *inside* the backbone, so
    /// backprop traverses the whole model.
    Adapters { bottleneck: usize },
    /// LoRA on Wq/Wv of every attention block, rank r.
    LoRA { rank: usize },
    /// The paper's Parallel Adapters (reduction factor from the spec);
    /// `cache` enables the activation cache for epochs >= 2.
    ParallelAdapters { cache: bool },
}

impl Method {
    /// The paper's default hyperparameters (calibrated so trainable-param
    /// counts land on Table I's 12M Adapters / 9M LoRA for T5-Large).
    pub fn adapters_default() -> Method {
        Method::Adapters { bottleneck: 122 }
    }

    pub fn lora_default() -> Method {
        Method::LoRA { rank: 31 }
    }

    pub fn pa(cache: bool) -> Method {
        Method::ParallelAdapters { cache }
    }

    pub fn name(&self) -> String {
        match self {
            Method::FullFT => "Full".into(),
            Method::Adapters { .. } => "Adapters".into(),
            Method::LoRA { .. } => "LoRA".into(),
            Method::ParallelAdapters { cache: false } => "ParallelAdapters".into(),
            Method::ParallelAdapters { cache: true } => "ParallelAdapters+Cache".into(),
        }
    }

    /// Number of trainable parameters for this method on `spec`.
    pub fn trainable_params(&self, spec: &ModelSpec) -> u64 {
        match *self {
            Method::FullFT => spec.params_total(),
            Method::Adapters { bottleneck } => {
                // one bottleneck (down d->m, up m->d) per transformer block
                (spec.n_blocks() * 2 * spec.d_model * bottleneck) as u64
            }
            Method::LoRA { rank } => {
                // Wq and Wv of every attention block (decoder blocks have
                // self- and cross-attention).
                let attn_blocks = spec.enc_layers + 2 * spec.dec_layers;
                (attn_blocks * 2 * 2 * spec.d_model * rank) as u64
            }
            Method::ParallelAdapters { .. } => spec.params_parallel_adapter(),
        }
    }

    /// Whether backpropagation must traverse the backbone (the paper's
    /// central inefficiency observation for Adapters/LoRA, §II/§IV-A).
    pub fn backprop_through_backbone(&self) -> bool {
        !matches!(self, Method::ParallelAdapters { .. })
    }

    /// Whether the backbone forward pass can be skipped entirely once the
    /// activation cache is warm (PAC+ phase 2).
    pub fn skips_backbone_with_cache(&self) -> bool {
        matches!(self, Method::ParallelAdapters { cache: true })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I: T5-Large trainable params — Adapters 12M (1.70%),
    /// LoRA 9M (1.26%).
    #[test]
    fn table1_trainable_params() {
        let spec = ModelSpec::t5_large();
        let ad = Method::adapters_default().trainable_params(&spec) as f64 / 1e6;
        let lo = Method::lora_default().trainable_params(&spec) as f64 / 1e6;
        assert!((ad - 12.0).abs() < 1.0, "adapters {ad}M");
        assert!((lo - 9.0).abs() < 1.0, "lora {lo}M");
        let full = Method::FullFT.trainable_params(&spec);
        assert_eq!(full, spec.params_total());
    }

    #[test]
    fn pa_parameter_fraction_small() {
        let spec = ModelSpec::t5_large();
        let pa = Method::pa(false).trainable_params(&spec) as f64;
        assert!(pa / (spec.params_total() as f64) < 0.04);
    }

    #[test]
    fn backprop_flags() {
        assert!(Method::FullFT.backprop_through_backbone());
        assert!(Method::lora_default().backprop_through_backbone());
        assert!(!Method::pa(false).backprop_through_backbone());
        assert!(!Method::pa(true).backprop_through_backbone());
        assert!(Method::pa(true).skips_backbone_with_cache());
        assert!(!Method::pa(false).skips_backbone_with_cache());
    }

    #[test]
    fn precision_bytes_ordering() {
        let b: Vec<f64> = Precision::all().iter().map(|p| p.bytes_per_param()).collect();
        assert!(b.windows(2).all(|w| w[0] > w[1]), "{b:?}");
        assert!(Precision::INT4.bytes_per_param() < 0.6);
    }
}
