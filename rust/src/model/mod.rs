//! Transformer model descriptors, PEFT method definitions, the layer graph
//! used by the planner, and the analytic FLOPs/memory cost model calibrated
//! against the paper's Table I / Fig. 3.

pub mod config;
pub mod cost;
pub mod graph;
pub mod peft;

pub use config::ModelSpec;
pub use cost::{MemoryBreakdown, Workload};
pub use graph::{Block, LayerGraph};
pub use peft::{Method, Precision};
