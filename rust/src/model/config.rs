//! Model specifications: the paper's evaluation LLMs (Table III) plus the
//! runnable configs mirroring `python/compile/configs.py`.

/// A transformer model description. The paper's models are encoder-decoder
/// ("en-de" in Table III, where `layers` counts each side); the runnable
/// configs are encoder-only (`dec_layers == 0`) — see DESIGN.md §2.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub enc_layers: usize,
    pub dec_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// Adapter width-reduction factor r (paper §IV-A; evaluation uses 8).
    pub reduction: usize,
}

impl ModelSpec {
    // ---- paper models (Table III) ----------------------------------------

    pub fn t5_base() -> ModelSpec {
        ModelSpec {
            name: "T5-Base".into(),
            enc_layers: 12,
            dec_layers: 12,
            d_model: 768,
            n_heads: 12,
            d_ff: 3072,
            vocab: 32128,
            reduction: 8,
        }
    }

    pub fn bart_large() -> ModelSpec {
        ModelSpec {
            name: "BART-Large".into(),
            enc_layers: 12,
            dec_layers: 12,
            d_model: 1024,
            n_heads: 16,
            d_ff: 4096,
            vocab: 50265,
            reduction: 8,
        }
    }

    pub fn t5_large() -> ModelSpec {
        ModelSpec {
            name: "T5-Large".into(),
            enc_layers: 24,
            dec_layers: 24,
            d_model: 1024,
            n_heads: 16,
            d_ff: 4096,
            vocab: 32128,
            reduction: 8,
        }
    }

    /// All three paper evaluation models, smallest first.
    pub fn paper_models() -> Vec<ModelSpec> {
        vec![Self::t5_base(), Self::bart_large(), Self::t5_large()]
    }

    // ---- runnable configs (must mirror python/compile/configs.py) --------

    pub fn tiny() -> ModelSpec {
        ModelSpec {
            name: "tiny".into(),
            enc_layers: 2,
            dec_layers: 0,
            d_model: 32,
            n_heads: 2,
            d_ff: 64,
            vocab: 128,
            reduction: 4,
        }
    }

    pub fn small() -> ModelSpec {
        ModelSpec {
            name: "small".into(),
            enc_layers: 4,
            dec_layers: 0,
            d_model: 128,
            n_heads: 4,
            d_ff: 256,
            vocab: 1000,
            reduction: 8,
        }
    }

    pub fn base100m() -> ModelSpec {
        ModelSpec {
            name: "base100m".into(),
            enc_layers: 12,
            dec_layers: 0,
            d_model: 768,
            n_heads: 12,
            d_ff: 3072,
            vocab: 16000,
            reduction: 8,
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name.to_ascii_lowercase().as_str() {
            "t5-base" | "t5base" => Some(Self::t5_base()),
            "bart-large" | "bartlarge" => Some(Self::bart_large()),
            "t5-large" | "t5large" => Some(Self::t5_large()),
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            "base100m" => Some(Self::base100m()),
            _ => None,
        }
    }

    // ---- derived quantities ----------------------------------------------

    /// Total transformer blocks (encoder + decoder layers).
    pub fn n_blocks(&self) -> usize {
        self.enc_layers + self.dec_layers
    }

    /// Parameters of one encoder layer: self-attn (4 d²) + FFN (2 d·ff)
    /// + 2 norm scales.
    pub fn params_enc_layer(&self) -> u64 {
        (4 * self.d_model * self.d_model
            + 2 * self.d_model * self.d_ff
            + 2 * self.d_model) as u64
    }

    /// Decoder layer adds cross-attention (another 4 d²) + a third norm.
    pub fn params_dec_layer(&self) -> u64 {
        self.params_enc_layer() + (4 * self.d_model * self.d_model + self.d_model) as u64
    }

    /// Embedding table (shared input/output, as in T5/BART).
    pub fn params_embedding(&self) -> u64 {
        (self.vocab * self.d_model) as u64
    }

    pub fn params_total(&self) -> u64 {
        self.params_embedding()
            + self.enc_layers as u64 * self.params_enc_layer()
            + self.dec_layers as u64 * self.params_dec_layer()
            + self.d_model as u64 // final norm
    }

    /// Adapter hidden width d/r.
    pub fn d_adapter(&self) -> usize {
        (self.d_model / self.reduction).max(1)
    }

    /// Parallel Adapter parameter count (mirrors configs.py formula,
    /// generalized to en-de blocks).
    pub fn params_parallel_adapter(&self) -> u64 {
        let d = self.d_model as u64;
        let da = self.d_adapter() as u64;
        let dff_a = (self.d_ff / self.reduction).max(4) as u64;
        let l = self.n_blocks() as u64;
        let per_layer = 2 * da + 4 * da * da + 2 * da * dff_a;
        (l + 1) * d * da      // W_down_0..L
            + l               // lambda_i
            + l * per_layer
            + da * d          // W_up
            + 2 * d           // head (approx: d x C + C with small C)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table III parameter counts: 0.25B / 0.41B / 0.74B.
    #[test]
    fn paper_param_counts() {
        let t5b = ModelSpec::t5_base().params_total() as f64 / 1e9;
        let bart = ModelSpec::bart_large().params_total() as f64 / 1e9;
        let t5l = ModelSpec::t5_large().params_total() as f64 / 1e9;
        assert!((t5b - 0.25).abs() < 0.03, "t5-base {t5b}B");
        assert!((bart - 0.41).abs() < 0.03, "bart-large {bart}B");
        assert!((t5l - 0.74).abs() < 0.03, "t5-large {t5l}B");
    }

    #[test]
    fn adapter_is_parameter_efficient() {
        for spec in ModelSpec::paper_models() {
            let frac = spec.params_parallel_adapter() as f64 / spec.params_total() as f64;
            assert!(frac < 0.06, "{}: adapter fraction {frac}", spec.name);
        }
    }

    #[test]
    fn runnable_matches_python() {
        // python configs.py: base100m backbone 97.0M params
        let b = ModelSpec::base100m();
        let total = b.params_total();
        // python counts pos_emb too; allow 1% slack
        let py = 97_036_032u64;
        let diff = (total as i64 - py as i64).unsigned_abs();
        assert!((diff as f64) / (py as f64) < 0.01, "rust {total} vs python {py}");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["t5-base", "bart-large", "t5-large", "tiny", "small", "base100m"] {
            assert!(ModelSpec::by_name(n).is_some(), "{n}");
        }
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn dec_layer_heavier_than_enc() {
        let s = ModelSpec::t5_base();
        assert!(s.params_dec_layer() > s.params_enc_layer());
    }
}
