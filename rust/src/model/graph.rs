//! The layer graph the planner partitions: a linear chain of blocks
//! (embedding → encoder layers → decoder layers → head), as in the paper's
//! Fig. 9/10 where an LLM is cut into consecutive stages.

use super::config::ModelSpec;
use super::cost;
use super::peft::{Method, Precision};
use crate::model::Workload;

/// One partitionable unit of the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Block {
    /// Token + positional embedding (attached to stage 0).
    Embed,
    /// One encoder transformer layer.
    Enc,
    /// One decoder transformer layer (cross-attention included).
    Dec,
    /// Final norm + LM/classification head (attached to the last stage).
    Head,
}

/// Linear chain of blocks for a model, with per-block cost queries.
#[derive(Debug, Clone)]
pub struct LayerGraph {
    pub spec: ModelSpec,
    pub blocks: Vec<Block>,
}

impl LayerGraph {
    pub fn new(spec: ModelSpec) -> LayerGraph {
        let mut blocks = vec![Block::Embed];
        blocks.extend(std::iter::repeat(Block::Enc).take(spec.enc_layers));
        blocks.extend(std::iter::repeat(Block::Dec).take(spec.dec_layers));
        blocks.push(Block::Head);
        LayerGraph { spec, blocks }
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Parameter count of one block.
    pub fn block_params(&self, i: usize) -> u64 {
        match self.blocks[i] {
            Block::Embed => self.spec.params_embedding(),
            Block::Enc => self.spec.params_enc_layer(),
            Block::Dec => self.spec.params_dec_layer(),
            Block::Head => self.spec.d_model as u64, // final norm (head shares emb)
        }
    }

    /// Weight bytes of blocks `[x, y)` at a given backbone precision.
    pub fn span_weight_bytes(&self, x: usize, y: usize, precision: Precision) -> u64 {
        (x..y)
            .map(|i| (self.block_params(i) as f64 * precision.bytes_per_param()) as u64)
            .sum()
    }

    /// Forward FLOPs of block `i` for `tokens` tokens at sequence `seq`.
    pub fn block_flops_fwd(&self, i: usize, tokens: u64, seq: usize) -> f64 {
        let t = tokens as f64;
        match self.blocks[i] {
            Block::Embed => 2.0 * t * self.spec.d_model as f64, // lookup + pos add
            Block::Enc => t * cost::flops_fwd_enc_block(&self.spec, seq),
            Block::Dec => t * cost::flops_fwd_dec_block(&self.spec, seq),
            Block::Head => 2.0 * t * self.spec.d_model as f64,
        }
    }

    /// Backward FLOPs of block `i` under `method` (epoch-1 semantics).
    ///
    /// * Full FT: 2× forward.
    /// * Adapters/LoRA: the activation-gradient chain (≈1× fwd) plus the
    ///   small trainable-weight gradients.
    /// * Parallel Adapters: **zero** on backbone blocks — the paper's
    ///   gradient highway; the adapter's own fwd+bwd is charged via
    ///   [`Self::block_adapter_flops`].
    pub fn block_flops_bwd(&self, i: usize, method: Method, tokens: u64, seq: usize) -> f64 {
        let fwd = self.block_flops_fwd(i, tokens, seq);
        match method {
            Method::FullFT => 2.0 * fwd,
            Method::Adapters { .. } | Method::LoRA { .. } => {
                let frac = method.trainable_params(&self.spec) as f64
                    / self.spec.params_total() as f64;
                (1.0 + frac + 0.15) * fwd
            }
            Method::ParallelAdapters { .. } => 0.0,
        }
    }

    /// Parallel-Adapter compute attached to block `i` (fwd + bwd of the
    /// adapter slice riding alongside this backbone block).
    pub fn block_adapter_flops(&self, i: usize, method: Method, tokens: u64, seq: usize) -> f64 {
        if !matches!(method, Method::ParallelAdapters { .. }) {
            return 0.0;
        }
        match self.blocks[i] {
            Block::Embed | Block::Head => 0.0,
            Block::Enc | Block::Dec => {
                let per_token =
                    cost::flops_fwd_adapter_per_token(&self.spec, seq) / self.spec.n_blocks() as f64;
                3.0 * per_token * tokens as f64
            }
        }
    }

    /// Activation bytes block `i` must hold per in-flight micro-batch.
    pub fn block_act_bytes(&self, i: usize, method: Method, wl: Workload) -> u64 {
        match self.blocks[i] {
            Block::Embed | Block::Head => {
                (wl.tokens() * self.spec.d_model as u64) * 4
            }
            Block::Enc | Block::Dec => {
                (cost::act_bytes_per_token_block(&self.spec, method) * wl.tokens() as f64) as u64
            }
        }
    }

    /// Trainable parameter bytes hosted by blocks `[x, y)` (what a stage
    /// AllReduces after each mini-batch).
    pub fn span_trainable_bytes(&self, x: usize, y: usize, method: Method) -> u64 {
        let total = method.trainable_params(&self.spec) as f64 * 4.0;
        let span_blocks = (x..y)
            .filter(|&i| matches!(self.blocks[i], Block::Enc | Block::Dec))
            .count() as f64;
        (total * span_blocks / self.spec.n_blocks() as f64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_shape() {
        let g = LayerGraph::new(ModelSpec::t5_base());
        assert_eq!(g.len(), 1 + 12 + 12 + 1);
        assert_eq!(g.blocks[0], Block::Embed);
        assert_eq!(g.blocks[1], Block::Enc);
        assert_eq!(g.blocks[13], Block::Dec);
        assert_eq!(*g.blocks.last().unwrap(), Block::Head);
    }

    #[test]
    fn block_params_sum_to_total() {
        for spec in ModelSpec::paper_models() {
            let g = LayerGraph::new(spec.clone());
            let sum: u64 = (0..g.len()).map(|i| g.block_params(i)).sum();
            // graph omits nothing but the final-norm rounding
            let diff = (sum as i64 - spec.params_total() as i64).abs();
            assert!(diff < 1_000_000, "{}: {diff}", spec.name);
        }
    }

    #[test]
    fn pa_has_no_backbone_bwd() {
        let g = LayerGraph::new(ModelSpec::t5_base());
        let tokens = 2048;
        assert_eq!(g.block_flops_bwd(1, Method::pa(false), tokens, 128), 0.0);
        assert!(g.block_flops_bwd(1, Method::FullFT, tokens, 128) > 0.0);
        assert!(g.block_adapter_flops(1, Method::pa(false), tokens, 128) > 0.0);
        assert_eq!(g.block_adapter_flops(1, Method::FullFT, tokens, 128), 0.0);
    }

    #[test]
    fn bwd_cheaper_for_peft_than_full() {
        let g = LayerGraph::new(ModelSpec::t5_large());
        let full = g.block_flops_bwd(1, Method::FullFT, 2048, 128);
        let lora = g.block_flops_bwd(1, Method::lora_default(), 2048, 128);
        assert!(lora < 0.7 * full);
    }

    #[test]
    fn span_weight_bytes_precision() {
        let g = LayerGraph::new(ModelSpec::t5_base());
        let f32b = g.span_weight_bytes(0, g.len(), Precision::FP32);
        let i8b = g.span_weight_bytes(0, g.len(), Precision::INT8);
        assert!(i8b * 3 < f32b, "int8 {i8b} vs f32 {f32b}");
    }

    #[test]
    fn trainable_bytes_partition() {
        let g = LayerGraph::new(ModelSpec::t5_base());
        let m = Method::pa(false);
        let whole = g.span_trainable_bytes(0, g.len(), m);
        let a = g.span_trainable_bytes(0, 13, m);
        let b = g.span_trainable_bytes(13, g.len(), m);
        let diff = (whole as i64 - (a + b) as i64).abs();
        assert!(diff < 16, "{a}+{b} vs {whole}");
    }
}
