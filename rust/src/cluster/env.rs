//! Evaluation environment presets (§VI-A).

use super::device::{Device, DeviceKind};
use super::network::Network;

/// A concrete edge cluster: devices + interconnect.
#[derive(Debug, Clone)]
pub struct Env {
    pub name: String,
    pub devices: Vec<Device>,
    pub network: Network,
}

impl Env {
    /// Homogeneous Environment A: 4 × Nano-H on a 1 Gbps LAN.
    pub fn env_a() -> Env {
        Env::homogeneous("Env.A", DeviceKind::NanoH, 4)
    }

    /// Heterogeneous Environment B: 1×Nano-H + 1×Nano-L + 1×TX2-H + 1×TX2-L.
    pub fn env_b() -> Env {
        Env {
            name: "Env.B".into(),
            devices: vec![
                Device::new(0, DeviceKind::Tx2H),
                Device::new(1, DeviceKind::Tx2L),
                Device::new(2, DeviceKind::NanoH),
                Device::new(3, DeviceKind::NanoL),
            ],
            network: Network::lan_1gbps(),
        }
    }

    /// n × Nano-H (the §VI-D/§VI-G scalability clusters use up to 8).
    pub fn nanos(n: usize) -> Env {
        Env::homogeneous(&format!("{n}xNano-H"), DeviceKind::NanoH, n)
    }

    pub fn homogeneous(name: &str, kind: DeviceKind, n: usize) -> Env {
        Env {
            name: name.into(),
            devices: (0..n).map(|i| Device::new(i, kind)).collect(),
            network: Network::lan_1gbps(),
        }
    }

    /// Single device (the Standalone baseline).
    pub fn standalone(kind: DeviceKind) -> Env {
        Env::homogeneous(&format!("1x{}", kind.name()), kind, 1)
    }

    pub fn by_name(name: &str) -> Option<Env> {
        match name.to_ascii_lowercase().as_str() {
            "env_a" | "env-a" | "a" => Some(Env::env_a()),
            "env_b" | "env-b" | "b" => Some(Env::env_b()),
            s if s.ends_with("nano") => {
                s.trim_end_matches("nano").trim_end_matches('x').parse().ok().map(Env::nanos)
            }
            _ => None,
        }
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Devices sorted fastest-first — the order the planner's `D_n`
    /// prefixes consume (puts the strongest devices in every sub-problem).
    pub fn devices_fastest_first(&self) -> Vec<Device> {
        let mut d = self.devices.clone();
        d.sort_by(|a, b| {
            b.kind
                .effective_flops()
                .partial_cmp(&a.kind.effective_flops())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        d
    }

    /// Aggregate compute of the cluster (for utilization reporting).
    pub fn total_effective_flops(&self) -> f64 {
        self.devices.iter().map(|d| d.kind.effective_flops()).sum()
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.devices
            .windows(2)
            .any(|w| w[0].kind.effective_flops() != w[1].kind.effective_flops())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let a = Env::env_a();
        assert_eq!(a.n(), 4);
        assert!(!a.is_heterogeneous());
        let b = Env::env_b();
        assert_eq!(b.n(), 4);
        assert!(b.is_heterogeneous());
    }

    #[test]
    fn fastest_first_ordering() {
        let b = Env::env_b();
        let d = b.devices_fastest_first();
        for w in d.windows(2) {
            assert!(w[0].kind.effective_flops() >= w[1].kind.effective_flops());
        }
        assert_eq!(d[0].kind, DeviceKind::Tx2H);
        assert_eq!(d[3].kind, DeviceKind::NanoL);
    }

    #[test]
    fn by_name() {
        assert_eq!(Env::by_name("env_a").unwrap().n(), 4);
        assert_eq!(Env::by_name("8xnano").unwrap().n(), 8);
        assert!(Env::by_name("datacenter").is_none());
    }

    #[test]
    fn unique_ids() {
        let e = Env::nanos(8);
        let mut ids: Vec<_> = e.devices.iter().map(|d| d.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 8);
    }
}
