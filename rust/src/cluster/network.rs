//! LAN model (§VI-A: 1000 Mbps intra-cluster bandwidth).

/// A shared-medium local network connecting the edge devices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Network {
    /// Point-to-point bandwidth in bytes/s.
    pub bandwidth: f64,
    /// Per-transfer latency in seconds (switch + stack).
    pub latency: f64,
}

impl Network {
    /// The paper's evaluation network: 1000 Mbps LAN.
    pub fn lan_1gbps() -> Network {
        Network { bandwidth: 1000e6 / 8.0, latency: 0.5e-3 }
    }

    /// A slower Wi-Fi-class network (for sensitivity studies).
    pub fn wifi_100mbps() -> Network {
        Network { bandwidth: 100e6 / 8.0, latency: 2e-3 }
    }

    /// Time to move `bytes` point-to-point.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Ring AllReduce over `n` participants of a `bytes`-sized buffer:
    /// 2·(n−1)/n · bytes per link, plus 2·(n−1) latency hops.
    pub fn allreduce_time(&self, bytes: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let n = n as f64;
        2.0 * (n - 1.0) / n * bytes as f64 / self.bandwidth + 2.0 * (n - 1.0) * self.latency
    }

    /// All-gather of `bytes` per participant to all `n` participants
    /// (used for the cache/parameter redistribution step, §V-B).
    pub fn allgather_time(&self, bytes_per_rank: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let n_f = n as f64;
        (n_f - 1.0) * bytes_per_rank as f64 / self.bandwidth + (n_f - 1.0) * self.latency
    }

    /// Broadcast `bytes` from one rank to `n−1` others (pipelined ring).
    pub fn broadcast_time(&self, bytes: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        bytes as f64 / self.bandwidth + (n as f64 - 1.0) * self.latency
    }

    /// Parameter-server star: one server ingests `n` uploads of `bytes`
    /// each over its single shared link, so transfers serialize — the
    /// classic star-topology aggregation bottleneck the federated
    /// experiments compare against the ring collectives.
    pub fn star_gather_time(&self, bytes: u64, n: usize) -> f64 {
        n as f64 * (self.latency + bytes as f64 / self.bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gigabit_transfer() {
        let net = Network::lan_1gbps();
        // 125 MB at 125 MB/s ≈ 1 s
        let t = net.transfer_time(125_000_000);
        assert!((t - 1.0005).abs() < 0.01, "{t}");
    }

    #[test]
    fn allreduce_scales() {
        let net = Network::lan_1gbps();
        assert_eq!(net.allreduce_time(1_000_000, 1), 0.0);
        let t2 = net.allreduce_time(100_000_000, 2);
        let t8 = net.allreduce_time(100_000_000, 8);
        // ring allreduce volume approaches 2x buffer as n grows
        assert!(t8 > t2);
        assert!(t8 < 2.5 * t2);
    }

    #[test]
    fn allgather_grows_linearly() {
        let net = Network::lan_1gbps();
        let t2 = net.allgather_time(10_000_000, 2);
        let t4 = net.allgather_time(10_000_000, 4);
        assert!(t4 > 2.0 * t2 * 0.9);
    }

    #[test]
    fn star_gather_serializes_uploads() {
        let net = Network::lan_1gbps();
        assert_eq!(net.star_gather_time(1_000_000, 0), 0.0);
        let t1 = net.star_gather_time(10_000_000, 1);
        assert!((t1 - net.transfer_time(10_000_000)).abs() < 1e-12);
        let t4 = net.star_gather_time(10_000_000, 4);
        assert!((t4 - 4.0 * t1).abs() < 1e-9, "star serializes: {t4} vs 4x{t1}");
        // past a couple of participants the star loses to the ring
        assert!(net.star_gather_time(10_000_000, 8) > net.allreduce_time(10_000_000, 8));
    }

    #[test]
    fn wifi_slower_than_lan() {
        let b = 50_000_000;
        assert!(
            Network::wifi_100mbps().transfer_time(b) > Network::lan_1gbps().transfer_time(b)
        );
    }
}
