//! Edge-cluster substrate: calibrated device performance models, the LAN
//! model, and the paper's testbed environment presets (Table IV, §VI-A).
//!
//! We do not have the paper's physical Jetson boards; per DESIGN.md §2 the
//! devices are performance models (peak FLOPS × a training-efficiency
//! factor calibrated against the paper's measured epoch times) that drive
//! the discrete-event schedule simulator. All heterogeneity structure
//! (2 device families × 2 power modes, 4 GB vs 8 GB memory walls) matches
//! Table IV.

pub mod device;
pub mod env;
pub mod network;

pub use device::{Device, DeviceKind};
pub use env::Env;
pub use network::Network;
