//! Edge-device performance models (paper Table IV).

/// The device types used in the paper's evaluation, with both power modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Jetson Nano, 10 W mode (921 MHz, 128-core Maxwell, 4 GB).
    NanoH,
    /// Jetson Nano, 5 W mode (640 MHz).
    NanoL,
    /// Jetson TX2, 15 W mode (1.3 GHz, 256-core Pascal, 8 GB).
    Tx2H,
    /// Jetson TX2, 7.5 W mode (850 MHz).
    Tx2L,
}

impl DeviceKind {
    /// Peak throughput in FLOP/s.
    ///
    /// Nano-H: 472 GFLOPS (the paper quotes 0.47 TFLOPS, §II-B); scaled by
    /// GPU frequency for the low-power modes; TX2 at ~1.33 TFLOPS
    /// (256 Pascal cores @ 1.3 GHz).
    pub fn peak_flops(self) -> f64 {
        match self {
            DeviceKind::NanoH => 472e9,
            DeviceKind::NanoL => 472e9 * 640.0 / 921.0,
            DeviceKind::Tx2H => 1330e9,
            DeviceKind::Tx2L => 1330e9 * 850.0 / 1300.0,
        }
    }

    /// Total DRAM (Table IV "Memory Budget").
    pub fn dram_bytes(self) -> u64 {
        match self {
            DeviceKind::NanoH | DeviceKind::NanoL => 4 * 1024 * 1024 * 1024,
            DeviceKind::Tx2H | DeviceKind::Tx2L => 8 * 1024 * 1024 * 1024,
        }
    }

    /// Memory budget available to training. Jetsons share DRAM between
    /// CPU and GPU; the OS, CUDA context and framework runtime reserve
    /// ~1.5 GB (§II-B: "typical mobile devices ... run both system
    /// software and applications").
    pub fn mem_budget(self) -> u64 {
        self.dram_bytes() - 1536 * 1024 * 1024
    }

    /// Achieved fraction of peak on transformer fine-tuning workloads.
    ///
    /// Calibrated so that T5-Base + Adapters on one Nano-H takes the
    /// paper's measured 72.6 min/epoch on MRPC (§II-B) — see the test.
    pub fn efficiency(self) -> f64 {
        match self {
            DeviceKind::NanoH | DeviceKind::NanoL => 0.24,
            // newer Pascal cores sustain slightly better utilization
            DeviceKind::Tx2H | DeviceKind::Tx2L => 0.28,
        }
    }

    /// Effective sustained FLOP/s for training kernels.
    pub fn effective_flops(self) -> f64 {
        self.peak_flops() * self.efficiency()
    }

    /// The same board's low-power mode (identity for the low modes) —
    /// what a thermal/battery-saver downclock degrades a device to.
    pub fn low_power(self) -> DeviceKind {
        match self {
            DeviceKind::NanoH | DeviceKind::NanoL => DeviceKind::NanoL,
            DeviceKind::Tx2H | DeviceKind::Tx2L => DeviceKind::Tx2L,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::NanoH => "Nano-H",
            DeviceKind::NanoL => "Nano-L",
            DeviceKind::Tx2H => "TX2-H",
            DeviceKind::Tx2L => "TX2-L",
        }
    }

    pub const ALL: [DeviceKind; 4] =
        [DeviceKind::NanoH, DeviceKind::NanoL, DeviceKind::Tx2H, DeviceKind::Tx2L];

    /// Parse a kind by display name (case-insensitive, `_`/`-`
    /// agnostic) — the inverse of [`name`](DeviceKind::name), used by
    /// the churn-trace file format.
    pub fn parse(s: &str) -> Option<DeviceKind> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "nano-h" | "nanoh" | "nano" => Some(DeviceKind::NanoH),
            "nano-l" | "nanol" => Some(DeviceKind::NanoL),
            "tx2-h" | "tx2h" | "tx2" => Some(DeviceKind::Tx2H),
            "tx2-l" | "tx2l" => Some(DeviceKind::Tx2L),
            _ => None,
        }
    }
}

/// A concrete device instance in a cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub id: usize,
    pub kind: DeviceKind,
}

impl Device {
    pub fn new(id: usize, kind: DeviceKind) -> Device {
        Device { id, kind }
    }

    /// Time to execute `flops` of training compute on this device, with a
    /// small per-kernel launch overhead (visible at tiny batch sizes).
    pub fn compute_time(&self, flops: f64) -> f64 {
        const LAUNCH_OVERHEAD: f64 = 150e-6; // per fused block execution
        flops / self.kind.effective_flops() + LAUNCH_OVERHEAD
    }

    pub fn mem_budget(&self) -> u64 {
        self.kind.mem_budget()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{cost, Method, ModelSpec};

    /// §II-B: "Fine-tuning a T5-Base model with Adapters on a single
    /// Jetson Nano requires an epoch time of 72.6 minutes" (MRPC: 3668
    /// samples, seq 128).
    #[test]
    fn nano_epoch_time_calibration() {
        let spec = ModelSpec::t5_base();
        let per_token =
            cost::flops_train_per_token(&spec, Method::adapters_default(), 128);
        let tokens = 3668.0 * 128.0;
        let secs = per_token * tokens / DeviceKind::NanoH.effective_flops();
        let minutes = secs / 60.0;
        assert!(
            (minutes - 72.6).abs() < 15.0,
            "calibration off: {minutes} min vs paper 72.6"
        );
    }

    /// §II-B: the A100 runs the same workload ~175.5× faster. With 312
    /// TFLOPS peak at ~0.45 efficiency the ratio lands in range.
    #[test]
    fn a100_speedup_ratio_plausible() {
        let a100_eff = 312e12 * 0.45;
        let ratio = a100_eff / DeviceKind::NanoH.effective_flops();
        assert!(ratio > 100.0 && ratio < 2500.0, "ratio {ratio}");
    }

    #[test]
    fn power_modes_scale_frequency() {
        assert!(DeviceKind::NanoL.peak_flops() < DeviceKind::NanoH.peak_flops());
        assert!(DeviceKind::Tx2L.peak_flops() < DeviceKind::Tx2H.peak_flops());
        let r = DeviceKind::NanoL.peak_flops() / DeviceKind::NanoH.peak_flops();
        assert!((r - 640.0 / 921.0).abs() < 1e-9);
    }

    #[test]
    fn parse_inverts_name() {
        for kind in DeviceKind::ALL {
            assert_eq!(DeviceKind::parse(kind.name()), Some(kind));
            assert_eq!(DeviceKind::parse(&kind.name().to_ascii_lowercase()), Some(kind));
        }
        assert_eq!(DeviceKind::parse("nano_h"), Some(DeviceKind::NanoH));
        assert_eq!(DeviceKind::parse("a100"), None);
    }

    #[test]
    fn low_power_pairs() {
        assert_eq!(DeviceKind::NanoH.low_power(), DeviceKind::NanoL);
        assert_eq!(DeviceKind::NanoL.low_power(), DeviceKind::NanoL);
        assert_eq!(DeviceKind::Tx2H.low_power(), DeviceKind::Tx2L);
        assert_eq!(DeviceKind::Tx2L.low_power(), DeviceKind::Tx2L);
    }

    #[test]
    fn memory_budgets() {
        assert!(DeviceKind::NanoH.mem_budget() < 4 * 1024 * 1024 * 1024);
        assert!(DeviceKind::Tx2H.mem_budget() > DeviceKind::NanoH.mem_budget());
    }

    #[test]
    fn compute_time_monotone() {
        let d = Device::new(0, DeviceKind::NanoH);
        assert!(d.compute_time(1e9) < d.compute_time(2e9));
        // launch overhead dominates tiny work
        assert!(d.compute_time(0.0) > 0.0);
    }
}
