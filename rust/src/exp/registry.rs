//! The [`Experiment`] trait and its name-addressed registry.
//!
//! Mirrors the strategy layer's open design
//! ([`crate::strategy::StrategyRegistry`]): every paper table, figure
//! and design ablation is an [`Experiment`] resolved by name (or alias)
//! through [`ExperimentRegistry::with_defaults`], producing a typed
//! [`Report`] that renders as text, JSON or CSV. The CLI
//! (`pacpp exp <list|run|all>`) and the bench harness address
//! experiments exclusively through this registry, so a registered
//! experiment is immediately reachable everywhere.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::accuracy::Budget;
use super::report::{Cell, ColType, Report, ELAPSED_SECS_META};
use super::tables::TABLE_SEQ;
use crate::cluster::Env;
use crate::data::Task;
use crate::model::{Method, ModelSpec};
use crate::runtime::Runtime;
use crate::strategy::{StrategyRegistry, TrainJob};

/// Shared inputs an experiment may draw on.
///
/// The simulator-backed experiments need nothing; the real-training
/// experiments (`table6`/`table7`/`fig14`) lazily load the PJRT
/// [`Runtime`] from [`artifacts`](ExpContext::artifacts) on first use
/// (the handle is cached, so `exp all` loads it once).
pub struct ExpContext {
    /// AOT artifact directory for the real-training experiments.
    pub artifacts: String,
    /// Training budget for the real-training experiments.
    pub budget: Budget,
    runtime: Mutex<Option<Arc<Runtime>>>,
}

impl ExpContext {
    pub fn new() -> ExpContext {
        ExpContext::with_artifacts("artifacts/small")
    }

    pub fn with_artifacts(dir: impl Into<String>) -> ExpContext {
        ExpContext {
            artifacts: dir.into(),
            budget: Budget::default(),
            runtime: Mutex::new(None),
        }
    }

    /// The shared runtime handle, loading artifacts on first call.
    pub fn runtime(&self) -> Result<Arc<Runtime>> {
        let mut slot = self.runtime.lock().expect("runtime lock poisoned");
        if let Some(rt) = &*slot {
            return Ok(rt.clone());
        }
        let rt = Arc::new(Runtime::load(&self.artifacts)?);
        *slot = Some(rt.clone());
        Ok(rt)
    }
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext::new()
    }
}

/// One reproducible experiment: a named producer of a [`Report`].
pub trait Experiment: Send + Sync {
    /// Canonical registry name (stable: used by the CLI and in JSON).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`ExperimentRegistry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `pacpp exp list` and docs.
    fn description(&self) -> &str {
        ""
    }

    /// Whether the experiment only reads shared state. Experiments that
    /// drive real training mutate process-global trainer state and are
    /// run serially by [`ExperimentRegistry::run_all`].
    fn parallel_safe(&self) -> bool {
        true
    }

    /// Whether the experiment needs the AOT artifact set (real PJRT
    /// training). Orthogonal to [`parallel_safe`](Experiment::parallel_safe):
    /// callers use this to gate or soft-skip experiments on checkouts
    /// without artifacts.
    fn requires_artifacts(&self) -> bool {
        false
    }

    /// Produce the report.
    fn run(&self, ctx: &ExpContext) -> Result<Report>;
}

/// Plain-function experiment: how every built-in is registered.
struct FnExperiment {
    name: &'static str,
    aliases: &'static [&'static str],
    description: &'static str,
    parallel_safe: bool,
    requires_artifacts: bool,
    run: fn(&ExpContext) -> Result<Report>,
}

impl Experiment for FnExperiment {
    fn name(&self) -> &str {
        self.name
    }
    fn aliases(&self) -> &'static [&'static str] {
        self.aliases
    }
    fn description(&self) -> &str {
        self.description
    }
    fn parallel_safe(&self) -> bool {
        self.parallel_safe
    }
    fn requires_artifacts(&self) -> bool {
        self.requires_artifacts
    }
    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        (self.run)(ctx)
    }
}

impl crate::util::registry::Registered for dyn Experiment {
    fn name(&self) -> &str {
        Experiment::name(self)
    }
    fn aliases(&self) -> &'static [&'static str] {
        Experiment::aliases(self)
    }
    fn describe(&self) -> &str {
        self.description()
    }
}

/// An ordered, name-addressed collection of experiments — a
/// [`crate::util::registry::Registry`] instantiation (uniform
/// resolution semantics; see [`crate::util::registry`]).
///
/// Registration order is preserved (it is the `exp list` / `exp all`
/// order). Canonical names are matched case-insensitively; aliases are
/// lowercase.
pub type ExperimentRegistry = crate::util::registry::Registry<dyn Experiment>;

impl ExperimentRegistry {
    /// An empty registry (build-your-own experiment line-ups).
    pub fn empty() -> ExperimentRegistry {
        crate::util::registry::Registry::new("experiment")
    }

    /// Every table, figure and ablation of the evaluation, plus the
    /// registry-only `sweep` grid.
    pub fn with_defaults() -> ExperimentRegistry {
        let mut r = ExperimentRegistry::empty();
        let defaults: Vec<FnExperiment> = vec![
            FnExperiment {
                name: "fig3",
                aliases: &["flops"],
                description: "Fig. 3 — FLOPs of fine-tuning techniques per mini-batch",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig3_report()),
            },
            FnExperiment {
                name: "table1",
                aliases: &["memory"],
                description: "Table I — memory breakdown, T5-Large",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::table1_report()),
            },
            FnExperiment {
                name: "table5",
                aliases: &["hours"],
                description: "Table V — end-to-end fine-tuning hours, Env.A",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::table5_report()),
            },
            FnExperiment {
                name: "table6",
                aliases: &["quality"],
                description: "Table VI (shape) — fine-tuned quality parity (real training)",
                parallel_safe: false,
                requires_artifacts: true,
                run: |ctx| super::accuracy::table6_report(&ctx.runtime()?, ctx.budget),
            },
            FnExperiment {
                name: "table7",
                aliases: &["quantized"],
                description: "Table VII (shape) — quantized-backbone quality (real training)",
                parallel_safe: false,
                requires_artifacts: true,
                run: |ctx| super::accuracy::table7_report(&ctx.runtime()?, ctx.budget),
            },
            FnExperiment {
                name: "fig12",
                aliases: &["hetero"],
                description: "Fig. 12 — PAC+ vs Asteroid/HetPipe under heterogeneity, Env.B",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig12_report()),
            },
            FnExperiment {
                name: "fig13",
                aliases: &["breakdown"],
                description: "Fig. 13 — per-sample time + memory breakdown, 8x Nano-H",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig13_report()),
            },
            FnExperiment {
                name: "fig14",
                aliases: &["init"],
                description: "Fig. 14 (shape) — adapter weight-init strategies (real training)",
                parallel_safe: false,
                requires_artifacts: true,
                run: |ctx| super::accuracy::fig14_report(&ctx.runtime()?, ctx.budget),
            },
            FnExperiment {
                name: "fig15",
                aliases: &["quant-mem"],
                description: "Fig. 15 — memory vs model size x precision",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig15_report()),
            },
            FnExperiment {
                name: "fig16",
                aliases: &["scalability"],
                description: "Fig. 16 — scalability of DP/PP/PAC+ over 2-8 devices",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig16_report()),
            },
            FnExperiment {
                name: "fig17",
                aliases: &["groupings"],
                description: "Fig. 17 — planner device groupings",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig17_report()),
            },
            FnExperiment {
                name: "fig18",
                aliases: &["cache"],
                description: "Fig. 18 — activation-cache benefit vs epoch count",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::tables::fig18_report()),
            },
            FnExperiment {
                name: "ablate_schedule",
                aliases: &["schedule"],
                description: "Ablation — 1F1B vs GPipe-style scheduling",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::ablations::schedule_report()),
            },
            FnExperiment {
                name: "ablate_bandwidth",
                aliases: &["bandwidth"],
                description: "Ablation — LAN vs Wi-Fi bandwidth sensitivity per system",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::ablations::bandwidth_report()),
            },
            FnExperiment {
                name: "ablate_microbatches",
                aliases: &["microbatches"],
                description: "Ablation — pipelining depth M sweep",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::ablations::microbatches_report()),
            },
            FnExperiment {
                name: "sweep",
                aliases: &["grid"],
                description:
                    "Sweep — long-form env x model x strategy grid (registry-only)",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(sweep_report()),
            },
            FnExperiment {
                name: "fleet",
                aliases: &["multi-tenant"],
                description:
                    "Fleet — multi-tenant scheduling grid, policy x trace x env (stable pool)",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::fleet::fleet_report()),
            },
            FnExperiment {
                name: "fleet_churn",
                aliases: &["fleet-churn", "churn"],
                description:
                    "Fleet — the same grid under device churn (joins/leaves/degrades)",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::fleet::fleet_churn_report()),
            },
            FnExperiment {
                name: "fleet_checkpoint",
                aliases: &["checkpoint", "ckpt"],
                description:
                    "Fleet — checkpoint interval k vs restart loss/overhead under churn",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::fleet::fleet_checkpoint_report()),
            },
            FnExperiment {
                name: "fleet_users",
                aliases: &["users", "slo"],
                description:
                    "Fleet — per-user SLO breakdown: p95, deadline hits, fairness shares",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::fleet::fleet_users_report()),
            },
            FnExperiment {
                name: "fed",
                aliases: &["federated"],
                description:
                    "Fed — federated adapter aggregation, selection x straggler grid",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::fed::fed_report()),
            },
            FnExperiment {
                name: "fed_select",
                aliases: &["fed-select", "selection"],
                description:
                    "Fed — client selection x availability trace x network grid",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| Ok(super::fed::fed_select_report()),
            },
            FnExperiment {
                name: "fleet_learn",
                aliases: &["learn", "rl", "dqn"],
                description:
                    "Learn — in-sim DQN training curve + eval vs FIFO/backfill/EDF",
                parallel_safe: true,
                requires_artifacts: false,
                run: |_| super::learn::fleet_learn_report(),
            },
        ];
        for e in defaults {
            r.register(Arc::new(e));
        }
        r
    }

    /// Run one experiment by name or alias, stamping the wall-clock it
    /// took into the report's [`ELAPSED_SECS_META`] metadata (rendered
    /// as the text footer, never part of any equality-tested cell).
    pub fn run(&self, name: &str, ctx: &ExpContext) -> Result<Report> {
        Self::timed_run(self.get_or_err(name)?.as_ref(), ctx)
    }

    /// Run `e`, stamping [`ELAPSED_SECS_META`] on success.
    fn timed_run(e: &dyn Experiment, ctx: &ExpContext) -> Result<Report> {
        let start = std::time::Instant::now();
        let mut report = e.run(ctx)?;
        report
            .meta
            .insert(ELAPSED_SECS_META.into(), format!("{:.3}", start.elapsed().as_secs_f64()));
        Ok(report)
    }

    /// Run every registered experiment, the parallel-safe ones on worker
    /// threads ([`crate::util::par_map`]) and the rest serially.
    /// Results come back in registration order, failures included (a
    /// missing artifact set fails `table6` without aborting the rest).
    ///
    /// Experiments that fan out internally (Table V, Figs. 12/16, the
    /// sweep) briefly nest scoped `par_map` workers under the outer
    /// ones; the oversubscription is transient and keeps the API free
    /// of a "how parallel am I inside" knob.
    pub fn run_all(&self, ctx: &ExpContext) -> Vec<(String, Result<Report>)> {
        let experiments: Vec<&Arc<dyn Experiment>> = self.iter().collect();
        let results = Self::run_set(&experiments, ctx);
        self.iter()
            .zip(results)
            .map(|(e, res)| (e.name().to_string(), res))
            .collect()
    }

    /// Run a set of experiments — the parallel-safe ones on worker
    /// threads, the rest serially — returning results in input order.
    /// The CLI's multi-name `exp run` shares this with
    /// [`run_all`](ExperimentRegistry::run_all).
    pub fn run_set(
        experiments: &[&Arc<dyn Experiment>],
        ctx: &ExpContext,
    ) -> Vec<Result<Report>> {
        let par_idx: Vec<usize> = (0..experiments.len())
            .filter(|&i| experiments[i].parallel_safe())
            .collect();
        let mut slots: Vec<Option<Result<Report>>> =
            (0..experiments.len()).map(|_| None).collect();
        let par_results = crate::util::par_map(par_idx.len(), |k| {
            Self::timed_run(experiments[par_idx[k]].as_ref(), ctx)
        });
        for (k, res) in par_results.into_iter().enumerate() {
            slots[par_idx[k]] = Some(res);
        }
        for (i, e) in experiments.iter().enumerate() {
            if !e.parallel_safe() {
                slots[i] = Some(Self::timed_run(e.as_ref(), ctx));
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("run_set: unfilled slot"))
            .collect()
    }
}

impl Default for ExperimentRegistry {
    fn default() -> Self {
        ExperimentRegistry::with_defaults()
    }
}

// ---------------------------------------------------------------------------
// sweep — the registry-only scenario grid
// ---------------------------------------------------------------------------

/// The sweep Report's empty shell (name, title, typed columns). Shared
/// with the rendering benches so they measure the real sweep shape —
/// a schema change here changes what they time, by construction.
pub fn sweep_schema() -> Report {
    Report::new(
        "sweep",
        "Sweep — fine-tuning across env × model × strategy (MRPC, P.A.+cache)",
    )
    .column("env", ColType::Str)
    .column("model", ColType::Str)
    .column("strategy", ColType::Str)
    .column("status", ColType::Str)
    .column("epoch1", ColType::Secs)
    .column("total", ColType::Secs)
    .column("hours", ColType::Float)
    .column("throughput", ColType::Float)
    .column("peak_mem", ColType::Bytes)
    .column("stages", ColType::Int)
    .column("grouping", ColType::Str)
}

/// Long-form grid over env × model × strategy, one row per cell — the
/// kind of cross-scenario comparison the per-figure surface could not
/// express (every figure hard-wired one environment). Strategies are
/// resolved by name through the [`StrategyRegistry`], so shadowing a
/// strategy changes the sweep too. Cells evaluate concurrently.
pub fn sweep_report() -> Report {
    let envs = [Env::env_a(), Env::env_b()];
    let models = [ModelSpec::t5_base(), ModelSpec::t5_large()];
    let strategy_names = ["dp", "pp", "pac+"];
    let registry = StrategyRegistry::with_defaults();
    let samples = Task::Mrpc.train_samples();
    let epochs = 3usize;

    let mut combos: Vec<(&Env, &ModelSpec, &str)> = Vec::new();
    for env in &envs {
        for spec in &models {
            for name in strategy_names {
                combos.push((env, spec, name));
            }
        }
    }
    let results = crate::util::par_map(combos.len(), |i| {
        let (env, spec, name) = combos[i];
        let strategy = registry.get(name).expect("sweep strategy registered");
        let profile = super::tables::profile(spec, Method::pa(true), TABLE_SEQ);
        let job = TrainJob::new(samples, epochs, TABLE_SEQ, 16);
        (strategy.name().to_string(), strategy.run(&profile, env, job))
    });

    let mut report = sweep_schema()
        .meta("task", "MRPC")
        .meta("samples", samples)
        .meta("epochs", epochs)
        .meta("seq", TABLE_SEQ)
        .meta("minibatch", 16)
        .meta("method", "pa+cache");

    for ((env, spec, _), (strategy_name, res)) in combos.iter().zip(results) {
        match res {
            Ok(r) => report.push(vec![
                Cell::Str(env.name.clone()),
                Cell::Str(spec.name.clone()),
                Cell::Str(strategy_name),
                Cell::Str("ok".into()),
                Cell::Secs(r.epoch1),
                Cell::Secs(r.total),
                Cell::Float(r.total / 3600.0),
                Cell::Float(samples as f64 / r.epoch1),
                Cell::Bytes(r.plan.peak_mem()),
                Cell::Int(r.plan.n_stages() as i64),
                Cell::Str(r.plan.grouping()),
            ]),
            Err(e) => report.push(vec![
                Cell::Str(env.name.clone()),
                Cell::Str(spec.name.clone()),
                Cell::Str(strategy_name),
                Cell::Str(e.to_string()),
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
                Cell::Missing,
            ]),
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_every_table_and_figure() {
        let r = ExperimentRegistry::with_defaults();
        assert_eq!(
            r.names(),
            vec![
                "fig3",
                "table1",
                "table5",
                "table6",
                "table7",
                "fig12",
                "fig13",
                "fig14",
                "fig15",
                "fig16",
                "fig17",
                "fig18",
                "ablate_schedule",
                "ablate_bandwidth",
                "ablate_microbatches",
                "sweep",
                "fleet",
                "fleet_churn",
                "fleet_checkpoint",
                "fleet_users",
                "fed",
                "fed_select",
                "fleet_learn",
            ]
        );
    }

    #[test]
    fn lookup_by_name_and_alias() {
        let r = ExperimentRegistry::with_defaults();
        for (query, want) in [
            ("table5", "table5"),
            ("TABLE5", "table5"),
            ("hours", "table5"),
            ("fig16", "fig16"),
            ("scalability", "fig16"),
            ("schedule", "ablate_schedule"),
            ("grid", "sweep"),
            ("fleet", "fleet"),
            ("fleet-churn", "fleet_churn"),
            ("churn", "fleet_churn"),
            ("ckpt", "fleet_checkpoint"),
            ("slo", "fleet_users"),
            ("fed", "fed"),
            ("federated", "fed"),
            ("fed-select", "fed_select"),
            ("selection", "fed_select"),
        ] {
            assert_eq!(r.get(query).map(|e| e.name()), Some(want), "query {query:?}");
        }
        assert!(r.get("table9").is_none());
    }

    #[test]
    fn register_replaces_by_name() {
        struct Shadow;
        impl Experiment for Shadow {
            fn name(&self) -> &str {
                "fig3"
            }
            fn run(&self, _ctx: &ExpContext) -> Result<Report> {
                Ok(Report::new("fig3", "shadowed"))
            }
        }
        let mut r = ExperimentRegistry::with_defaults();
        let n = r.len();
        r.register(Arc::new(Shadow));
        assert_eq!(r.len(), n, "replace, not append");
        let rep = r.run("fig3", &ExpContext::new()).unwrap();
        assert_eq!(rep.title, "shadowed");
    }

    #[test]
    fn register_replaces_case_insensitively() {
        struct Shadow;
        impl Experiment for Shadow {
            fn name(&self) -> &str {
                "FIG3"
            }
            fn run(&self, _ctx: &ExpContext) -> Result<Report> {
                Ok(Report::new("FIG3", "shadowed-upper"))
            }
        }
        let mut r = ExperimentRegistry::with_defaults();
        let n = r.len();
        r.register(Arc::new(Shadow));
        assert_eq!(r.len(), n, "case-insensitive replace, not an unreachable twin");
        assert_eq!(r.run("fig3", &ExpContext::new()).unwrap().title, "shadowed-upper");
    }

    #[test]
    fn run_stamps_elapsed_wall_clock_meta() {
        let r = ExperimentRegistry::with_defaults();
        let rep = r.run("fig3", &ExpContext::new()).unwrap();
        let v = rep.meta.get(ELAPSED_SECS_META).expect("elapsed_secs meta stamped by run");
        assert!(v.parse::<f64>().unwrap() >= 0.0, "{v}");
        let results = ExperimentRegistry::run_set(
            &r.iter().take(2).collect::<Vec<_>>(),
            &ExpContext::new(),
        );
        for res in results {
            let rep = res.unwrap();
            assert!(rep.meta.contains_key(ELAPSED_SECS_META), "{}", rep.name);
        }
    }

    #[test]
    fn run_unknown_names_the_alternatives() {
        let r = ExperimentRegistry::with_defaults();
        let err = r.run("fig99", &ExpContext::new()).unwrap_err().to_string();
        assert!(err.contains("unknown experiment"), "{err}");
        assert!(err.contains("table5"), "{err}");
    }

    #[test]
    fn real_training_experiments_are_serial_and_need_artifacts() {
        let r = ExperimentRegistry::with_defaults();
        for name in ["table6", "table7", "fig14"] {
            let e = r.get(name).unwrap();
            assert!(!e.parallel_safe(), "{name}");
            assert!(e.requires_artifacts(), "{name}");
        }
        let table5 = r.get("table5").unwrap();
        assert!(table5.parallel_safe());
        assert!(!table5.requires_artifacts());
    }

    #[test]
    fn sweep_covers_the_grid() {
        let rep = sweep_report();
        // 2 envs x 2 models x 3 strategies, one long-form row per cell
        assert_eq!(rep.n_rows(), 12);
        for (col, want) in [
            ("env", vec!["Env.A", "Env.B"]),
            ("model", vec!["T5-Base", "T5-Large"]),
            ("strategy", vec!["DP (EDDL)", "PP (Eco-FL)", "PAC+"]),
        ] {
            for w in want {
                assert!(
                    (0..rep.n_rows()).any(|i| rep
                        .cell(i, col)
                        .and_then(Cell::as_str)
                        .map(|s| s == w)
                        .unwrap_or(false)),
                    "missing {col}={w}"
                );
            }
        }
        // PAC+ rows always plan (the paper's core claim)
        for i in 0..rep.n_rows() {
            if rep.cell(i, "strategy").and_then(Cell::as_str) == Some("PAC+") {
                assert_eq!(rep.cell(i, "status").and_then(Cell::as_str), Some("ok"));
                assert!(rep.cell(i, "hours").unwrap().as_f64().unwrap() > 0.0);
            }
        }
    }
}
