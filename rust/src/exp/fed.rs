//! The federated experiments: selection × straggler and
//! selection × availability-trace × network grids over the round-based
//! adapter-aggregation simulator.
//!
//! Each cell is one deterministic [`crate::fed::simulate_fed`] run
//! (fixed seed, shared client population per seed), so the reports are
//! bit-identical across runs and machines — diffable with the
//! `BENCH_*.json` workflow like every other report.

use crate::cluster::Network;
use crate::fed::{
    simulate_fed, AggregationMode, FedMetrics, FedOptions, FedTraceKind, SelectionRegistry,
    StragglerRegistry,
};
use crate::util::par_map;

use super::report::{Cell, ColType, Report};

/// Rounds per cell of the experiment grids.
const GRID_ROUNDS: usize = 20;
/// Client population per cell.
const GRID_CLIENTS: usize = 24;
/// Aggregation target K per round.
const GRID_K: usize = 6;
/// Seed shared by every grid cell.
const GRID_SEED: u64 = 42;
/// Convergence-proxy target in effective rounds (under the round cap,
/// so full-participation cells provably reach it).
const GRID_TARGET: f64 = 10.0;

/// The fed Report's empty shell (name, title, typed columns). Shared by
/// the grids, the CLI subcommand and `bench_fed`, so every surface
/// emits the same schema.
pub fn fed_schema(name: &str, title: &str) -> Report {
    Report::new(name, title)
        .column("net", ColType::Str)
        .column("trace", ColType::Str)
        .column("select", ColType::Str)
        .column("straggler", ColType::Str)
        .column("agg", ColType::Str)
        .column("mode", ColType::Str) // sync cohorts or async buffered folding
        .column("clients", ColType::Int)
        .column("k", ColType::Int)
        .column("rounds", ColType::Int)
        .column("selected", ColType::Int) // client-rounds selected
        .column("aggregated", ColType::Int) // client-rounds aggregated
        .column("dropped", ColType::Int) // stragglers dropped
        .column("p50", ColType::Secs) // round-time percentiles
        .column("p95", ColType::Secs)
        .column("p99", ColType::Secs)
        .column("bytes_up", ColType::Bytes)
        .column("bytes_down", ColType::Bytes)
        .column("fairness", ColType::Float) // Jain over participation counts
        .column("eff_rounds", ColType::Float) // participation-weighted progress
        .column("rph", ColType::Float) // effective rounds per virtual hour
        .column("stale_p50", ColType::Float) // per-delta staleness (async only)
        .column("stale_p95", ColType::Float)
        .column("to_target", ColType::Int) // rounds to the convergence proxy
        .column("t_target", ColType::Secs)
        .column("makespan", ColType::Secs)
}

/// One metrics row in the shared schema. `trace` is the availability
/// label — usually `opts.trace.name()`, but `pacpp fed --churn-file`
/// passes `"churn-file"` (the traces came from a recorded fleet churn
/// trace, not a generated [`FedTraceKind`] pattern).
pub fn fed_row(net: &str, trace: &str, opts: &FedOptions, m: &FedMetrics) -> Vec<Cell> {
    vec![
        Cell::Str(net.into()),
        Cell::Str(trace.into()),
        Cell::Str(opts.select.clone()),
        Cell::Str(opts.straggler.clone()),
        Cell::Str(opts.agg.name().into()),
        Cell::Str(opts.agg_mode.name().into()),
        Cell::Int(opts.clients as i64),
        Cell::Int(opts.k as i64),
        Cell::Int(m.rounds as i64),
        Cell::Int(m.selected_total as i64),
        Cell::Int(m.aggregated_total as i64),
        Cell::Int(m.dropped_total as i64),
        Cell::opt(m.round_p50, Cell::Secs),
        Cell::opt(m.round_p95, Cell::Secs),
        Cell::opt(m.round_p99, Cell::Secs),
        Cell::Bytes(m.bytes_up),
        Cell::Bytes(m.bytes_down),
        Cell::Float(m.participation_fairness),
        Cell::Float(m.effective_rounds),
        Cell::Float(m.rounds_per_hour),
        Cell::opt(m.staleness_p50, Cell::Float),
        Cell::opt(m.staleness_p95, Cell::Float),
        Cell::opt(m.rounds_to_target, |r| Cell::Int(r as i64)),
        Cell::opt(m.time_to_target, Cell::Secs),
        Cell::Secs(m.makespan),
    ]
}

fn base_opts() -> FedOptions {
    FedOptions {
        rounds: GRID_ROUNDS,
        clients: GRID_CLIENTS,
        k: GRID_K,
        seed: GRID_SEED,
        target_rounds: GRID_TARGET,
        ..Default::default()
    }
}

fn net_by_name(name: &str) -> Network {
    match name {
        "wifi" => Network::wifi_100mbps(),
        _ => Network::lan_1gbps(),
    }
}

/// `fed` — the mitigation grid: every selection policy × every
/// straggler policy on the shared churny population (LAN, ring
/// AllReduce), plus one async buffered-aggregation row per selection
/// policy (no straggler barrier to vary). The dropped/round-time
/// columns show what each straggler discipline buys; the fairness
/// column what each selector costs; the rph/staleness columns what
/// dropping the barrier buys and pays.
pub fn fed_report() -> Report {
    let selections = SelectionRegistry::with_defaults();
    let stragglers = StragglerRegistry::with_defaults();
    let base = base_opts();
    let mut combos: Vec<FedOptions> = Vec::new();
    for select in selections.names() {
        for straggler in stragglers.names() {
            combos.push(FedOptions {
                select: select.to_string(),
                straggler: straggler.to_string(),
                ..base.clone()
            });
        }
    }
    for select in selections.names() {
        combos.push(FedOptions {
            select: select.to_string(),
            // bypassed in async mode, but the column must still hold a
            // canonical registry name
            straggler: "Wait-all".into(),
            agg_mode: AggregationMode::Async,
            ..base.clone()
        });
    }
    let results = par_map(combos.len(), |i| {
        let opts = combos[i].clone();
        let m = simulate_fed(&opts).expect("default fed policies are registered");
        (opts, m)
    });

    let mut report = fed_schema(
        "fed",
        "Fed — federated adapter aggregation, selection x straggler (churny clients)",
    )
    .meta("rounds", GRID_ROUNDS)
    .meta("clients", GRID_CLIENTS)
    .meta("k", GRID_K)
    .meta("seed", GRID_SEED)
    .meta("trace", base.trace.name())
    .meta("agg", base.agg.name())
    .meta("strategy", &base.strategy)
    .meta("target", GRID_TARGET);
    for (opts, m) in &results {
        report.push(fed_row("lan", opts.trace.name(), opts, m));
    }
    observe_meta(report, &results)
}

/// Attach the grid-summed observe counters (strategy-oracle memo
/// hits/misses over the per-client quoting passes) to a fed report's
/// metadata.
fn observe_meta(report: Report, results: &[(FedOptions, FedMetrics)]) -> Report {
    report
        .meta(
            "oracle_hits_total",
            results.iter().map(|(_, m)| m.oracle_hits).sum::<usize>(),
        )
        .meta(
            "oracle_misses_total",
            results.iter().map(|(_, m)| m.oracle_misses).sum::<usize>(),
        )
}

/// `fed_select` — the availability grid: every selection policy ×
/// availability trace × network, under synchronous (wait-all) rounds
/// where a dropout hurts most. Availability-aware selection's edge over
/// uniform on the flaky/churny traces is the story.
pub fn fed_select_report() -> Report {
    let selections = SelectionRegistry::with_defaults();
    let nets = ["lan", "wifi"];
    let mut combos: Vec<(String, FedTraceKind, &str)> = Vec::new();
    for select in selections.names() {
        for trace in FedTraceKind::ALL {
            for net in nets {
                combos.push((select.to_string(), trace, net));
            }
        }
    }
    let base = base_opts();
    let results = par_map(combos.len(), |i| {
        let (select, trace, net) = &combos[i];
        let opts = FedOptions {
            select: select.clone(),
            // canonical name: the straggler column must match the fed
            // grid's rows, which come from StragglerRegistry::names()
            straggler: "Wait-all".into(),
            trace: *trace,
            network: net_by_name(net),
            ..base.clone()
        };
        (opts.clone(), simulate_fed(&opts).expect("default fed policies are registered"))
    });

    let mut report = fed_schema(
        "fed_select",
        "Fed — client selection x availability trace x network (wait-all rounds)",
    )
    .meta("rounds", GRID_ROUNDS)
    .meta("clients", GRID_CLIENTS)
    .meta("k", GRID_K)
    .meta("seed", GRID_SEED)
    .meta("straggler", "Wait-all")
    .meta("agg", base.agg.name())
    .meta("strategy", &base.strategy)
    .meta("target", GRID_TARGET);
    for ((_, _, net), (opts, m)) in combos.iter().zip(&results) {
        report.push(fed_row(net, opts.trace.name(), opts, m));
    }
    observe_meta(report, &results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn str_values(rep: &Report, col: &str) -> Vec<String> {
        (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_str).map(String::from))
            .collect()
    }

    #[test]
    fn fed_grid_covers_selection_by_straggler() {
        let rep = fed_report();
        // 5 selection x 3 straggler policies, plus 5 async rows
        assert_eq!(rep.n_rows(), 20);
        for (col, want) in [
            (
                "select",
                vec!["Uniform", "Power-of-d", "Availability-aware", "Fair-share", "Utility"],
            ),
            ("straggler", vec!["Wait-all", "Deadline", "Over-select"]),
            ("mode", vec!["sync", "async"]),
        ] {
            let values = str_values(&rep, col);
            for w in want {
                assert!(values.iter().any(|v| v == w), "missing {col}={w}");
            }
        }
        for col in
            ["agg", "mode", "rounds", "aggregated", "dropped", "p50", "p95", "bytes_up",
             "fairness", "eff_rounds", "rph", "stale_p50", "to_target", "makespan"]
        {
            assert!(rep.columns().iter().any(|c| c.name == col), "missing column {col}");
        }
        // staleness is an async-only concept: absent from every sync
        // row, present in every async row
        for i in 0..rep.n_rows() {
            let mode = rep.cell(i, "mode").unwrap().as_str().unwrap().to_string();
            let stale = rep.cell(i, "stale_p50").and_then(|c| c.as_f64());
            match mode.as_str() {
                "async" => assert!(stale.is_some(), "row {i}: async rows report staleness"),
                _ => assert!(stale.is_none(), "row {i}: sync rows have no staleness"),
            }
        }
        for i in 0..rep.n_rows() {
            let rounds = rep.cell(i, "rounds").unwrap().as_f64().unwrap();
            assert!(rounds > 0.0, "row {i} completed no rounds");
            assert!(rounds <= GRID_ROUNDS as f64, "row {i}");
            let agg = rep.cell(i, "aggregated").unwrap().as_f64().unwrap();
            let sel = rep.cell(i, "selected").unwrap().as_f64().unwrap();
            let dropped = rep.cell(i, "dropped").unwrap().as_f64().unwrap();
            assert!(agg <= sel, "row {i}");
            assert_eq!(agg + dropped, sel, "row {i}: selection partitions");
            let fairness = rep.cell(i, "fairness").unwrap().as_f64().unwrap();
            assert!(fairness > 0.0 && fairness <= 1.0 + 1e-9, "row {i}: {fairness}");
            assert!(rep.cell(i, "bytes_up").unwrap().as_f64().unwrap() > 0.0, "row {i}");
        }
        // observe counters ride along in the metadata: 20 cells × 24
        // quoted clients each
        for key in ["oracle_hits_total", "oracle_misses_total"] {
            assert!(rep.meta.contains_key(key), "missing meta {key}");
        }
        let hits: usize = rep.meta["oracle_hits_total"].parse().unwrap();
        let misses: usize = rep.meta["oracle_misses_total"].parse().unwrap();
        assert_eq!(hits + misses, 20 * GRID_CLIENTS, "one quote per client per cell");
    }

    #[test]
    fn fed_select_grid_covers_traces_and_networks() {
        let rep = fed_select_report();
        // 5 selection x 3 traces x 2 networks
        assert_eq!(rep.n_rows(), 30);
        for (col, want) in [
            ("net", vec!["lan", "wifi"]),
            ("trace", vec!["stable", "churny", "flaky"]),
        ] {
            let values = str_values(&rep, col);
            for w in want {
                assert!(values.iter().any(|v| v == w), "missing {col}={w}");
            }
        }
        // every row is a wait-all row by construction
        for v in str_values(&rep, "straggler") {
            assert_eq!(v, "Wait-all");
        }
    }

    #[test]
    fn reports_are_deterministic() {
        let a = fed_report();
        let b = fed_report();
        assert_eq!(a, b);
        assert_eq!(
            a.render(crate::exp::Format::Json),
            b.render(crate::exp::Format::Json)
        );
        assert_eq!(fed_select_report(), fed_select_report());
    }
}
