//! The fleet experiments: multi-tenant scheduling grids over
//! policy × arrival-trace × environment, with and without device churn.
//!
//! Each cell is one deterministic [`crate::fleet::simulate_fleet`] run
//! (fixed seed, shared job count and horizon), so the reports are
//! bit-identical across runs and machines — diffable with the
//! `BENCH_*.json` workflow like every other report.

use std::sync::Arc;

use crate::cluster::Env;
use crate::fleet::{
    generate_churn, generate_jobs, simulate_fleet, ChurnEvent, FleetMetrics, FleetOptions,
    PlacementPolicy, PolicyRegistry, TraceKind,
};
use crate::util::par_map;

use super::report::{Cell, ColType, Report};

/// Jobs per cell of the experiment grids.
const GRID_JOBS: usize = 40;
/// Seed shared by every grid cell (traces differ by kind, not seed).
const GRID_SEED: u64 = 42;
/// Churn intensity of the `fleet_churn` grid, events/hour.
const GRID_CHURN_PER_HOUR: f64 = 2.0;

/// The fleet Report's empty shell (name, title, typed columns). Shared
/// by both grids, the CLI subcommand and `bench_fleet`, so every
/// surface emits the same schema.
pub fn fleet_schema(name: &str, title: &str) -> Report {
    Report::new(name, title)
        .column("env", ColType::Str)
        .column("trace", ColType::Str)
        .column("policy", ColType::Str)
        .column("jobs", ColType::Int)
        .column("completed", ColType::Int)
        .column("failed", ColType::Int)
        .column("throughput", ColType::Float) // jobs/hour
        .column("p50", ColType::Secs)
        .column("p95", ColType::Secs)
        .column("p99", ColType::Secs)
        .column("utilization", ColType::Float)
        .column("replans", ColType::Int)
        .column("restarts", ColType::Int)
        .column("work_lost", ColType::Secs)
        .column("migration", ColType::Secs)
}

/// One metrics row in the shared schema.
pub fn fleet_row(env: &str, trace: &str, policy: &str, jobs: usize, m: &FleetMetrics) -> Vec<Cell> {
    vec![
        Cell::Str(env.into()),
        Cell::Str(trace.into()),
        Cell::Str(policy.into()),
        Cell::Int(jobs as i64),
        Cell::Int(m.completed as i64),
        Cell::Int(m.failed as i64),
        Cell::Float(m.jobs_per_hour),
        Cell::opt(m.latency_p50, Cell::Secs),
        Cell::opt(m.latency_p95, Cell::Secs),
        Cell::opt(m.latency_p99, Cell::Secs),
        Cell::Float(m.utilization),
        Cell::Int(m.replans as i64),
        Cell::Int(m.restarts as i64),
        Cell::Secs(m.work_lost),
        Cell::Secs(m.migration_overhead),
    ]
}

fn grid_report(name: &str, title: &str, churn_per_hour: Option<f64>) -> Report {
    let envs = [Env::env_a(), Env::env_b()];
    let registry = PolicyRegistry::with_defaults();
    let opts = FleetOptions::default();

    // Every registered policy gets a row per env x trace, even when two
    // policies happen to place identically on a given trace (on a
    // stable pool Best-fit and Preempt-replan differ only in the
    // never-invoked churn response): the grid reports what each named
    // policy does, and guessing behavioral equality across arbitrary
    // registered policies is not this layer's business.
    let mut combos: Vec<(&Env, TraceKind, Arc<dyn PlacementPolicy>)> = Vec::new();
    for env in &envs {
        for trace in TraceKind::ALL {
            for policy in registry.iter() {
                combos.push((env, trace, policy.clone()));
            }
        }
    }
    let results = par_map(combos.len(), |i| {
        let (env, trace, policy) = &combos[i];
        let jobs = generate_jobs(*trace, GRID_JOBS, GRID_SEED);
        let churn: Vec<ChurnEvent> = match churn_per_hour {
            Some(rate) => generate_churn(env, opts.horizon, rate, GRID_SEED),
            None => Vec::new(),
        };
        simulate_fleet(env, &jobs, &churn, policy.as_ref(), &opts)
            .expect("default strategy is registered")
    });

    let mut report = fleet_schema(name, title)
        .meta("jobs", GRID_JOBS)
        .meta("seed", GRID_SEED)
        .meta("horizon_h", opts.horizon / 3600.0)
        .meta("strategy", &opts.strategy)
        .meta(
            "churn_per_hour",
            churn_per_hour.map(|r| r.to_string()).unwrap_or_else(|| "0".into()),
        );
    for ((env, trace, policy), m) in combos.iter().zip(&results) {
        report.push(fleet_row(&env.name, trace.name(), policy.name(), GRID_JOBS, m));
    }
    report
}

/// `fleet` — the stable-pool grid: policy × trace × env, no churn.
pub fn fleet_report() -> Report {
    grid_report(
        "fleet",
        "Fleet — multi-tenant scheduling, policy × trace × env (stable pool)",
        None,
    )
}

/// `fleet_churn` — the same grid under device churn (joins, leaves,
/// degrades at ~2 events/hour): the replan/restart/work-lost columns
/// become the story.
pub fn fleet_churn_report() -> Report {
    grid_report(
        "fleet_churn",
        "Fleet — multi-tenant scheduling under device churn, policy × trace × env",
        Some(GRID_CHURN_PER_HOUR),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn str_values(rep: &Report, col: &str) -> Vec<String> {
        (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_str).map(String::from))
            .collect()
    }

    #[test]
    fn fleet_grid_covers_policies_traces_envs() {
        let rep = fleet_report();
        // 2 envs x 3 traces x 3 policies
        assert_eq!(rep.n_rows(), 18);
        for (col, want) in [
            ("env", vec!["Env.A", "Env.B"]),
            ("trace", vec!["steady", "diurnal", "bursty"]),
            ("policy", vec!["FIFO-exclusive", "Best-fit", "Preempt-replan"]),
        ] {
            let values = str_values(&rep, col);
            for w in want {
                assert!(values.iter().any(|v| v == w), "missing {col}={w}");
            }
        }
        for col in ["throughput", "p50", "p95", "p99", "utilization"] {
            assert!(
                rep.columns().iter().any(|c| c.name == col),
                "missing column {col}"
            );
        }
        // a stable pool never replans or restarts
        for i in 0..rep.n_rows() {
            assert_eq!(rep.cell(i, "replans"), Some(&Cell::Int(0)), "row {i}");
            assert_eq!(rep.cell(i, "restarts"), Some(&Cell::Int(0)), "row {i}");
        }
    }

    #[test]
    fn churn_grid_shows_churn_effects() {
        let rep = fleet_churn_report();
        assert_eq!(rep.n_rows(), 18);
        // somewhere in the grid churn must have forced replans (preempt
        // rows) and restarts (fifo/best-fit rows)
        let col_sum = |col: &str| -> f64 {
            (0..rep.n_rows())
                .filter_map(|i| rep.cell(i, col).and_then(Cell::as_f64))
                .sum()
        };
        assert!(col_sum("replans") > 0.0, "no replans anywhere under churn");
        assert!(col_sum("restarts") > 0.0, "no restarts anywhere under churn");
        assert!(col_sum("work_lost") > 0.0, "no work lost anywhere under churn");
        // every replan pays its cache-migration cost
        assert!(col_sum("migration") > 0.0, "replans must report migration seconds");
    }

    #[test]
    fn reports_are_deterministic() {
        let a = fleet_report();
        let b = fleet_report();
        assert_eq!(a, b);
        assert_eq!(
            a.render(crate::exp::Format::Json),
            b.render(crate::exp::Format::Json)
        );
    }
}
