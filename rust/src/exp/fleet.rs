//! The fleet experiments: multi-tenant scheduling grids over
//! policy × arrival-trace × environment (with and without device
//! churn), the checkpoint-interval tradeoff, and the per-user SLO
//! breakdown.
//!
//! Each cell is one deterministic [`crate::fleet::simulate_fleet`] run
//! (fixed seed, shared job count and horizon), so the reports are
//! bit-identical across runs and machines — diffable with the
//! `BENCH_*.json` workflow like every other report.

use std::sync::Arc;

use crate::cluster::Env;
use crate::fleet::{
    generate_churn, generate_jobs, simulate_fleet, BestFit, CheckpointSpec, ChurnEvent,
    FleetMetrics, FleetOptions, PlacementPolicy, PolicyRegistry, PreemptReplan,
    QueuePolicyRegistry, TraceKind, DEFAULT_CKPT_COST,
};
use crate::util::par_map;

use super::report::{Cell, ColType, Report};

/// Jobs per cell of the experiment grids.
const GRID_JOBS: usize = 40;
/// Seed shared by every grid cell (traces differ by kind, not seed).
const GRID_SEED: u64 = 42;
/// Churn intensity of the `fleet_churn` grid, events/hour.
const GRID_CHURN_PER_HOUR: f64 = 2.0;
/// Churn intensity of the `fleet_checkpoint` grid — denser, so the
/// k-vs-overhead tradeoff has restarts to bound.
const CKPT_CHURN_PER_HOUR: f64 = 4.0;

/// Canonical display name of the queue discipline in `opts` — one
/// resolution shared by meta and row cells, so the two never disagree
/// on casing (rows once said "FIFO" while meta said "fifo").
fn queue_display(opts: &FleetOptions) -> String {
    QueuePolicyRegistry::with_defaults()
        .get(&opts.queue)
        .map(|q| q.name().to_string())
        .unwrap_or_else(|| opts.queue.clone())
}

/// The fleet Report's empty shell (name, title, typed columns). Shared
/// by the grids, the CLI subcommand and `bench_fleet`, so every
/// surface emits the same schema.
pub fn fleet_schema(name: &str, title: &str) -> Report {
    Report::new(name, title)
        .column("env", ColType::Str)
        .column("trace", ColType::Str)
        .column("policy", ColType::Str)
        .column("queue", ColType::Str)
        .column("ckpt", ColType::Int) // checkpoint interval k, 0 = off
        .column("jobs", ColType::Int)
        .column("completed", ColType::Int)
        .column("failed", ColType::Int)
        .column("met", ColType::Int) // jobs finished within deadline
        .column("throughput", ColType::Float) // jobs/hour
        .column("goodput", ColType::Float) // deadline-met jobs/hour
        .column("miss_rate", ColType::Float)
        .column("p50", ColType::Secs)
        .column("p95", ColType::Secs)
        .column("p99", ColType::Secs)
        .column("utilization", ColType::Float)
        .column("fairness", ColType::Float) // Jain index over user service
        .column("replans", ColType::Int)
        .column("restarts", ColType::Int)
        .column("work_lost", ColType::Secs)
        .column("migration", ColType::Secs)
        .column("ckpt_overhead", ColType::Secs)
}

/// One metrics row in the shared schema.
pub fn fleet_row(
    env: &str,
    trace: &str,
    policy: &str,
    queue: &str,
    ckpt_k: usize,
    jobs: usize,
    m: &FleetMetrics,
) -> Vec<Cell> {
    vec![
        Cell::Str(env.into()),
        Cell::Str(trace.into()),
        Cell::Str(policy.into()),
        Cell::Str(queue.into()),
        Cell::Int(ckpt_k as i64),
        Cell::Int(jobs as i64),
        Cell::Int(m.completed as i64),
        Cell::Int(m.failed as i64),
        Cell::Int(m.deadline_met as i64),
        Cell::Float(m.jobs_per_hour),
        Cell::Float(m.goodput_per_hour),
        Cell::Float(m.deadline_miss_rate),
        Cell::opt(m.latency_p50, Cell::Secs),
        Cell::opt(m.latency_p95, Cell::Secs),
        Cell::opt(m.latency_p99, Cell::Secs),
        Cell::Float(m.utilization),
        Cell::Float(m.fairness),
        Cell::Int(m.replans as i64),
        Cell::Int(m.restarts as i64),
        Cell::Secs(m.work_lost),
        Cell::Secs(m.migration_overhead),
        Cell::Secs(m.ckpt_overhead),
    ]
}

fn grid_report(name: &str, title: &str, churn_per_hour: Option<f64>) -> Report {
    let envs = [Env::env_a(), Env::env_b()];
    let registry = PolicyRegistry::with_defaults();
    let opts = FleetOptions::default();

    // Every registered policy gets a row per env x trace, even when two
    // policies happen to place identically on a given trace (on a
    // stable pool Best-fit and Preempt-replan differ only in the
    // never-invoked churn response): the grid reports what each named
    // policy does, and guessing behavioral equality across arbitrary
    // registered policies is not this layer's business.
    let mut combos: Vec<(&Env, TraceKind, Arc<dyn PlacementPolicy>)> = Vec::new();
    for env in &envs {
        for trace in TraceKind::ALL {
            for policy in registry.iter() {
                combos.push((env, trace, policy.clone()));
            }
        }
    }
    let results = par_map(combos.len(), |i| {
        let (env, trace, policy) = &combos[i];
        let jobs = generate_jobs(*trace, GRID_JOBS, GRID_SEED);
        let churn: Vec<ChurnEvent> = match churn_per_hour {
            Some(rate) => generate_churn(env, opts.horizon, rate, GRID_SEED),
            None => Vec::new(),
        };
        simulate_fleet(env, &jobs, &churn, policy.as_ref(), &opts)
            .expect("default strategy is registered")
    });

    let queue = queue_display(&opts);
    let mut report = fleet_schema(name, title)
        .meta("jobs", GRID_JOBS)
        .meta("seed", GRID_SEED)
        .meta("horizon_h", opts.horizon / 3600.0)
        .meta("strategy", &opts.strategy)
        .meta("queue", &queue)
        .meta("deadline_scale", opts.deadline_scale)
        .meta(
            "churn_per_hour",
            churn_per_hour.map(|r| r.to_string()).unwrap_or_else(|| "0".into()),
        );
    for ((env, trace, policy), m) in combos.iter().zip(&results) {
        report.push(fleet_row(
            &env.name,
            trace.name(),
            policy.name(),
            &queue,
            0,
            GRID_JOBS,
            m,
        ));
    }
    observe_meta(report, &results)
}

/// Attach the grid-summed observe counters (events processed, oracle
/// memo hits/misses, rescans the incremental index avoided) to a fleet
/// report's metadata.
fn observe_meta(report: Report, results: &[FleetMetrics]) -> Report {
    report
        .meta("events_total", results.iter().map(|m| m.events).sum::<usize>())
        .meta("oracle_hits_total", results.iter().map(|m| m.oracle_hits).sum::<usize>())
        .meta(
            "oracle_misses_total",
            results.iter().map(|m| m.oracle_misses).sum::<usize>(),
        )
        .meta(
            "rescans_avoided_total",
            results.iter().map(|m| m.rescans_avoided).sum::<usize>(),
        )
}

/// `fleet` — the stable-pool grid: policy × trace × env, no churn.
pub fn fleet_report() -> Report {
    grid_report(
        "fleet",
        "Fleet — multi-tenant scheduling, policy × trace × env (stable pool)",
        None,
    )
}

/// `fleet_churn` — the same grid under device churn (joins, leaves,
/// degrades at ~2 events/hour): the replan/restart/work-lost columns
/// become the story.
pub fn fleet_churn_report() -> Report {
    grid_report(
        "fleet_churn",
        "Fleet — multi-tenant scheduling under device churn, policy × trace × env",
        Some(GRID_CHURN_PER_HOUR),
    )
}

/// `fleet_checkpoint` — the checkpoint-interval tradeoff: k ∈
/// {off, 1, 2, 4} × {restart, replan} policies under dense churn on a
/// bursty trace. Small k bounds restart losses tightly but pays more
/// checkpoint overhead; the `work_lost` vs `ckpt_overhead` columns are
/// the tradeoff.
pub fn fleet_checkpoint_report() -> Report {
    let env = Env::env_a();
    let trace = TraceKind::Bursty;
    let ks = [0usize, 1, 2, 4];
    let policies: [Arc<dyn PlacementPolicy>; 2] =
        [Arc::new(BestFit), Arc::new(PreemptReplan)];

    let mut combos: Vec<(usize, Arc<dyn PlacementPolicy>)> = Vec::new();
    for &k in &ks {
        for policy in &policies {
            combos.push((k, policy.clone()));
        }
    }
    let base = FleetOptions::default();
    let results = par_map(combos.len(), |i| {
        let (k, policy) = &combos[i];
        let opts = FleetOptions {
            ckpt: if *k > 0 { Some(CheckpointSpec::new(*k, DEFAULT_CKPT_COST)) } else { None },
            ..base.clone()
        };
        let jobs = generate_jobs(trace, GRID_JOBS, GRID_SEED);
        let churn = generate_churn(&env, opts.horizon, CKPT_CHURN_PER_HOUR, GRID_SEED);
        simulate_fleet(&env, &jobs, &churn, policy.as_ref(), &opts)
            .expect("default strategy is registered")
    });

    let queue = queue_display(&base);
    let mut report = fleet_schema(
        "fleet_checkpoint",
        "Fleet — checkpoint interval k vs restart loss under churn (bursty, Env.A)",
    )
    .meta("jobs", GRID_JOBS)
    .meta("seed", GRID_SEED)
    .meta("horizon_h", base.horizon / 3600.0)
    .meta("strategy", &base.strategy)
    .meta("queue", &queue)
    .meta("churn_per_hour", CKPT_CHURN_PER_HOUR)
    .meta("ckpt_cost", DEFAULT_CKPT_COST);
    for ((k, policy), m) in combos.iter().zip(&results) {
        report.push(fleet_row(
            &env.name,
            trace.name(),
            policy.name(),
            &queue,
            *k,
            GRID_JOBS,
            m,
        ));
    }
    observe_meta(report, &results)
}

/// The per-user Report's empty shell: one row per (policy, user).
pub fn fleet_users_schema() -> Report {
    Report::new(
        "fleet_users",
        "Fleet — per-user SLO breakdown: latency p95, deadline hits, service share",
    )
    .column("env", ColType::Str)
    .column("trace", ColType::Str)
    .column("policy", ColType::Str)
    .column("user", ColType::Int)
    .column("jobs", ColType::Int)
    .column("completed", ColType::Int)
    .column("met", ColType::Int)
    .column("p95", ColType::Secs)
    .column("service", ColType::Secs) // device-seconds consumed
    .column("share", ColType::Float) // fraction of all service handed out
    .column("fairness", ColType::Float) // the run's Jain index (same per policy)
}

/// `fleet_users` — the per-user dimension of the fleet: each policy's
/// run on the shared bursty trace, broken down by submitting user, so
/// JSON/CSV consumers get user ids, per-user p95 and service shares
/// alongside the run-level Jain fairness index.
pub fn fleet_users_report() -> Report {
    let env = Env::env_a();
    let trace = TraceKind::Bursty;
    let registry = PolicyRegistry::with_defaults();
    let opts = FleetOptions::default();
    let policies: Vec<Arc<dyn PlacementPolicy>> = registry.iter().cloned().collect();
    let results = par_map(policies.len(), |i| {
        let jobs = generate_jobs(trace, GRID_JOBS, GRID_SEED);
        simulate_fleet(&env, &jobs, &[], policies[i].as_ref(), &opts)
            .expect("default strategy is registered")
    });

    let mut report = fleet_users_schema()
        .meta("jobs", GRID_JOBS)
        .meta("seed", GRID_SEED)
        .meta("horizon_h", opts.horizon / 3600.0)
        .meta("strategy", &opts.strategy)
        .meta("queue", queue_display(&opts))
        .meta("deadline_scale", opts.deadline_scale);
    for (policy, m) in policies.iter().zip(&results) {
        let total: f64 = m.per_user.iter().map(|u| u.service).sum();
        for u in &m.per_user {
            report.push(vec![
                Cell::Str(env.name.clone()),
                Cell::Str(trace.name().into()),
                Cell::Str(policy.name().into()),
                Cell::Int(u.user as i64),
                Cell::Int(u.jobs as i64),
                Cell::Int(u.completed as i64),
                Cell::Int(u.met as i64),
                Cell::opt(u.p95, Cell::Secs),
                Cell::Secs(u.service),
                Cell::Float(if total > 0.0 { u.service / total } else { 0.0 }),
                Cell::Float(m.fairness),
            ]);
        }
    }
    observe_meta(report, &results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn str_values(rep: &Report, col: &str) -> Vec<String> {
        (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, col).and_then(Cell::as_str).map(String::from))
            .collect()
    }

    #[test]
    fn fleet_grid_covers_policies_traces_envs() {
        let rep = fleet_report();
        // 2 envs x 3 traces x 3 policies
        assert_eq!(rep.n_rows(), 18);
        for (col, want) in [
            ("env", vec!["Env.A", "Env.B"]),
            ("trace", vec!["steady", "diurnal", "bursty"]),
            ("policy", vec!["FIFO-exclusive", "Best-fit", "Preempt-replan"]),
        ] {
            let values = str_values(&rep, col);
            for w in want {
                assert!(values.iter().any(|v| v == w), "missing {col}={w}");
            }
        }
        for col in
            ["queue", "ckpt", "met", "throughput", "goodput", "miss_rate", "p50", "p95",
             "p99", "utilization", "fairness", "ckpt_overhead"]
        {
            assert!(
                rep.columns().iter().any(|c| c.name == col),
                "missing column {col}"
            );
        }
        // a stable pool never replans, restarts or checkpoints-to-any-use
        for i in 0..rep.n_rows() {
            assert_eq!(rep.cell(i, "replans"), Some(&Cell::Int(0)), "row {i}");
            assert_eq!(rep.cell(i, "restarts"), Some(&Cell::Int(0)), "row {i}");
            assert_eq!(rep.cell(i, "queue"), Some(&Cell::Str("FIFO".into())), "row {i}");
            assert_eq!(rep.cell(i, "ckpt"), Some(&Cell::Int(0)), "row {i}");
            let fairness = rep.cell(i, "fairness").unwrap().as_f64().unwrap();
            assert!(fairness > 0.0 && fairness <= 1.0 + 1e-9, "row {i}: {fairness}");
            let met = rep.cell(i, "met").unwrap().as_f64().unwrap();
            let completed = rep.cell(i, "completed").unwrap().as_f64().unwrap();
            assert!(met <= completed, "row {i}");
        }
        // observe counters ride along in the metadata
        for key in
            ["events_total", "oracle_hits_total", "oracle_misses_total", "rescans_avoided_total"]
        {
            assert!(rep.meta.contains_key(key), "missing meta {key}");
        }
        assert!(rep.meta["events_total"].parse::<usize>().unwrap() > 0);
        assert!(rep.meta["oracle_hits_total"].parse::<usize>().unwrap() > 0);
    }

    #[test]
    fn churn_grid_shows_churn_effects() {
        let rep = fleet_churn_report();
        assert_eq!(rep.n_rows(), 18);
        // somewhere in the grid churn must have forced replans (preempt
        // rows) and restarts (fifo/best-fit rows)
        let col_sum = |col: &str| -> f64 {
            (0..rep.n_rows())
                .filter_map(|i| rep.cell(i, col).and_then(Cell::as_f64))
                .sum()
        };
        assert!(col_sum("replans") > 0.0, "no replans anywhere under churn");
        assert!(col_sum("restarts") > 0.0, "no restarts anywhere under churn");
        assert!(col_sum("work_lost") > 0.0, "no work lost anywhere under churn");
        // every replan pays its cache-migration cost
        assert!(col_sum("migration") > 0.0, "replans must report migration seconds");
    }

    #[test]
    fn checkpoint_grid_shows_the_tradeoff() {
        let rep = fleet_checkpoint_report();
        // 4 intervals x 2 policies
        assert_eq!(rep.n_rows(), 8);
        let k_values: Vec<f64> = (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, "ckpt").and_then(Cell::as_f64))
            .collect();
        for k in [0.0, 1.0, 2.0, 4.0] {
            assert!(k_values.contains(&k), "missing ckpt k={k}");
        }
        for i in 0..rep.n_rows() {
            let k = rep.cell(i, "ckpt").unwrap().as_f64().unwrap();
            let overhead = rep.cell(i, "ckpt_overhead").unwrap().as_f64().unwrap();
            if k == 0.0 {
                assert_eq!(overhead, 0.0, "row {i}: no checkpointing, no overhead");
            }
        }
        // checkpointing actually happened somewhere in the k>0 rows
        let total_overhead: f64 = (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, "ckpt_overhead").and_then(Cell::as_f64))
            .sum();
        assert!(total_overhead > 0.0, "k>0 rows must pay checkpoint overhead");
    }

    #[test]
    fn users_report_partitions_jobs_by_user() {
        let rep = fleet_users_report();
        let policies: Vec<String> = {
            let mut v = str_values(&rep, "policy");
            v.sort();
            v.dedup();
            v
        };
        assert_eq!(policies.len(), 3, "one block per registered policy");
        // distinct users, and each policy's user rows partition the jobs
        let mut users: Vec<f64> = (0..rep.n_rows())
            .filter_map(|i| rep.cell(i, "user").and_then(Cell::as_f64))
            .collect();
        users.sort_by(|a, b| a.total_cmp(b));
        users.dedup();
        assert!(users.len() >= 2, "the generated trace spans multiple users");
        for p in &policies {
            let jobs_sum: f64 = (0..rep.n_rows())
                .filter(|&i| rep.cell(i, "policy").and_then(Cell::as_str) == Some(p.as_str()))
                .filter_map(|i| rep.cell(i, "jobs").and_then(Cell::as_f64))
                .sum();
            assert_eq!(jobs_sum, GRID_JOBS as f64, "policy {p}");
            let share_sum: f64 = (0..rep.n_rows())
                .filter(|&i| rep.cell(i, "policy").and_then(Cell::as_str) == Some(p.as_str()))
                .filter_map(|i| rep.cell(i, "share").and_then(Cell::as_f64))
                .sum();
            assert!((share_sum - 1.0).abs() < 1e-9, "policy {p}: shares sum to {share_sum}");
        }
    }

    #[test]
    fn reports_are_deterministic() {
        let a = fleet_report();
        let b = fleet_report();
        assert_eq!(a, b);
        assert_eq!(
            a.render(crate::exp::Format::Json),
            b.render(crate::exp::Format::Json)
        );
        assert_eq!(fleet_users_report(), fleet_users_report());
    }
}
