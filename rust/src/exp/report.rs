//! The [`Report`] type — the common, typed output of every experiment.
//!
//! A report is a named table: typed columns ([`ColType`]), rows of
//! [`Cell`]s, and free-form metadata (env, model, strategy, ...). One
//! report renders in three formats:
//!
//! * **text** — aligned columns ([`Report::to_text`]; this *replaces*
//!   the legacy `print_*` layouts — same values, uniform rendering:
//!   missing cells print `-`, ratios print as raw fractions);
//! * **JSON** — via [`crate::util::json`], round-trippable through
//!   [`Report::from_json`] (numbers travel as f64, so integer cells
//!   are exact up to 2^53 — far above anything a report holds);
//! * **CSV** — RFC-4180-style quoting ([`Report::to_csv`]).
//!
//! Typing lives in the columns: every cell pushed into a report is
//! checked against its column's [`ColType`], and [`Cell::Missing`]
//! (an OOM cell, a never-reached target, ...) is legal in any column.
//! The distinction between `Float`, `Bytes`, `Secs` and `Speedup` is a
//! *rendering* contract — JSON and CSV always carry the raw number, so
//! downstream tooling (perf trajectories, diffing) never has to parse
//! `"3.42 GB"` back apart.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::{fmt_bytes, fmt_secs};

/// Meta key carrying the wall-clock seconds an experiment took
/// ([`crate::exp::ExperimentRegistry::run`] stamps it). Wall-clock is
/// non-deterministic, so the text renderer keeps it out of the
/// `[k=v, ...]` provenance line and prints it as a trailing footer —
/// and nothing equality-tested ever includes it.
pub const ELAPSED_SECS_META: &str = "elapsed_secs";

/// Output format for rendering a [`Report`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Text,
    Json,
    Csv,
}

impl Format {
    /// Parse a CLI spelling (`text`/`txt`, `json`, `csv`).
    pub fn parse(s: &str) -> Option<Format> {
        match s.to_ascii_lowercase().as_str() {
            "text" | "txt" => Some(Format::Text),
            "json" => Some(Format::Json),
            "csv" => Some(Format::Csv),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::Text => "text",
            Format::Json => "json",
            Format::Csv => "csv",
        }
    }
}

/// The declared type of a report column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColType {
    /// Free-form label (model, technique, grouping, ...).
    Str,
    /// Integer count (devices, epochs, stages, ...).
    Int,
    /// Dimensionless number (hours, GB, losses — caller-chosen unit).
    Float,
    /// Byte count; text renders via [`fmt_bytes`].
    Bytes,
    /// Duration in seconds; text renders via [`fmt_secs`].
    Secs,
    /// Ratio vs a baseline; text renders as `N.NNx`.
    Speedup,
}

impl ColType {
    pub fn name(self) -> &'static str {
        match self {
            ColType::Str => "str",
            ColType::Int => "int",
            ColType::Float => "float",
            ColType::Bytes => "bytes",
            ColType::Secs => "secs",
            ColType::Speedup => "speedup",
        }
    }

    pub fn parse(s: &str) -> Option<ColType> {
        match s {
            "str" => Some(ColType::Str),
            "int" => Some(ColType::Int),
            "float" => Some(ColType::Float),
            "bytes" => Some(ColType::Bytes),
            "secs" => Some(ColType::Secs),
            "speedup" => Some(ColType::Speedup),
            _ => None,
        }
    }

    /// Str columns left-align in text output, numeric columns right-align.
    fn left_aligned(self) -> bool {
        matches!(self, ColType::Str)
    }
}

/// A typed column header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Column {
    pub name: String,
    pub ty: ColType,
}

/// One value of a report row.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    Str(String),
    Int(i64),
    Float(f64),
    Bytes(u64),
    Secs(f64),
    Speedup(f64),
    /// Absent value (OOM, unplannable, target never reached). Legal in
    /// any column; renders as `-` in text, `null` in JSON, empty in CSV.
    Missing,
}

impl Cell {
    /// Lift an `Option` into a cell, `None` becoming [`Cell::Missing`].
    pub fn opt<T>(v: Option<T>, f: impl FnOnce(T) -> Cell) -> Cell {
        v.map(f).unwrap_or(Cell::Missing)
    }

    fn matches(&self, ty: ColType) -> bool {
        matches!(
            (self, ty),
            (Cell::Missing, _)
                | (Cell::Str(_), ColType::Str)
                | (Cell::Int(_), ColType::Int)
                | (Cell::Float(_), ColType::Float)
                | (Cell::Bytes(_), ColType::Bytes)
                | (Cell::Secs(_), ColType::Secs)
                | (Cell::Speedup(_), ColType::Speedup)
        )
    }

    /// The raw numeric value, when there is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Cell::Int(v) => Some(*v as f64),
            Cell::Float(v) | Cell::Secs(v) | Cell::Speedup(v) => Some(*v),
            Cell::Bytes(v) => Some(*v as f64),
            Cell::Str(_) | Cell::Missing => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Cell::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_missing(&self) -> bool {
        matches!(self, Cell::Missing)
    }

    /// Human rendering for the text format.
    fn text(&self) -> String {
        match self {
            Cell::Str(s) => s.clone(),
            Cell::Int(v) => v.to_string(),
            Cell::Float(v) => fmt_float(*v),
            Cell::Bytes(v) => fmt_bytes(*v),
            Cell::Secs(v) => fmt_secs(*v),
            Cell::Speedup(v) => format!("{v:.2}x"),
            Cell::Missing => "-".into(),
        }
    }

    /// Raw rendering for CSV (numbers unformatted, missing empty).
    fn csv(&self) -> String {
        match self {
            Cell::Str(s) => csv_quote(s),
            Cell::Int(v) => v.to_string(),
            Cell::Float(v) | Cell::Secs(v) | Cell::Speedup(v) => fmt_f64_raw(*v),
            Cell::Bytes(v) => v.to_string(),
            Cell::Missing => String::new(),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Cell::Str(s) => Json::Str(s.clone()),
            // exact: push() rejects integers beyond the f64-exact range
            Cell::Int(v) => Json::from(*v),
            Cell::Bytes(v) => Json::from(*v),
            // push() rejects non-finite values, so Num is always valid JSON
            Cell::Float(v) | Cell::Secs(v) | Cell::Speedup(v) => Json::Num(*v),
            Cell::Missing => Json::Null,
        }
    }

    fn from_json(v: &Json, ty: ColType) -> Result<Cell> {
        // integral columns are validated, not coerced: a fractional or
        // out-of-range number is a corrupt file, not a value to truncate
        let int = |n: f64| -> Result<i64> {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                Ok(n as i64)
            } else {
                bail!("{n} is not an integer cell value")
            }
        };
        // non-finite floats could not have been written by to_json (push
        // rejects them) and would not re-serialize as valid JSON
        let finite = |n: f64| -> Result<f64> {
            if n.is_finite() {
                Ok(n)
            } else {
                bail!("{n} is not a finite cell value")
            }
        };
        Ok(match (v, ty) {
            (Json::Null, _) => Cell::Missing,
            (Json::Str(s), ColType::Str) => Cell::Str(s.clone()),
            (Json::Num(n), ColType::Int) => Cell::Int(int(*n)?),
            (Json::Num(n), ColType::Float) => Cell::Float(finite(*n)?),
            (Json::Num(n), ColType::Bytes) => {
                if *n < 0.0 {
                    bail!("{n} is not a byte count");
                }
                Cell::Bytes(int(*n)? as u64)
            }
            (Json::Num(n), ColType::Secs) => Cell::Secs(finite(*n)?),
            (Json::Num(n), ColType::Speedup) => Cell::Speedup(finite(*n)?),
            (v, ty) => bail!("cell {v} does not fit column type {}", ty.name()),
        })
    }
}

/// Shortest float rendering for text cells: fixed 3 decimals with the
/// trailing zeros trimmed (`1.500` → `1.5`, `2.000` → `2`); values the
/// 3-decimal rendering would collapse to 0 fall back to scientific so
/// a tiny nonzero measurement stays distinguishable from zero.
fn fmt_float(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let s = format!("{v:.3}");
    let s = s.trim_end_matches('0').trim_end_matches('.');
    if (s == "0" || s == "-0") && v != 0.0 {
        return format!("{v:e}");
    }
    s.to_string()
}

/// Raw float for CSV: Rust's shortest round-trip `Display`.
fn fmt_f64_raw(v: f64) -> String {
    format!("{v}")
}

fn csv_quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// A named, typed table of experiment results.
///
/// `columns` and `rows` are private so every row enters through the
/// checked [`Report::push`] — the renderers rely on its arity, type and
/// finiteness invariants.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    /// Registry name of the producing experiment (`table5`, `sweep`, ...).
    pub name: String,
    /// Human title — the text format's first line.
    pub title: String,
    columns: Vec<Column>,
    rows: Vec<Vec<Cell>>,
    /// Free-form provenance: env, model, strategy, seq, minibatch, ...
    /// Deliberately string-valued — it labels a report; measurements
    /// belong in typed columns. (`from_json` also accepts scalar JSON
    /// meta values, stringifying them.)
    pub meta: BTreeMap<String, String>,
}

impl Report {
    pub fn new(name: impl Into<String>, title: impl Into<String>) -> Report {
        Report {
            name: name.into(),
            title: title.into(),
            columns: Vec::new(),
            rows: Vec::new(),
            meta: BTreeMap::new(),
        }
    }

    /// Append a typed column (builder-style; declare all columns before
    /// pushing rows).
    pub fn column(mut self, name: impl Into<String>, ty: ColType) -> Report {
        assert!(self.rows.is_empty(), "declare columns before pushing rows");
        self.columns.push(Column { name: name.into(), ty });
        self
    }

    /// Attach a metadata entry (builder-style).
    pub fn meta(mut self, key: impl Into<String>, value: impl ToString) -> Report {
        self.meta.insert(key.into(), value.to_string());
        self
    }

    /// Append a row.
    ///
    /// Panics on arity or type mismatch — a report schema violation is a
    /// programming error in the producing experiment, not a runtime
    /// condition.
    pub fn push(&mut self, row: Vec<Cell>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "report {:?}: row arity {} != {} columns",
            self.name,
            row.len(),
            self.columns.len()
        );
        for (cell, col) in row.iter().zip(&self.columns) {
            assert!(
                cell.matches(col.ty),
                "report {:?}: cell {:?} does not fit column {:?} ({})",
                self.name,
                cell,
                col.name,
                col.ty.name()
            );
            // values that could not survive the JSON round-trip are
            // rejected at the producer, not discovered by the loader:
            // JSON has no NaN/inf (push Cell::Missing instead), and
            // integral cells travel as f64, exact only below ~9e15
            match cell {
                Cell::Float(v) | Cell::Secs(v) | Cell::Speedup(v) => assert!(
                    v.is_finite(),
                    "report {:?}: non-finite {:?} in column {:?}; use Cell::Missing",
                    self.name,
                    cell,
                    col.name
                ),
                Cell::Int(v) => assert!(
                    v.unsigned_abs() < 9_000_000_000_000_000,
                    "report {:?}: {v} in column {:?} exceeds the f64-exact integer range",
                    self.name,
                    col.name
                ),
                Cell::Bytes(v) => assert!(
                    *v < 9_000_000_000_000_000,
                    "report {:?}: {v} in column {:?} exceeds the f64-exact integer range",
                    self.name,
                    col.name
                ),
                Cell::Str(_) | Cell::Missing => {}
            }
        }
        self.rows.push(row);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    pub fn rows(&self) -> &[Vec<Cell>] {
        &self.rows
    }

    /// The cell at `(row, column-name)`, if both exist.
    pub fn cell(&self, row: usize, col: &str) -> Option<&Cell> {
        let c = self.columns.iter().position(|c| c.name == col)?;
        self.rows.get(row)?.get(c)
    }

    /// Render in `format`.
    pub fn render(&self, format: Format) -> String {
        match format {
            Format::Text => self.to_text(),
            Format::Json => {
                let mut s = self.to_json().to_string_pretty();
                s.push('\n');
                s
            }
            Format::Csv => self.to_csv(),
        }
    }

    // -- text ---------------------------------------------------------------

    /// Aligned fixed-width text (title, metadata line, header, rows).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.title);
        out.push('\n');
        let pairs: Vec<String> = self
            .meta
            .iter()
            .filter(|(k, _)| k.as_str() != ELAPSED_SECS_META)
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        if !pairs.is_empty() {
            out.push_str(&format!("  [{}]\n", pairs.join(", ")));
        }
        // column widths over header + every rendered cell, in chars —
        // format! pads by char count, and cells like "250.0 µs" hold
        // multi-byte glyphs
        let rendered: Vec<Vec<String>> =
            self.rows.iter().map(|r| r.iter().map(Cell::text).collect()).collect();
        let chars = |s: &str| s.chars().count();
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                rendered
                    .iter()
                    .map(|r| chars(&r[i]))
                    .chain(std::iter::once(chars(&c.name)))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let mut line = |cells: &[String]| {
            let fields: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    if self.columns[i].ty.left_aligned() {
                        format!("{:<w$}", s, w = widths[i])
                    } else {
                        format!("{:>w$}", s, w = widths[i])
                    }
                })
                .collect();
            out.push_str(fields.join("  ").trim_end());
            out.push('\n');
        };
        let header: Vec<String> = self.columns.iter().map(|c| c.name.clone()).collect();
        line(&header);
        for r in &rendered {
            line(r);
        }
        if let Some(secs) =
            self.meta.get(ELAPSED_SECS_META).and_then(|s| s.parse::<f64>().ok())
        {
            out.push_str(&format!("  elapsed: {}\n", fmt_secs(secs)));
        }
        out
    }

    // -- csv ----------------------------------------------------------------

    /// CSV: header of column names, then raw (unformatted) values.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let header: Vec<String> =
            self.columns.iter().map(|c| csv_quote(&c.name)).collect();
        out.push_str(&header.join(","));
        out.push('\n');
        for row in &self.rows {
            let fields: Vec<String> = row.iter().map(Cell::csv).collect();
            out.push_str(&fields.join(","));
            out.push('\n');
        }
        out
    }

    // -- json ---------------------------------------------------------------

    /// Structured JSON: name/title/meta, typed column schema, row arrays.
    pub fn to_json(&self) -> Json {
        let columns: Json = self
            .columns
            .iter()
            .map(|c| {
                crate::util::json::obj(vec![
                    ("name", Json::Str(c.name.clone())),
                    ("type", Json::Str(c.ty.name().into())),
                ])
            })
            .collect();
        let rows: Json = self
            .rows
            .iter()
            .map(|r| r.iter().map(Cell::to_json).collect::<Json>())
            .collect();
        let meta = Json::Obj(
            self.meta.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        crate::util::json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("title", Json::Str(self.title.clone())),
            ("meta", meta),
            ("columns", columns),
            ("rows", rows),
        ])
    }

    /// Rebuild a report from [`Report::to_json`] output (the golden tests
    /// assert `from_json(parse(to_json)) == self`).
    pub fn from_json(v: &Json) -> Result<Report> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .context("report json: missing name")?;
        let title = v
            .get("title")
            .and_then(Json::as_str)
            .context("report json: missing title")?;
        let mut report = Report::new(name, title);
        if let Some(meta) = v.get("meta").and_then(Json::as_obj) {
            for (k, val) in meta {
                // meta is string-valued provenance; accept scalar JSON
                // too so files from a future typed-meta writer still load
                let s = match val {
                    Json::Str(s) => s.clone(),
                    Json::Num(_) | Json::Bool(_) => val.to_string_compact(),
                    _ => anyhow::bail!("report json: non-scalar meta value for {k:?}"),
                };
                report.meta.insert(k.clone(), s);
            }
        }
        for c in v
            .get("columns")
            .and_then(Json::as_arr)
            .context("report json: missing columns")?
        {
            let cname = c
                .get("name")
                .and_then(Json::as_str)
                .context("report json: column missing name")?;
            let ty = c
                .get("type")
                .and_then(Json::as_str)
                .and_then(ColType::parse)
                .context("report json: bad column type")?;
            report.columns.push(Column { name: cname.into(), ty });
        }
        for row in v
            .get("rows")
            .and_then(Json::as_arr)
            .context("report json: missing rows")?
        {
            let cells = row.as_arr().context("report json: row is not an array")?;
            if cells.len() != report.columns.len() {
                bail!(
                    "report json: row arity {} != {} columns",
                    cells.len(),
                    report.columns.len()
                );
            }
            let mut parsed = Vec::with_capacity(cells.len());
            for (c, col) in cells.iter().zip(&report.columns) {
                parsed.push(Cell::from_json(c, col.ty)?);
            }
            report.rows.push(parsed);
        }
        Ok(report)
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_text())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new("sample", "Sample — a demo report")
            .column("model", ColType::Str)
            .column("n", ColType::Int)
            .column("hours", ColType::Float)
            .column("mem", ColType::Bytes)
            .column("latency", ColType::Secs)
            .column("vs_base", ColType::Speedup)
            .meta("env", "env_a")
            .meta("seq", 128);
        r.push(vec![
            Cell::Str("t5-base".into()),
            Cell::Int(4),
            Cell::Float(1.25),
            Cell::Bytes(3 * 1024 * 1024),
            Cell::Secs(0.25),
            Cell::Speedup(3.5),
        ]);
        r.push(vec![
            Cell::Str("t5-large".into()),
            Cell::Int(8),
            Cell::Missing,
            Cell::Missing,
            Cell::Missing,
            Cell::Missing,
        ]);
        r
    }

    #[test]
    fn text_renders_aligned() {
        let t = sample().to_text();
        assert!(t.starts_with("Sample — a demo report\n"));
        assert!(t.contains("env=env_a"));
        assert!(t.contains("seq=128"));
        assert!(t.contains("t5-base"));
        assert!(t.contains("3.00 MB"));
        assert!(t.contains("250.00 ms"));
        assert!(t.contains("3.50x"));
        assert!(t.contains('-'), "missing cells render as -");
        // header and rows align on the first column
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 2 + 1 + 2, "title, meta, header, two rows");
    }

    #[test]
    fn elapsed_meta_renders_as_footer_not_in_meta_line() {
        let r = sample().meta(ELAPSED_SECS_META, "1.5");
        let t = r.to_text();
        assert!(!t.contains("elapsed_secs=1.5"), "kept out of the provenance line: {t}");
        assert!(t.contains("env=env_a"), "other meta still renders: {t}");
        assert!(t.ends_with("  elapsed: 1.50 s\n"), "footer: {t}");
        assert_eq!(t.lines().count(), 2 + 1 + 2 + 1, "title, meta, header, rows, footer");
        // a report whose only meta is the elapsed stamp skips the
        // bracket line entirely
        let bare = Report::new("x", "t").meta(ELAPSED_SECS_META, "0.25");
        assert!(!bare.to_text().contains("[]"));
    }

    #[test]
    fn csv_has_raw_values() {
        let c = sample().to_csv();
        let lines: Vec<&str> = c.lines().collect();
        assert_eq!(lines[0], "model,n,hours,mem,latency,vs_base");
        assert_eq!(lines[1], "t5-base,4,1.25,3145728,0.25,3.5");
        assert_eq!(lines[2], "t5-large,8,,,,");
    }

    #[test]
    fn csv_quotes_commas_and_quotes() {
        assert_eq!(csv_quote("plain"), "plain");
        assert_eq!(csv_quote("a,b"), "\"a,b\"");
        assert_eq!(csv_quote("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn json_roundtrips_exactly() {
        let r = sample();
        let s = r.render(Format::Json);
        let parsed = Json::parse(&s).expect("valid json");
        let back = Report::from_json(&parsed).expect("report shape");
        assert_eq!(back, r);
        // compact form round-trips too
        let compact = Json::parse(&r.to_json().to_string_compact()).unwrap();
        assert_eq!(Report::from_json(&compact).unwrap(), r);
    }

    #[test]
    fn json_missing_is_null() {
        let j = sample().to_json();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].as_arr().unwrap()[2], Json::Null);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn push_checks_arity() {
        let mut r = Report::new("x", "x").column("a", ColType::Int);
        r.push(vec![Cell::Int(1), Cell::Int(2)]);
    }

    #[test]
    #[should_panic(expected = "does not fit column")]
    fn push_checks_types() {
        let mut r = Report::new("x", "x").column("a", ColType::Int);
        r.push(vec![Cell::Str("not an int".into())]);
    }

    #[test]
    #[should_panic(expected = "use Cell::Missing")]
    fn push_rejects_non_finite_floats() {
        let mut r = Report::new("x", "x").column("ratio", ColType::Speedup);
        r.push(vec![Cell::Speedup(f64::NAN)]);
    }

    #[test]
    #[should_panic(expected = "f64-exact integer range")]
    fn push_rejects_unrepresentable_ints() {
        let mut r = Report::new("x", "x").column("n", ColType::Int);
        r.push(vec![Cell::Int(10_000_000_000_000_000)]);
    }

    #[test]
    fn missing_fits_any_column() {
        let mut r = Report::new("x", "x")
            .column("a", ColType::Int)
            .column("b", ColType::Str);
        r.push(vec![Cell::Missing, Cell::Missing]);
        assert_eq!(r.n_rows(), 1);
    }

    #[test]
    fn cell_lookup_by_name() {
        let r = sample();
        assert_eq!(r.cell(0, "n"), Some(&Cell::Int(4)));
        assert_eq!(r.cell(1, "hours"), Some(&Cell::Missing));
        assert!(r.cell(0, "absent").is_none());
        assert!(r.cell(9, "n").is_none());
    }

    #[test]
    fn from_json_rejects_corrupt_integral_cells() {
        let cell = |ty: ColType, v: Json| Cell::from_json(&v, ty);
        assert!(cell(ColType::Int, Json::Num(3.7)).is_err(), "fractional int");
        assert!(cell(ColType::Bytes, Json::Num(-1.0)).is_err(), "negative bytes");
        assert!(cell(ColType::Bytes, Json::Num(2.5)).is_err(), "fractional bytes");
        assert!(cell(ColType::Int, Json::Str("7".into())).is_err(), "string in int");
        assert_eq!(cell(ColType::Int, Json::Num(-3.0)).unwrap(), Cell::Int(-3));
        assert_eq!(cell(ColType::Bytes, Json::Num(4096.0)).unwrap(), Cell::Bytes(4096));
    }

    #[test]
    fn format_parsing() {
        assert_eq!(Format::parse("json"), Some(Format::Json));
        assert_eq!(Format::parse("TEXT"), Some(Format::Text));
        assert_eq!(Format::parse("csv"), Some(Format::Csv));
        assert_eq!(Format::parse("yaml"), None);
    }

    #[test]
    fn float_text_trimming() {
        assert_eq!(fmt_float(1.5), "1.5");
        assert_eq!(fmt_float(2.0), "2");
        assert_eq!(fmt_float(0.125), "0.125");
        assert_eq!(fmt_float(1.23456), "1.235");
        assert_eq!(fmt_float(0.0), "0");
        // tiny nonzero values stay distinguishable from zero
        assert_eq!(fmt_float(0.0004), "4e-4");
        assert_eq!(fmt_float(-0.0004), "-4e-4");
    }

    #[test]
    fn from_json_rejects_non_finite_numbers() {
        // 1e999 is valid JSON but parses to f64 infinity
        let v = Json::parse("1e999").unwrap();
        assert!(Cell::from_json(&v, ColType::Float).is_err());
        assert!(Cell::from_json(&v, ColType::Secs).is_err());
        assert!(Cell::from_json(&v, ColType::Speedup).is_err());
    }
}
