//! The `fleet_learn` experiment: train the in-simulator DQN scheduler
//! ([`crate::learn`]), round-trip its weights through the JSON dump
//! format, and evaluate the reloaded [`crate::learn::LearnedQueue`]
//! against the hand-written disciplines on held-out workloads.
//!
//! The report has two row phases sharing one schema:
//!
//! * `train` rows — the episode curve (reward, ε, fitted-Q loss, and
//!   the episode's own goodput/miss-rate under the exploring policy);
//! * `eval` rows — one per policy (the learned one plus
//!   FIFO / EASY-backfill / EDF), aggregated over the held-out seeds
//!   ([`crate::learn::held_out_seed`] — disjoint from every training
//!   seed by construction).
//!
//! The weights the eval rows use are **not** the in-memory trained
//! network: they are dumped to JSON text and parsed back first
//! ([`crate::learn::Mlp::to_json`]/[`from_json`](crate::learn::Mlp::from_json)),
//! so the experiment exercises the same dump → reload path the CLI and
//! CI smoke use. The dump is bit-exact, so this costs nothing but
//! proves the artifact is sufficient.

use anyhow::{Context, Result};

use crate::cluster::Env;
use crate::fleet::QueuePolicyRegistry;
use crate::learn::{evaluate, train_observed, LearnedQueue, Mlp, TrainConfig};
use crate::obs::Observer;
use crate::util::json::Json;

use super::report::{Cell, ColType, Report};

/// The learn Report's empty shell: one schema shared by the `train`
/// and `eval` phases (cells not meaningful for a phase are
/// [`Cell::Missing`]).
pub fn learn_schema(name: &str, title: &str) -> Report {
    Report::new(name, title)
        .column("phase", ColType::Str) // "train" | "eval"
        .column("episode", ColType::Int) // train rows; Missing on eval
        .column("policy", ColType::Str)
        .column("steps", ColType::Int) // dispatch decisions taken
        .column("reward", ColType::Float)
        .column("epsilon", ColType::Float)
        .column("loss", ColType::Float)
        .column("goodput", ColType::Float) // deadline-met jobs/hour
        .column("miss_rate", ColType::Float)
        .column("completed", ColType::Int)
        .column("met", ColType::Int)
}

/// Queue policies the learned scheduler is evaluated against.
const EVAL_BASELINES: &[&str] = &["fifo", "backfill", "edf"];

/// Train + dump + reload + evaluate, as one typed report. The returned
/// [`Mlp`] is the *reloaded* network (identical to the trained one —
/// the dump is bit-exact), so callers can persist exactly what was
/// evaluated.
pub fn learn_report(env: &Env, cfg: &TrainConfig) -> Result<(Report, Mlp)> {
    learn_report_observed(env, cfg, &Observer::disabled())
}

/// [`learn_report`] with an [`Observer`]: training runs through
/// [`crate::learn::train_observed`], so episode spans, fleet job events
/// and the `training` wall-clock phase land in the trace.
pub fn learn_report_observed(
    env: &Env,
    cfg: &TrainConfig,
    obs: &Observer,
) -> Result<(Report, Mlp)> {
    let result = train_observed(env, cfg, obs)?;

    // round-trip the weights through the JSON dump format: what the
    // eval rows measure is what `--weights` / a later `from_json` gets
    let dump = result.net.to_json().to_string_pretty();
    let net = Mlp::from_json(
        &Json::parse(&dump).map_err(|e| anyhow::anyhow!("re-parsing weight dump: {e}"))?,
    )
    .context("reloading dumped weights")?;

    let mut report = learn_schema(
        "fleet_learn",
        "Learn — in-sim DQN training curve + held-out eval vs hand-written disciplines",
    )
    .meta("env", env.name.clone())
    .meta("episodes", cfg.episodes)
    .meta("jobs", cfg.jobs)
    .meta("seed", cfg.seed)
    .meta("eval_seeds", cfg.eval_seeds)
    .meta("hidden", cfg.dqn.hidden)
    .meta("lr", cfg.dqn.lr)
    .meta("gamma", cfg.dqn.gamma)
    .meta("weights_bytes", dump.len());

    for e in &result.episodes {
        report.push(vec![
            Cell::Str("train".into()),
            Cell::Int(e.episode as i64),
            Cell::Str("Learned-trainer".into()),
            Cell::Int(e.steps as i64),
            Cell::Float(e.reward),
            Cell::Float(e.epsilon),
            Cell::opt(e.loss, Cell::Float),
            Cell::Float(e.goodput),
            Cell::Float(e.miss_rate),
            Cell::Int(e.completed as i64),
            Cell::Int(e.met as i64),
        ]);
    }

    let learned = LearnedQueue::new(net.clone());
    let registry = QueuePolicyRegistry::with_defaults();
    let mut evals = vec![evaluate(env, cfg, &learned)?];
    for name in EVAL_BASELINES {
        evals.push(evaluate(env, cfg, registry.get_or_err(name)?.as_ref())?);
    }
    for ev in &evals {
        report.push(vec![
            Cell::Str("eval".into()),
            Cell::Missing,
            Cell::Str(ev.policy.clone()),
            Cell::Missing,
            Cell::Missing,
            Cell::Missing,
            Cell::Missing,
            Cell::Float(ev.goodput),
            Cell::Float(ev.miss_rate),
            Cell::Int(ev.completed as i64),
            Cell::Int(ev.met as i64),
        ]);
    }

    Ok((report, net))
}

/// Registry entry point: the CI-fast default configuration (small
/// episode count, small workloads) on Env.A. For real training runs
/// use `pacpp learn` with explicit `--episodes/--jobs`.
pub fn fleet_learn_report() -> Result<Report> {
    let env = Env::env_a();
    let cfg = TrainConfig { episodes: 8, jobs: 20, ..TrainConfig::default() };
    Ok(learn_report(&env, &cfg)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learn_report_has_train_and_eval_phases() {
        let env = Env::env_a();
        let cfg = TrainConfig { episodes: 2, jobs: 8, eval_seeds: 1, ..TrainConfig::default() };
        let (report, net) = learn_report(&env, &cfg).expect("learn_report");
        let rows = report.rows();
        // 2 train rows + learned + 3 baselines
        assert_eq!(rows.len(), 2 + 1 + EVAL_BASELINES.len());
        let phases: Vec<_> = rows
            .iter()
            .map(|r| match &r[0] {
                Cell::Str(s) => s.as_str(),
                other => panic!("phase cell should be Str, got {other:?}"),
            })
            .collect();
        assert_eq!(phases[..2], ["train", "train"]);
        assert!(phases[2..].iter().all(|p| *p == "eval"));
        // the returned net survived a dump→reload round trip
        assert_eq!(net.n_in(), crate::learn::N_FEATURES);
    }
}
