//! Design-choice ablations beyond the paper's figures (DESIGN.md §5):
//!
//! * [`ablate_schedule`] — 1F1B vs GPipe-style all-forward-then-backward
//!   (the paper adopts 1F1B [40] "to release the activation memory
//!   produced by FP for reuse"; this quantifies both the memory and the
//!   latency effect).
//! * [`ablate_bandwidth`] — sensitivity of every system to LAN bandwidth
//!   (1 Gbps LAN vs 100 Mbps Wi-Fi class).
//! * [`ablate_microbatches`] — mini-batch pipelining depth M sweep.

use crate::baselines::{run_system, System, TrainJob};
use crate::cluster::{Env, Network};
use crate::model::graph::LayerGraph;
use crate::model::{Method, ModelSpec, Precision};
use crate::planner::{plan, PlannerOptions};
use crate::profiler::Profile;
use crate::sched::{simulate_minibatch, Op};

fn profile(spec: &ModelSpec, method: Method) -> Profile {
    Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, 128)
}

// ---------------------------------------------------------------------------
// 1F1B vs GPipe schedule
// ---------------------------------------------------------------------------

/// GPipe-style order: all forwards, then all backwards.
pub fn gpipe_order(m: usize) -> Vec<Op> {
    (0..m).map(Op::F).chain((0..m).map(Op::B)).collect()
}

#[derive(Debug, Clone)]
pub struct ScheduleAblation {
    pub model: String,
    pub minibatch_time_1f1b: f64,
    pub minibatch_time_gpipe: f64,
    /// peak in-flight micro-batches (stage 0): memory proxy
    pub in_flight_1f1b: usize,
    pub in_flight_gpipe: usize,
}

pub fn ablate_schedule() -> Vec<ScheduleAblation> {
    let env = Env::nanos(4);
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        let prof = profile(&spec, Method::pa(false));
        let opts = PlannerOptions {
            microbatch: 4,
            n_microbatches: 8,
            ..Default::default()
        };
        let Ok(p) = plan(&prof, &env, &opts) else { continue };
        let sim = simulate_minibatch(&p, &prof, &env.network);
        // GPipe: same stages, but every micro-batch forwarded before any
        // backward => stage 0 holds all M activations
        let gpipe_in_flight = p.microbatches;
        // latency: same compute volume, bubbles differ only at the
        // warmup/drain boundary; approximate via the simulator's span
        // plus the extra drain (all backwards serialized at the end)
        let drain_extra: f64 = p
            .stages
            .iter()
            .skip(1)
            .map(|s| s.e_b)
            .sum();
        rows.push(ScheduleAblation {
            model: spec.name.clone(),
            minibatch_time_1f1b: sim.minibatch_time,
            minibatch_time_gpipe: sim.minibatch_time + drain_extra,
            in_flight_1f1b: sim.peak_in_flight[0],
            in_flight_gpipe: gpipe_in_flight,
        });
    }
    rows
}

pub fn print_ablate_schedule() {
    println!("Ablation — 1F1B vs GPipe scheduling (4x Nano-H, M=8, Parallel Adapters)");
    println!(
        "{:<12} {:>12} {:>12} {:>14} {:>14}",
        "model", "1F1B (s)", "GPipe (s)", "acts in-flight", "GPipe in-flight"
    );
    for r in ablate_schedule() {
        println!(
            "{:<12} {:>12.2} {:>12.2} {:>14} {:>15}",
            r.model, r.minibatch_time_1f1b, r.minibatch_time_gpipe, r.in_flight_1f1b,
            r.in_flight_gpipe
        );
    }
}

// ---------------------------------------------------------------------------
// LAN bandwidth sensitivity
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct BandwidthAblation {
    pub system: String,
    pub hours_lan: Option<f64>,
    pub hours_wifi: Option<f64>,
}

pub fn ablate_bandwidth() -> Vec<BandwidthAblation> {
    let spec = ModelSpec::t5_base();
    let job = TrainJob::new(3668, 1, 128, 16);
    let mut rows = Vec::new();
    for (system, method) in [
        (System::DataParallel, Method::adapters_default()),
        (System::PipelineParallel, Method::adapters_default()),
        (System::HetPipe, Method::FullFT),
        (System::PacPlus, Method::pa(false)),
    ] {
        let prof = profile(&spec, method);
        let run = |net: Network| {
            let mut env = Env::env_a();
            env.network = net;
            run_system(system, &prof, &env, job).ok().map(|r| r.total / 3600.0)
        };
        rows.push(BandwidthAblation {
            system: system.name().into(),
            hours_lan: run(Network::lan_1gbps()),
            hours_wifi: run(Network::wifi_100mbps()),
        });
    }
    rows
}

pub fn print_ablate_bandwidth() {
    println!("Ablation — network sensitivity (T5-Base, MRPC-sized, Env.A devices)");
    println!("{:<14} {:>12} {:>14} {:>10}", "system", "1Gbps (h)", "100Mbps (h)", "slowdown");
    for r in ablate_bandwidth() {
        let fmt = |x: Option<f64>| x.map(|v| format!("{v:.2}")).unwrap_or("OOM".into());
        let slow = match (r.hours_lan, r.hours_wifi) {
            (Some(a), Some(b)) => format!("{:.2}x", b / a),
            _ => "-".into(),
        };
        println!(
            "{:<14} {:>12} {:>14} {:>10}",
            r.system,
            fmt(r.hours_lan),
            fmt(r.hours_wifi),
            slow
        );
    }
}

// ---------------------------------------------------------------------------
// micro-batch depth sweep
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MicrobatchAblation {
    pub m: usize,
    pub minibatch_time: f64,
    pub bubble_fraction: f64,
    pub peak_mem_gb: f64,
}

pub fn ablate_microbatches() -> Vec<MicrobatchAblation> {
    let env = Env::nanos(4);
    let prof = profile(&ModelSpec::t5_large(), Method::pa(false));
    let mut rows = Vec::new();
    for m in [1usize, 2, 4, 8, 16] {
        let opts = PlannerOptions {
            microbatch: 4,
            n_microbatches: m,
            ..Default::default()
        };
        let Ok(p) = plan(&prof, &env, &opts) else { continue };
        let sim = simulate_minibatch(&p, &prof, &env.network);
        rows.push(MicrobatchAblation {
            m,
            minibatch_time: sim.minibatch_time / m as f64, // per micro-batch
            bubble_fraction: sim.bubble_fraction,
            peak_mem_gb: p.peak_mem() as f64 / 1e9,
        });
    }
    rows
}

pub fn print_ablate_microbatches() {
    println!("Ablation — pipelining depth M (T5-Large, 4x Nano-H, per-microbatch cost)");
    println!("{:<6} {:>16} {:>10} {:>12}", "M", "s/microbatch", "bubbles", "peak mem");
    for r in ablate_microbatches() {
        println!(
            "{:<6} {:>16.3} {:>9.0}% {:>10.2}GB",
            r.m,
            r.minibatch_time,
            r.bubble_fraction * 100.0,
            r.peak_mem_gb
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_order_shape() {
        let o = gpipe_order(3);
        assert_eq!(o, vec![Op::F(0), Op::F(1), Op::F(2), Op::B(0), Op::B(1), Op::B(2)]);
    }

    #[test]
    fn one_f_one_b_saves_memory_vs_gpipe() {
        for r in ablate_schedule() {
            assert!(
                r.in_flight_1f1b <= r.in_flight_gpipe,
                "{}: 1F1B {} vs GPipe {}",
                r.model,
                r.in_flight_1f1b,
                r.in_flight_gpipe
            );
            assert!(r.minibatch_time_1f1b <= r.minibatch_time_gpipe);
        }
    }

    #[test]
    fn wifi_hurts_communication_heavy_systems_most() {
        let rows = ablate_bandwidth();
        let slow = |sys: &str| {
            rows.iter()
                .find(|r| r.system == sys)
                .and_then(|r| Some(r.hours_wifi? / r.hours_lan?))
        };
        // HetPipe's PS traffic makes it the most bandwidth-sensitive
        if let (Some(h), Some(p)) = (slow("HetPipe"), slow("PAC+")) {
            assert!(h > p, "HetPipe {h} vs PAC+ {p}");
        }
    }

    #[test]
    fn deeper_pipelining_amortizes_bubbles() {
        let rows = ablate_microbatches();
        assert!(rows.len() >= 3);
        let first = rows.first().unwrap();
        let last = rows.last().unwrap();
        // per-microbatch cost drops as M grows (bubble amortization)...
        assert!(last.minibatch_time < first.minibatch_time);
        // ...but peak memory grows (more in-flight activations)
        assert!(last.peak_mem_gb >= first.peak_mem_gb);
    }
}
