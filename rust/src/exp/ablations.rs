//! Design-choice ablations beyond the paper's figures (DESIGN.md §5):
//!
//! * [`schedule_report`] — 1F1B vs GPipe-style all-forward-then-backward
//!   (the paper adopts 1F1B [40] "to release the activation memory
//!   produced by FP for reuse"; this quantifies both the memory and the
//!   latency effect).
//! * [`bandwidth_report`] — sensitivity of every system to LAN bandwidth
//!   (1 Gbps LAN vs 100 Mbps Wi-Fi class).
//! * [`microbatches_report`] — mini-batch pipelining depth M sweep.
//!
//! Like the tables, each ablation is a private `*_rows()` kernel plus a
//! `*_report()`; the legacy typed-row and `print_*` surfaces are
//! deprecated wrappers kept for one release.

use super::report::{Cell, ColType, Report};
use super::tables::profile as table_profile;
use crate::baselines::{run_system, System, TrainJob};
use crate::cluster::{Env, Network};
use crate::model::{Method, ModelSpec};
use crate::planner::{plan, PlannerOptions};
use crate::profiler::Profile;
use crate::sched::{simulate_minibatch, Op};

/// All ablations use the tables' shared profile constructor at the
/// tables' sequence length, so they cannot diverge from the figures.
fn profile(spec: &ModelSpec, method: Method) -> Profile {
    table_profile(spec, method, 128)
}

// ---------------------------------------------------------------------------
// 1F1B vs GPipe schedule
// ---------------------------------------------------------------------------

/// GPipe-style order: all forwards, then all backwards.
pub fn gpipe_order(m: usize) -> Vec<Op> {
    (0..m).map(Op::F).chain((0..m).map(Op::B)).collect()
}

#[derive(Debug, Clone)]
pub struct ScheduleAblation {
    pub model: String,
    pub minibatch_time_1f1b: f64,
    pub minibatch_time_gpipe: f64,
    /// peak in-flight micro-batches (stage 0): memory proxy
    pub in_flight_1f1b: usize,
    pub in_flight_gpipe: usize,
}

fn schedule_rows() -> Vec<ScheduleAblation> {
    let env = Env::nanos(4);
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        let prof = profile(&spec, Method::pa(false));
        let opts = PlannerOptions {
            microbatch: 4,
            n_microbatches: 8,
            ..Default::default()
        };
        let Ok(p) = plan(&prof, &env, &opts) else { continue };
        let sim = simulate_minibatch(&p, &prof, &env.network);
        // GPipe: same stages, but every micro-batch forwarded before any
        // backward => stage 0 holds all M activations
        let gpipe_in_flight = p.microbatches;
        // latency: same compute volume, bubbles differ only at the
        // warmup/drain boundary; approximate via the simulator's span
        // plus the extra drain (all backwards serialized at the end)
        let drain_extra: f64 = p
            .stages
            .iter()
            .skip(1)
            .map(|s| s.e_b)
            .sum();
        rows.push(ScheduleAblation {
            model: spec.name.clone(),
            minibatch_time_1f1b: sim.minibatch_time,
            minibatch_time_gpipe: sim.minibatch_time + drain_extra,
            in_flight_1f1b: sim.peak_in_flight[0],
            in_flight_gpipe: gpipe_in_flight,
        });
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn ablate_schedule() -> Vec<ScheduleAblation> {
    schedule_rows()
}

/// The 1F1B-vs-GPipe ablation as a typed [`Report`].
pub fn schedule_report() -> Report {
    let mut r = Report::new(
        "ablate_schedule",
        "Ablation — 1F1B vs GPipe scheduling (4x Nano-H, M=8, Parallel Adapters)",
    )
    .column("model", ColType::Str)
    .column("minibatch_1f1b", ColType::Secs)
    .column("minibatch_gpipe", ColType::Secs)
    .column("in_flight_1f1b", ColType::Int)
    .column("in_flight_gpipe", ColType::Int)
    .meta("env", "4xNano-H")
    .meta("microbatches", 8);
    for row in schedule_rows() {
        r.push(vec![
            Cell::Str(row.model),
            Cell::Secs(row.minibatch_time_1f1b),
            Cell::Secs(row.minibatch_time_gpipe),
            Cell::Int(row.in_flight_1f1b as i64),
            Cell::Int(row.in_flight_gpipe as i64),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_ablate_schedule() {
    print!("{}", schedule_report().to_text());
}

// ---------------------------------------------------------------------------
// LAN bandwidth sensitivity
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct BandwidthAblation {
    pub system: String,
    pub hours_lan: Option<f64>,
    pub hours_wifi: Option<f64>,
}

fn bandwidth_rows() -> Vec<BandwidthAblation> {
    let spec = ModelSpec::t5_base();
    let job = TrainJob::new(3668, 1, 128, 16);
    let mut rows = Vec::new();
    for (system, method) in [
        (System::DataParallel, Method::adapters_default()),
        (System::PipelineParallel, Method::adapters_default()),
        (System::HetPipe, Method::FullFT),
        (System::PacPlus, Method::pa(false)),
    ] {
        let prof = profile(&spec, method);
        let run = |net: Network| {
            let mut env = Env::env_a();
            env.network = net;
            run_system(system, &prof, &env, job).ok().map(|r| r.total / 3600.0)
        };
        rows.push(BandwidthAblation {
            system: system.name().into(),
            hours_lan: run(Network::lan_1gbps()),
            hours_wifi: run(Network::wifi_100mbps()),
        });
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn ablate_bandwidth() -> Vec<BandwidthAblation> {
    bandwidth_rows()
}

/// The bandwidth-sensitivity ablation as a typed [`Report`], with the
/// derived `slowdown` [`ColType::Speedup`] column (Wi-Fi over LAN).
pub fn bandwidth_report() -> Report {
    let mut r = Report::new(
        "ablate_bandwidth",
        "Ablation — network sensitivity (T5-Base, MRPC-sized, Env.A devices)",
    )
    .column("system", ColType::Str)
    .column("hours_lan", ColType::Float)
    .column("hours_wifi", ColType::Float)
    .column("slowdown", ColType::Speedup)
    .meta("model", "T5-Base")
    .meta("samples", 3668);
    for row in bandwidth_rows() {
        let slowdown = match (row.hours_lan, row.hours_wifi) {
            (Some(lan), Some(wifi)) if lan > 0.0 => Cell::Speedup(wifi / lan),
            _ => Cell::Missing,
        };
        r.push(vec![
            Cell::Str(row.system),
            Cell::opt(row.hours_lan, Cell::Float),
            Cell::opt(row.hours_wifi, Cell::Float),
            slowdown,
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_ablate_bandwidth() {
    print!("{}", bandwidth_report().to_text());
}

// ---------------------------------------------------------------------------
// micro-batch depth sweep
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MicrobatchAblation {
    pub m: usize,
    pub minibatch_time: f64,
    pub bubble_fraction: f64,
    pub peak_mem_gb: f64,
}

fn microbatch_rows() -> Vec<MicrobatchAblation> {
    let env = Env::nanos(4);
    let prof = profile(&ModelSpec::t5_large(), Method::pa(false));
    let mut rows = Vec::new();
    for m in [1usize, 2, 4, 8, 16] {
        let opts = PlannerOptions {
            microbatch: 4,
            n_microbatches: m,
            ..Default::default()
        };
        let Ok(p) = plan(&prof, &env, &opts) else { continue };
        let sim = simulate_minibatch(&p, &prof, &env.network);
        rows.push(MicrobatchAblation {
            m,
            minibatch_time: sim.minibatch_time / m as f64, // per micro-batch
            bubble_fraction: sim.bubble_fraction,
            peak_mem_gb: p.peak_mem() as f64 / 1e9,
        });
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn ablate_microbatches() -> Vec<MicrobatchAblation> {
    microbatch_rows()
}

/// The pipelining-depth ablation as a typed [`Report`].
pub fn microbatches_report() -> Report {
    let mut r = Report::new(
        "ablate_microbatches",
        "Ablation — pipelining depth M (T5-Large, 4x Nano-H, per-microbatch cost)",
    )
    .column("m", ColType::Int)
    .column("s_per_microbatch", ColType::Secs)
    .column("bubble_fraction", ColType::Float)
    .column("peak_mem_gb", ColType::Float)
    .meta("env", "4xNano-H")
    .meta("model", "T5-Large");
    for row in microbatch_rows() {
        r.push(vec![
            Cell::Int(row.m as i64),
            Cell::Secs(row.minibatch_time),
            Cell::Float(row.bubble_fraction),
            Cell::Float(row.peak_mem_gb),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_ablate_microbatches() {
    print!("{}", microbatches_report().to_text());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_order_shape() {
        let o = gpipe_order(3);
        assert_eq!(o, vec![Op::F(0), Op::F(1), Op::F(2), Op::B(0), Op::B(1), Op::B(2)]);
    }

    #[test]
    fn one_f_one_b_saves_memory_vs_gpipe() {
        for r in schedule_rows() {
            assert!(
                r.in_flight_1f1b <= r.in_flight_gpipe,
                "{}: 1F1B {} vs GPipe {}",
                r.model,
                r.in_flight_1f1b,
                r.in_flight_gpipe
            );
            assert!(r.minibatch_time_1f1b <= r.minibatch_time_gpipe);
        }
    }

    #[test]
    fn wifi_hurts_communication_heavy_systems_most() {
        let rows = bandwidth_rows();
        let slow = |sys: &str| {
            rows.iter()
                .find(|r| r.system == sys)
                .and_then(|r| Some(r.hours_wifi? / r.hours_lan?))
        };
        // HetPipe's PS traffic makes it the most bandwidth-sensitive
        if let (Some(h), Some(p)) = (slow("HetPipe"), slow("PAC+")) {
            assert!(h > p, "HetPipe {h} vs PAC+ {p}");
        }
    }

    #[test]
    fn deeper_pipelining_amortizes_bubbles() {
        let rows = microbatch_rows();
        assert!(rows.len() >= 3);
        let first = rows.first().unwrap();
        let last = rows.last().unwrap();
        // per-microbatch cost drops as M grows (bubble amortization)...
        assert!(last.minibatch_time < first.minibatch_time);
        // ...but peak memory grows (more in-flight activations)
        assert!(last.peak_mem_gb >= first.peak_mem_gb);
    }

    #[test]
    fn bandwidth_report_slowdown_matches_hours() {
        let rep = bandwidth_report();
        for i in 0..rep.n_rows() {
            let lan = rep.cell(i, "hours_lan").unwrap().as_f64();
            let wifi = rep.cell(i, "hours_wifi").unwrap().as_f64();
            let slow = rep.cell(i, "slowdown").unwrap().as_f64();
            match (lan, wifi) {
                (Some(l), Some(w)) => {
                    assert!((slow.unwrap() - w / l).abs() < 1e-12);
                }
                _ => assert!(slow.is_none()),
            }
        }
    }
}
