//! Real-execution accuracy experiments (Table VI, Table VII, Fig. 14).
//!
//! These run actual training through the PJRT runtime on the `small`
//! artifact set with synthetic GLUE-like tasks (DESIGN.md §2): the goal
//! is the paper's *shape* — Parallel Adapters matching the baselines'
//! final quality, quantized backbones costing little accuracy, informed
//! initialization converging faster — on models this testbed can train.
//!
//! Each experiment is a private `*_rows()` kernel plus a `*_report()`;
//! the registry entries (`table6`/`table7`/`fig14`) are marked
//! non-parallel-safe because the trainer keeps process-global adapter
//! state. Legacy typed-row and `print_*` surfaces are deprecated
//! wrappers kept for one release.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::report::{Cell, ColType, Report};
use crate::data::SyntheticTask;
use crate::exec::{self, TrainOptions};
use crate::runtime::{Runtime, Tensor};

/// Training budget for the accuracy experiments.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub train_samples: usize,
    pub epochs: usize,
    pub lr: f32,
}

impl Default for Budget {
    fn default() -> Self {
        Budget { train_samples: 512, epochs: 6, lr: 5e-3 }
    }
}

fn dataset(rt: &Runtime, n: usize, seed: u64) -> SyntheticTask {
    let cfg = &rt.manifest.config;
    // HalfMajority converges inside the small experiment budget (the
    // parity rule needs far more steps at d=128 — data/mod.rs docs)
    SyntheticTask::generate_rule(
        n, cfg.seq_len, cfg.vocab, 0.02, seed, crate::data::Rule::HalfMajority)
}


/// Real training can diverge to NaN/inf losses; a report cell must then
/// be Missing, not a panic in Report::push's finiteness check.
fn float_cell(v: f64) -> Cell {
    if v.is_finite() {
        Cell::Float(v)
    } else {
        Cell::Missing
    }
}

// ---------------------------------------------------------------------------
// Generic baseline training loops over the step artifacts
// ---------------------------------------------------------------------------

/// Run a `*_step` artifact in a loop: `inputs = fixed ++ trainable ++
/// [tokens, labels, lr]`, `outputs = new trainable ++ [loss]`.
/// Returns (per-step losses, final trainable params).
fn run_step_loop(
    rt: &Arc<Runtime>,
    artifact: &str,
    fixed: &[Tensor],
    mut trainable: Vec<Tensor>,
    task: &SyntheticTask,
    epochs: usize,
    lr: f32,
) -> Result<(Vec<f32>, Vec<Tensor>)> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    if batches.is_empty() {
        bail!("dataset too small");
    }
    rt.executable(artifact)?;
    let mut losses = Vec::new();
    for _ in 0..epochs {
        for (toks, labs) in &batches {
            let mut inp = fixed.to_vec();
            inp.extend(trainable.iter().cloned());
            inp.push(Tensor::I32(toks.clone(), vec![cfg.batch, cfg.seq_len]));
            inp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
            inp.push(Tensor::F32(vec![lr], vec![]));
            let mut out = rt.execute(artifact, &inp)?;
            let loss = out.pop().unwrap().scalar_f32()?;
            losses.push(loss);
            trainable = out;
        }
    }
    Ok((losses, trainable))
}

/// Accuracy of `full_ft`-style models: rebuild logits via the artifact's
/// own eval (we reuse the step's loss on held-out data as proxy) — for
/// the baselines we report train-loss-threshold behavior and final
/// held-out loss (accuracy is only defined through the adapter head for
/// the PA variants, evaluated by `exec::evaluate`).
fn heldout_loss(
    rt: &Arc<Runtime>,
    artifact: &str,
    fixed: &[Tensor],
    trainable: &[Tensor],
    task: &SyntheticTask,
) -> Result<f64> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    let mut sum = 0.0;
    for (toks, labs) in &batches {
        let mut inp = fixed.to_vec();
        inp.extend(trainable.iter().cloned());
        inp.push(Tensor::I32(toks.clone(), vec![cfg.batch, cfg.seq_len]));
        inp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
        inp.push(Tensor::F32(vec![0.0], vec![])); // lr = 0: pure eval
        let out = rt.execute(artifact, &inp)?;
        sum += out.last().unwrap().scalar_f32()? as f64;
    }
    Ok(sum / batches.len() as f64)
}

// ---------------------------------------------------------------------------
// Table VI — fine-tuned quality parity
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table6Row {
    pub technique: String,
    pub final_train_loss: f64,
    pub heldout_loss: f64,
    /// accuracy where the method has an eval head (PA variants)
    pub accuracy: Option<f64>,
}

fn table6_rows(rt: &Arc<Runtime>, budget: Budget) -> Result<Vec<Table6Row>> {
    let full = dataset(rt, budget.train_samples + 64, 11);
    let (train, eval) = full.split(64.0 / (budget.train_samples + 64) as f64);
    let mut rows = Vec::new();

    // Parallel Adapters through the real PAC+ engine
    let mut opts = TrainOptions::new(std::env::temp_dir().join("pacpp_t6"));
    opts.epochs = budget.epochs;
    opts.lr = budget.lr;
    opts.workers = 2;
    opts.init_tag = "adapter_prune".into();
    let log = exec::train_data_parallel(rt, &train, &opts)?;
    let adapter = exec::take_final_adapter().expect("adapter missing");
    let (eloss, acc) = exec::evaluate(rt, &adapter, &eval, &None)?;
    rows.push(Table6Row {
        technique: "Parallel Adapters (PAC+)".into(),
        final_train_loss: log.final_loss() as f64,
        heldout_loss: eloss,
        accuracy: Some(acc),
    });

    // Baselines through their step artifacts
    let backbone = rt.load_params("backbone")?;
    let head = rt.load_params("head")?;
    let mut run_baseline = |name: &str,
                            artifact: &str,
                            fixed: Vec<Tensor>,
                            trainable: Vec<Tensor>|
     -> Result<()> {
        let (losses, final_params) = run_step_loop(
            rt, artifact, &fixed, trainable, &train, budget.epochs, budget.lr * 0.2,
        )?;
        let hl = heldout_loss(rt, artifact, &fixed, &final_params, &eval)?;
        rows.push(Table6Row {
            technique: name.into(),
            final_train_loss: *losses.last().unwrap() as f64,
            heldout_loss: hl,
            accuracy: None,
        });
        Ok(())
    };

    // Full FT: trainable = backbone + head (fixed = nothing)
    let mut full_trainable = backbone.clone();
    full_trainable.extend(head.clone());
    run_baseline("Full model", "full_ft_step", vec![], full_trainable)?;
    run_baseline("LoRA", "lora_step", backbone.clone(), rt.load_params("lora")?)?;
    run_baseline("Adapters", "houlsby_step", backbone, rt.load_params("houlsby")?)?;

    Ok(rows)
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn table6(rt: &Arc<Runtime>, budget: Budget) -> Result<Vec<Table6Row>> {
    table6_rows(rt, budget)
}

/// Table VI as a typed [`Report`].
pub fn table6_report(rt: &Arc<Runtime>, budget: Budget) -> Result<Report> {
    let mut r = Report::new(
        "table6",
        "Table VI (shape) — fine-tuned quality parity on a synthetic task",
    )
    .column("technique", ColType::Str)
    .column("train_loss", ColType::Float)
    .column("eval_loss", ColType::Float)
    .column("accuracy", ColType::Float)
    .meta("train_samples", budget.train_samples)
    .meta("epochs", budget.epochs)
    .meta("lr", budget.lr);
    for row in table6_rows(rt, budget)? {
        r.push(vec![
            Cell::Str(row.technique),
            float_cell(row.final_train_loss),
            float_cell(row.heldout_loss),
            row.accuracy.map(float_cell).unwrap_or(Cell::Missing),
        ]);
    }
    Ok(r)
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_table6(rt: &Arc<Runtime>, budget: Budget) -> Result<()> {
    print!("{}", table6_report(rt, budget)?.to_text());
    Ok(())
}

// ---------------------------------------------------------------------------
// Table VII — quantized-backbone quality
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table7Row {
    pub precision: String,
    pub final_train_loss: f64,
    pub heldout_loss: f64,
    pub accuracy: f64,
}

fn table7_rows(rt: &Arc<Runtime>, budget: Budget) -> Result<Vec<Table7Row>> {
    let full = dataset(rt, budget.train_samples + 64, 12);
    let (train, eval) = full.split(64.0 / (budget.train_samples + 64) as f64);
    let mut rows = Vec::new();
    let mut precisions = vec![("FP32", None)];
    if rt.manifest.artifacts.contains_key("qbackbone_fwd_fp16") {
        precisions.push(("FP16", Some("fp16".to_string())));
    }
    precisions.push(("INT8", Some("int8".to_string())));
    precisions.push(("INT4", Some("int4".to_string())));
    for (name, quant) in precisions {
        let mut opts = TrainOptions::new(std::env::temp_dir().join(format!("pacpp_t7_{name}")));
        opts.epochs = budget.epochs;
        opts.lr = budget.lr;
        opts.workers = 2;
        opts.quant = quant.clone();
        let log = exec::train_data_parallel(rt, &train, &opts)?;
        let adapter = exec::take_final_adapter().expect("adapter missing");
        let (eloss, acc) = exec::evaluate(rt, &adapter, &eval, &quant)?;
        rows.push(Table7Row {
            precision: name.into(),
            final_train_loss: log.final_loss() as f64,
            heldout_loss: eloss,
            accuracy: acc,
        });
    }
    Ok(rows)
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn table7(rt: &Arc<Runtime>, budget: Budget) -> Result<Vec<Table7Row>> {
    table7_rows(rt, budget)
}

/// Table VII as a typed [`Report`].
pub fn table7_report(rt: &Arc<Runtime>, budget: Budget) -> Result<Report> {
    let mut r = Report::new(
        "table7",
        "Table VII (shape) — Parallel Adapters with quantized backbone",
    )
    .column("precision", ColType::Str)
    .column("train_loss", ColType::Float)
    .column("eval_loss", ColType::Float)
    .column("accuracy", ColType::Float)
    .meta("train_samples", budget.train_samples)
    .meta("epochs", budget.epochs)
    .meta("lr", budget.lr);
    for row in table7_rows(rt, budget)? {
        r.push(vec![
            Cell::Str(row.precision),
            float_cell(row.final_train_loss),
            float_cell(row.heldout_loss),
            float_cell(row.accuracy),
        ]);
    }
    Ok(r)
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_table7(rt: &Arc<Runtime>, budget: Budget) -> Result<()> {
    print!("{}", table7_report(rt, budget)?.to_text());
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 14 — weight-initialization strategies
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig14Row {
    pub strategy: String,
    /// steps to reach the loss threshold (None = never within budget)
    pub steps_to_target: Option<usize>,
    pub final_loss: f32,
}

/// Loss threshold the Fig. 14 convergence race is measured against.
pub const FIG14_TARGET_LOSS: f32 = 0.55;

fn fig14_rows(rt: &Arc<Runtime>, budget: Budget, target_loss: f32) -> Result<Vec<Fig14Row>> {
    let train = dataset(rt, budget.train_samples, 13);
    let mut rows = Vec::new();
    for strat in ["distill", "prune", "gaussian", "zero"] {
        let tag = format!("adapter_{strat}");
        if rt.manifest.param_set(&tag).is_err() {
            continue; // artifact set built without this init
        }
        let mut opts = TrainOptions::new(std::env::temp_dir().join(format!("pacpp_f14_{strat}")));
        opts.epochs = budget.epochs;
        opts.lr = budget.lr;
        opts.workers = 2;
        opts.init_tag = tag;
        let log = exec::train_data_parallel(rt, &train, &opts)?;
        let steps_to_target = log
            .steps
            .iter()
            .position(|s| s.loss <= target_loss);
        rows.push(Fig14Row {
            strategy: strat.into(),
            steps_to_target,
            final_loss: log.final_loss(),
        });
    }
    Ok(rows)
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig14(rt: &Arc<Runtime>, budget: Budget, target_loss: f32) -> Result<Vec<Fig14Row>> {
    fig14_rows(rt, budget, target_loss)
}

/// Fig. 14 as a typed [`Report`] (uses [`FIG14_TARGET_LOSS`]).
pub fn fig14_report(rt: &Arc<Runtime>, budget: Budget) -> Result<Report> {
    let mut r = Report::new(
        "fig14",
        format!("Fig. 14 (shape) — adapter init strategies, steps to loss<={FIG14_TARGET_LOSS}"),
    )
    .column("init", ColType::Str)
    .column("steps_to_target", ColType::Int)
    .column("final_loss", ColType::Float)
    .meta("target_loss", FIG14_TARGET_LOSS)
    .meta("train_samples", budget.train_samples)
    .meta("epochs", budget.epochs);
    for row in fig14_rows(rt, budget, FIG14_TARGET_LOSS)? {
        r.push(vec![
            Cell::Str(row.strategy),
            Cell::opt(row.steps_to_target, |s| Cell::Int(s as i64)),
            float_cell(row.final_loss as f64),
        ]);
    }
    Ok(r)
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig14(rt: &Arc<Runtime>, budget: Budget) -> Result<()> {
    print!("{}", fig14_report(rt, budget)?.to_text());
    Ok(())
}
