//! Real-execution accuracy experiments (Table VI, Table VII, Fig. 14).
//!
//! These run actual training through the PJRT runtime on the `small`
//! artifact set with synthetic GLUE-like tasks (DESIGN.md §2): the goal
//! is the paper's *shape* — Parallel Adapters matching the baselines'
//! final quality, quantized backbones costing little accuracy, informed
//! initialization converging faster — on models this testbed can train.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::data::SyntheticTask;
use crate::exec::{self, TrainOptions};
use crate::runtime::{Runtime, Tensor};

/// Training budget for the accuracy experiments.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub train_samples: usize,
    pub epochs: usize,
    pub lr: f32,
}

impl Default for Budget {
    fn default() -> Self {
        Budget { train_samples: 512, epochs: 6, lr: 5e-3 }
    }
}

fn dataset(rt: &Runtime, n: usize, seed: u64) -> SyntheticTask {
    let cfg = &rt.manifest.config;
    // HalfMajority converges inside the small experiment budget (the
    // parity rule needs far more steps at d=128 — data/mod.rs docs)
    SyntheticTask::generate_rule(
        n, cfg.seq_len, cfg.vocab, 0.02, seed, crate::data::Rule::HalfMajority)
}

// ---------------------------------------------------------------------------
// Generic baseline training loops over the step artifacts
// ---------------------------------------------------------------------------

/// Run a `*_step` artifact in a loop: `inputs = fixed ++ trainable ++
/// [tokens, labels, lr]`, `outputs = new trainable ++ [loss]`.
/// Returns (per-step losses, final trainable params).
fn run_step_loop(
    rt: &Arc<Runtime>,
    artifact: &str,
    fixed: &[Tensor],
    mut trainable: Vec<Tensor>,
    task: &SyntheticTask,
    epochs: usize,
    lr: f32,
) -> Result<(Vec<f32>, Vec<Tensor>)> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    if batches.is_empty() {
        bail!("dataset too small");
    }
    rt.executable(artifact)?;
    let mut losses = Vec::new();
    for _ in 0..epochs {
        for (toks, labs) in &batches {
            let mut inp = fixed.to_vec();
            inp.extend(trainable.iter().cloned());
            inp.push(Tensor::I32(toks.clone(), vec![cfg.batch, cfg.seq_len]));
            inp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
            inp.push(Tensor::F32(vec![lr], vec![]));
            let mut out = rt.execute(artifact, &inp)?;
            let loss = out.pop().unwrap().scalar_f32()?;
            losses.push(loss);
            trainable = out;
        }
    }
    Ok((losses, trainable))
}

/// Accuracy of `full_ft`-style models: rebuild logits via the artifact's
/// own eval (we reuse the step's loss on held-out data as proxy) — for
/// the baselines we report train-loss-threshold behavior and final
/// held-out loss (accuracy is only defined through the adapter head for
/// the PA variants, evaluated by `exec::evaluate`).
fn heldout_loss(
    rt: &Arc<Runtime>,
    artifact: &str,
    fixed: &[Tensor],
    trainable: &[Tensor],
    task: &SyntheticTask,
) -> Result<f64> {
    let cfg = rt.manifest.config.clone();
    let batches = task.batches(cfg.batch);
    let mut sum = 0.0;
    for (toks, labs) in &batches {
        let mut inp = fixed.to_vec();
        inp.extend(trainable.iter().cloned());
        inp.push(Tensor::I32(toks.clone(), vec![cfg.batch, cfg.seq_len]));
        inp.push(Tensor::I32(labs.clone(), vec![cfg.batch]));
        inp.push(Tensor::F32(vec![0.0], vec![])); // lr = 0: pure eval
        let out = rt.execute(artifact, &inp)?;
        sum += out.last().unwrap().scalar_f32()? as f64;
    }
    Ok(sum / batches.len() as f64)
}

// ---------------------------------------------------------------------------
// Table VI — fine-tuned quality parity
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table6Row {
    pub technique: String,
    pub final_train_loss: f64,
    pub heldout_loss: f64,
    /// accuracy where the method has an eval head (PA variants)
    pub accuracy: Option<f64>,
}

pub fn table6(rt: &Arc<Runtime>, budget: Budget) -> Result<Vec<Table6Row>> {
    let full = dataset(rt, budget.train_samples + 64, 11);
    let (train, eval) = full.split(64.0 / (budget.train_samples + 64) as f64);
    let mut rows = Vec::new();

    // Parallel Adapters through the real PAC+ engine
    let mut opts = TrainOptions::new(std::env::temp_dir().join("pacpp_t6"));
    opts.epochs = budget.epochs;
    opts.lr = budget.lr;
    opts.workers = 2;
    opts.init_tag = "adapter_prune".into();
    let log = exec::train_data_parallel(rt, &train, &opts)?;
    let adapter = exec::take_final_adapter().expect("adapter missing");
    let (eloss, acc) = exec::evaluate(rt, &adapter, &eval, &None)?;
    rows.push(Table6Row {
        technique: "Parallel Adapters (PAC+)".into(),
        final_train_loss: log.final_loss() as f64,
        heldout_loss: eloss,
        accuracy: Some(acc),
    });

    // Baselines through their step artifacts
    let backbone = rt.load_params("backbone")?;
    let head = rt.load_params("head")?;
    let mut run_baseline = |name: &str,
                            artifact: &str,
                            fixed: Vec<Tensor>,
                            trainable: Vec<Tensor>|
     -> Result<()> {
        let (losses, final_params) = run_step_loop(
            rt, artifact, &fixed, trainable, &train, budget.epochs, budget.lr * 0.2,
        )?;
        let hl = heldout_loss(rt, artifact, &fixed, &final_params, &eval)?;
        rows.push(Table6Row {
            technique: name.into(),
            final_train_loss: *losses.last().unwrap() as f64,
            heldout_loss: hl,
            accuracy: None,
        });
        Ok(())
    };

    // Full FT: trainable = backbone + head (fixed = nothing)
    let mut full_trainable = backbone.clone();
    full_trainable.extend(head.clone());
    run_baseline("Full model", "full_ft_step", vec![], full_trainable)?;
    run_baseline("LoRA", "lora_step", backbone.clone(), rt.load_params("lora")?)?;
    run_baseline("Adapters", "houlsby_step", backbone, rt.load_params("houlsby")?)?;

    Ok(rows)
}

pub fn print_table6(rt: &Arc<Runtime>, budget: Budget) -> Result<()> {
    println!("Table VI (shape) — fine-tuned quality parity on a synthetic task");
    println!(
        "{:<26} {:>12} {:>12} {:>10}",
        "technique", "train loss", "eval loss", "accuracy"
    );
    for r in table6(rt, budget)? {
        println!(
            "{:<26} {:>12.4} {:>12.4} {:>10}",
            r.technique,
            r.final_train_loss,
            r.heldout_loss,
            r.accuracy.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or("-".into())
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table VII — quantized-backbone quality
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table7Row {
    pub precision: String,
    pub final_train_loss: f64,
    pub heldout_loss: f64,
    pub accuracy: f64,
}

pub fn table7(rt: &Arc<Runtime>, budget: Budget) -> Result<Vec<Table7Row>> {
    let full = dataset(rt, budget.train_samples + 64, 12);
    let (train, eval) = full.split(64.0 / (budget.train_samples + 64) as f64);
    let mut rows = Vec::new();
    let mut precisions = vec![("FP32", None)];
    if rt.manifest.artifacts.contains_key("qbackbone_fwd_fp16") {
        precisions.push(("FP16", Some("fp16".to_string())));
    }
    precisions.push(("INT8", Some("int8".to_string())));
    precisions.push(("INT4", Some("int4".to_string())));
    for (name, quant) in precisions {
        let mut opts = TrainOptions::new(std::env::temp_dir().join(format!("pacpp_t7_{name}")));
        opts.epochs = budget.epochs;
        opts.lr = budget.lr;
        opts.workers = 2;
        opts.quant = quant.clone();
        let log = exec::train_data_parallel(rt, &train, &opts)?;
        let adapter = exec::take_final_adapter().expect("adapter missing");
        let (eloss, acc) = exec::evaluate(rt, &adapter, &eval, &quant)?;
        rows.push(Table7Row {
            precision: name.into(),
            final_train_loss: log.final_loss() as f64,
            heldout_loss: eloss,
            accuracy: acc,
        });
    }
    Ok(rows)
}

pub fn print_table7(rt: &Arc<Runtime>, budget: Budget) -> Result<()> {
    println!("Table VII (shape) — Parallel Adapters with quantized backbone");
    println!(
        "{:<8} {:>12} {:>12} {:>10}",
        "prec", "train loss", "eval loss", "accuracy"
    );
    for r in table7(rt, budget)? {
        println!(
            "{:<8} {:>12.4} {:>12.4} {:>9.1}%",
            r.precision, r.final_train_loss, r.heldout_loss, r.accuracy * 100.0
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 14 — weight-initialization strategies
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig14Row {
    pub strategy: String,
    /// steps to reach the loss threshold (None = never within budget)
    pub steps_to_target: Option<usize>,
    pub final_loss: f32,
}

pub fn fig14(rt: &Arc<Runtime>, budget: Budget, target_loss: f32) -> Result<Vec<Fig14Row>> {
    let train = dataset(rt, budget.train_samples, 13);
    let mut rows = Vec::new();
    for strat in ["distill", "prune", "gaussian", "zero"] {
        let tag = format!("adapter_{strat}");
        if rt.manifest.param_set(&tag).is_err() {
            continue; // artifact set built without this init
        }
        let mut opts = TrainOptions::new(std::env::temp_dir().join(format!("pacpp_f14_{strat}")));
        opts.epochs = budget.epochs;
        opts.lr = budget.lr;
        opts.workers = 2;
        opts.init_tag = tag;
        let log = exec::train_data_parallel(rt, &train, &opts)?;
        let steps_to_target = log
            .steps
            .iter()
            .position(|s| s.loss <= target_loss);
        rows.push(Fig14Row {
            strategy: strat.into(),
            steps_to_target,
            final_loss: log.final_loss(),
        });
    }
    Ok(rows)
}

pub fn print_fig14(rt: &Arc<Runtime>, budget: Budget) -> Result<()> {
    println!("Fig. 14 (shape) — adapter init strategies, steps to loss<=0.55");
    println!("{:<10} {:>16} {:>12}", "init", "steps to target", "final loss");
    for r in fig14(rt, budget, 0.55)? {
        println!(
            "{:<10} {:>16} {:>12.4}",
            r.strategy,
            r.steps_to_target.map(|s| s.to_string()).unwrap_or(">budget".into()),
            r.final_loss
        );
    }
    Ok(())
}
