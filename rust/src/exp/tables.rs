//! Simulator-backed experiment harnesses (timing/memory tables & figures).
//!
//! Each experiment is a private `*_rows()` computation kernel plus a
//! public `*_report()` that types the rows into a [`Report`] — the form
//! the [`ExperimentRegistry`](super::registry::ExperimentRegistry)
//! serves. The legacy typed-row functions (`table5()`, ...) and the
//! `print_*` functions remain as thin **deprecated** wrappers for one
//! release; the golden tests (`tests/exp_golden.rs`) pin the typed-row
//! values and the Report cells to be identical. The `print_*` wrappers
//! now emit the Report's uniform text layout — same values, not the
//! byte-identical legacy formatting (missing cells print `-` rather
//! than `OOM`, ratios print as raw fractions).
//!
//! Systems are resolved through the strategy layer (`run_system` is a
//! thin adapter over the registry), and the multi-system comparisons
//! (Table V, Fig. 12, Fig. 16) evaluate their cells on worker threads
//! via [`crate::util::par_map`] — every cell is an independent
//! plan+simulate, so the tables regenerate at core-count speed.

use super::report::{Cell, ColType, Report};
use crate::baselines::{run_system, System, TrainJob};
use crate::cluster::Env;
use crate::data::Task;
use crate::model::graph::LayerGraph;
use crate::model::{cost, Method, ModelSpec, Precision, Workload};
use crate::planner::{plan, PlanError, PlannerOptions};
use crate::profiler::Profile;

/// Sequence length used by the timing tables — the paper's stated 128.
/// (Absolute hours come out ~2–3× the paper's Table V, whose timings
/// imply shorter effective sequences; the ratios and OOM pattern are the
/// reproduction target — see EXPERIMENTS.md.)
pub const TABLE_SEQ: usize = 128;

/// Shared FP32 profile constructor — the sweep (`exp::registry`) and
/// every table/figure here must build profiles the same way.
pub(super) fn profile(spec: &ModelSpec, method: Method, seq: usize) -> Profile {
    Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, seq)
}

// ---------------------------------------------------------------------------
// Fig. 3 — FLOPs of fine-tuning techniques
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub model: String,
    pub technique: String,
    /// TFLOPs per mini-batch (16 × 128 tokens).
    pub tflops: f64,
    /// forward share of the total
    pub fwd_share: f64,
}

fn fig3_rows() -> Vec<Fig3Row> {
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        let fwd = cost::flops_inference_per_token(&spec, wl.seq) * wl.tokens() as f64;
        let entries: Vec<(&str, f64)> = vec![
            ("Full", cost::flops_train_per_token(&spec, Method::FullFT, wl.seq)),
            ("Adapters", cost::flops_train_per_token(&spec, Method::adapters_default(), wl.seq)),
            ("LoRA", cost::flops_train_per_token(&spec, Method::lora_default(), wl.seq)),
            ("P.A. (ours)", cost::flops_train_per_token(&spec, Method::pa(false), wl.seq)),
            ("P.A.+cache", cost::flops_train_cached_per_token(&spec, Method::pa(true), wl.seq)),
            ("Inference", cost::flops_inference_per_token(&spec, wl.seq)),
        ];
        for (name, per_token) in entries {
            let total = per_token * wl.tokens() as f64;
            rows.push(Fig3Row {
                model: spec.name.clone(),
                technique: name.into(),
                tflops: total / 1e12,
                fwd_share: (fwd / total).min(1.0),
            });
        }
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig3() -> Vec<Fig3Row> {
    fig3_rows()
}

/// Fig. 3 as a typed [`Report`].
pub fn fig3_report() -> Report {
    let mut r = Report::new("fig3", "Fig. 3 — FLOPs per mini-batch (B=16, S=128)")
        .column("model", ColType::Str)
        .column("technique", ColType::Str)
        .column("tflops", ColType::Float)
        .column("fwd_share", ColType::Float)
        .meta("seq", 128)
        .meta("minibatch", 16);
    for row in fig3_rows() {
        r.push(vec![
            Cell::Str(row.model),
            Cell::Str(row.technique),
            Cell::Float(row.tflops),
            Cell::Float(row.fwd_share),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig3() {
    print!("{}", fig3_report().to_text());
}

// ---------------------------------------------------------------------------
// Table I — memory breakdown
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub technique: String,
    pub trainable_m: f64,
    pub weights_gb: f64,
    pub activations_gb: f64,
    pub gradients_gb: f64,
    pub total_gb: f64,
}

fn table1_rows() -> Vec<Table1Row> {
    let spec = ModelSpec::t5_large();
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for (name, method) in [
        ("Full", Method::FullFT),
        ("Adapters", Method::adapters_default()),
        ("LoRA", Method::lora_default()),
        ("P.A. (ours)", Method::pa(false)),
        ("P.A.+cache", Method::pa(true)),
    ] {
        let m = cost::memory(&spec, method, Precision::FP32, wl);
        rows.push(Table1Row {
            technique: name.into(),
            trainable_m: method.trainable_params(&spec) as f64 / 1e6,
            weights_gb: cost::gb(m.weights),
            activations_gb: cost::gb(m.activations),
            gradients_gb: cost::gb(m.gradients),
            total_gb: cost::gb(m.total()),
        });
    }
    rows.push(Table1Row {
        technique: "Inference".into(),
        trainable_m: 0.0,
        weights_gb: cost::gb(cost::memory_inference(&spec, Precision::FP32)),
        activations_gb: 0.0,
        gradients_gb: 0.0,
        total_gb: cost::gb(cost::memory_inference(&spec, Precision::FP32)),
    });
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn table1() -> Vec<Table1Row> {
    table1_rows()
}

/// Table I as a typed [`Report`].
pub fn table1_report() -> Report {
    let mut r = Report::new("table1", "Table I — memory breakdown, T5-Large, B=16, S=128 (GB)")
        .column("technique", ColType::Str)
        .column("trainable_m", ColType::Float)
        .column("weights_gb", ColType::Float)
        .column("activations_gb", ColType::Float)
        .column("gradients_gb", ColType::Float)
        .column("total_gb", ColType::Float)
        .meta("model", "T5-Large")
        .meta("seq", 128)
        .meta("minibatch", 16);
    for row in table1_rows() {
        r.push(vec![
            Cell::Str(row.technique),
            Cell::Float(row.trainable_m),
            Cell::Float(row.weights_gb),
            Cell::Float(row.activations_gb),
            Cell::Float(row.gradients_gb),
            Cell::Float(row.total_gb),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_table1() {
    print!("{}", table1_report().to_text());
}

// ---------------------------------------------------------------------------
// Table V — end-to-end fine-tuning durations, Env.A
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table5Row {
    pub model: String,
    pub technique: String,
    pub system: String,
    /// hours per task, or None = OOM (Table V's "OOM" cells).
    pub hours: Vec<Option<f64>>,
}

fn table5_rows() -> Vec<Table5Row> {
    let env = Env::env_a();
    let tasks = Task::all();
    // flatten every (model, technique, system) row, then evaluate the
    // rows on worker threads — each cell is an independent plan+simulate
    let mut combos: Vec<(ModelSpec, &str, Method, System)> = Vec::new();
    for spec in ModelSpec::paper_models() {
        let entries: Vec<(&str, Method, System)> = vec![
            ("Full", Method::FullFT, System::Standalone),
            ("Full", Method::FullFT, System::PipelineParallel),
            ("Full", Method::FullFT, System::DataParallel),
            ("Adapters", Method::adapters_default(), System::Standalone),
            ("Adapters", Method::adapters_default(), System::PipelineParallel),
            ("Adapters", Method::adapters_default(), System::DataParallel),
            ("LoRA", Method::lora_default(), System::Standalone),
            ("LoRA", Method::lora_default(), System::PipelineParallel),
            ("LoRA", Method::lora_default(), System::DataParallel),
            ("ParallelAdapters", Method::pa(true), System::PacPlus),
        ];
        for (tech, method, system) in entries {
            combos.push((spec.clone(), tech, method, system));
        }
    }
    crate::util::par_map(combos.len(), |i| {
        let (spec, tech, method, system) = &combos[i];
        let prof = profile(spec, *method, TABLE_SEQ);
        let hours: Vec<Option<f64>> = tasks
            .iter()
            .map(|t| {
                let job = TrainJob::new(t.train_samples(), t.epochs(), TABLE_SEQ, 16);
                match run_system(*system, &prof, &env, job) {
                    Ok(r) => Some(r.total / 3600.0),
                    Err(PlanError::InsufficientMemory) => None,
                    Err(_) => None,
                }
            })
            .collect();
        Table5Row {
            model: spec.name.clone(),
            technique: (*tech).into(),
            system: system.name().into(),
            hours,
        }
    })
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn table5() -> Vec<Table5Row> {
    table5_rows()
}

/// Table V as a typed [`Report`] — one `Float` hours column per GLUE
/// task, `Missing` for the paper's OOM cells.
pub fn table5_report() -> Report {
    let mut r = Report::new("table5", "Table V — fine-tuning durations in hours, Env.A (4x Nano-H)")
        .column("model", ColType::Str)
        .column("technique", ColType::Str)
        .column("system", ColType::Str)
        .meta("env", "Env.A")
        .meta("seq", TABLE_SEQ)
        .meta("minibatch", 16)
        .meta("epochs", "3 for MRPC/STS-B, 1 for SST-2/QNLI");
    for task in Task::all() {
        r = r.column(task.name(), ColType::Float);
    }
    for row in table5_rows() {
        let mut cells = vec![
            Cell::Str(row.model),
            Cell::Str(row.technique),
            Cell::Str(row.system),
        ];
        cells.extend(row.hours.into_iter().map(|h| Cell::opt(h, Cell::Float)));
        r.push(cells);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_table5() {
    print!("{}", table5_report().to_text());
}

// ---------------------------------------------------------------------------
// Fig. 12 — existing systems under heterogeneity (Env.B)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig12Row {
    pub model: String,
    pub system: String,
    pub epochs: usize,
    pub hours: Option<f64>,
}

fn fig12_rows() -> Vec<Fig12Row> {
    let env = Env::env_b();
    let mut combos: Vec<(ModelSpec, usize, System, Method)> = Vec::new();
    for spec in ModelSpec::paper_models() {
        for epochs in [1usize, 3] {
            for (system, method) in [
                (System::HetPipe, Method::FullFT),
                (System::Asteroid, Method::FullFT),
                (System::PacHomo, Method::pa(true)),
                (System::PacPlus, Method::pa(true)),
            ] {
                combos.push((spec.clone(), epochs, system, method));
            }
        }
    }
    crate::util::par_map(combos.len(), |i| {
        let (spec, epochs, system, method) = &combos[i];
        let prof = profile(spec, *method, TABLE_SEQ);
        let job = TrainJob::new(Task::Mrpc.train_samples(), *epochs, TABLE_SEQ, 16);
        let hours = run_system(*system, &prof, &env, job)
            .ok()
            .map(|r| r.total / 3600.0);
        Fig12Row {
            model: spec.name.clone(),
            system: system.name().into(),
            epochs: *epochs,
            hours,
        }
    })
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig12() -> Vec<Fig12Row> {
    fig12_rows()
}

/// Fig. 12 as a typed [`Report`], with the derived `vs_pacplus`
/// [`ColType::Speedup`] column (PAC+ rows read `1.00x`).
pub fn fig12_report() -> Report {
    let rows = fig12_rows();
    let mut r = Report::new(
        "fig12",
        "Fig. 12 — total fine-tuning time on MRPC, Env.B (heterogeneous)",
    )
    .column("model", ColType::Str)
    .column("system", ColType::Str)
    .column("epochs", ColType::Int)
    .column("hours", ColType::Float)
    .column("vs_pacplus", ColType::Speedup)
    .meta("env", "Env.B")
    .meta("task", "MRPC")
    .meta("seq", TABLE_SEQ)
    .meta("minibatch", 16);
    for row in &rows {
        let pac = rows
            .iter()
            .find(|p| p.model == row.model && p.epochs == row.epochs && p.system == "PAC+")
            .and_then(|p| p.hours);
        let speedup = match (row.hours, pac) {
            (Some(h), Some(p)) if p > 0.0 => Cell::Speedup(h / p),
            _ => Cell::Missing,
        };
        r.push(vec![
            Cell::Str(row.model.clone()),
            Cell::Str(row.system.clone()),
            Cell::Int(row.epochs as i64),
            Cell::opt(row.hours, Cell::Float),
            speedup,
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig12() {
    print!("{}", fig12_report().to_text());
}

// ---------------------------------------------------------------------------
// Fig. 13 — per-sample time & memory breakdown (8 × Nano-H)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub technique: String,
    /// average per-sample training time (s) on the 8-Nano cluster
    pub sample_time: Option<f64>,
    /// peak per-device memory breakdown (bytes)
    pub weights: u64,
    pub activations: u64,
    pub gradients: u64,
}

fn fig13_rows() -> Vec<Fig13Row> {
    let env = Env::nanos(8);
    let spec = ModelSpec::t5_large();
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for (name, method) in [
        ("Full", Method::FullFT),
        ("Adapters", Method::adapters_default()),
        ("LoRA", Method::lora_default()),
        ("P.A.", Method::pa(false)),
        ("P.A.+cache", Method::pa(true)),
    ] {
        let prof = profile(&spec, method, wl.seq);
        let opts = PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() };
        let sample_time = plan(&prof, &env, &opts).ok().map(|p| {
            if method.skips_backbone_with_cache() {
                crate::sched::training::epoch_time_cached(&prof, &env, 16, 16) / 16.0
            } else {
                crate::sched::simulate_minibatch(&p, &prof, &env.network).minibatch_time
                    / p.minibatch_samples() as f64
            }
        });
        // single-device-equivalent memory breakdown (paper reports the
        // per-device peak across the cluster; we report the cost-model
        // breakdown scaled to the planned per-device share)
        let m = cost::memory(&spec, method, Precision::FP32, wl);
        let stages = plan(
            &prof,
            &env,
            &PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() },
        )
        .map(|p| p.n_stages() as u64)
        .unwrap_or(1);
        rows.push(Fig13Row {
            technique: name.into(),
            sample_time,
            weights: m.weights / stages,
            activations: m.activations / stages,
            gradients: m.gradients / stages,
        });
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig13() -> Vec<Fig13Row> {
    fig13_rows()
}

/// Fig. 13 as a typed [`Report`].
pub fn fig13_report() -> Report {
    let mut r = Report::new(
        "fig13",
        "Fig. 13 — per-sample time & per-device memory (8x Nano-H, T5-Large)",
    )
    .column("technique", ColType::Str)
    .column("sample_time", ColType::Secs)
    .column("weights", ColType::Bytes)
    .column("activations", ColType::Bytes)
    .column("gradients", ColType::Bytes)
    .meta("env", "8xNano-H")
    .meta("model", "T5-Large");
    for row in fig13_rows() {
        r.push(vec![
            Cell::Str(row.technique),
            Cell::opt(row.sample_time, Cell::Secs),
            Cell::Bytes(row.weights),
            Cell::Bytes(row.activations),
            Cell::Bytes(row.gradients),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig13() {
    print!("{}", fig13_report().to_text());
}

// ---------------------------------------------------------------------------
// Fig. 15 — memory vs model size under quantization
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig15Row {
    pub params_m: f64,
    pub technique: String,
    pub total_gb: f64,
}

fn fig15_rows() -> Vec<Fig15Row> {
    // a family of T5-style models of growing size (paper: varies hidden
    // size / layers / heads)
    let family: Vec<ModelSpec> = vec![
        ModelSpec { name: "t5-60m".into(), enc_layers: 6, dec_layers: 6, d_model: 512, n_heads: 8, d_ff: 2048, vocab: 32128, reduction: 8 },
        ModelSpec::t5_base(),
        ModelSpec::bart_large(),
        ModelSpec::t5_large(),
        ModelSpec { name: "t5-1b".into(), enc_layers: 24, dec_layers: 24, d_model: 1280, n_heads: 20, d_ff: 5120, vocab: 32128, reduction: 8 },
    ];
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for spec in &family {
        let mut push = |tech: &str, method: Method, prec: Precision| {
            let m = cost::memory(spec, method, prec, wl);
            rows.push(Fig15Row {
                params_m: spec.params_total() as f64 / 1e6,
                technique: tech.into(),
                total_gb: cost::gb(m.total()),
            });
        };
        push("Full FP32", Method::FullFT, Precision::FP32);
        push("LoRA FP32", Method::lora_default(), Precision::FP32);
        push("Adapters FP32", Method::adapters_default(), Precision::FP32);
        push("P.A. FP32", Method::pa(false), Precision::FP32);
        push("P.A. INT8", Method::pa(false), Precision::INT8);
        push("P.A. INT4", Method::pa(false), Precision::INT4);
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig15() -> Vec<Fig15Row> {
    fig15_rows()
}

/// Fig. 15 as a typed [`Report`].
pub fn fig15_report() -> Report {
    let mut r = Report::new("fig15", "Fig. 15 — fine-tuning memory vs model size (GB)")
        .column("params_m", ColType::Float)
        .column("technique", ColType::Str)
        .column("total_gb", ColType::Float)
        .meta("seq", 128)
        .meta("minibatch", 16);
    for row in fig15_rows() {
        r.push(vec![
            Cell::Float(row.params_m),
            Cell::Str(row.technique),
            Cell::Float(row.total_gb),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig15() {
    print!("{}", fig15_report().to_text());
}

// ---------------------------------------------------------------------------
// Fig. 16 — scalability of DP / PP / PAC+ over 2–8 Nanos
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig16Row {
    pub model: String,
    pub n_devices: usize,
    pub system: String,
    /// samples/s, None = OOM
    pub throughput: Option<f64>,
    /// peak per-device weight bytes
    pub weight_mem: Option<u64>,
}

fn fig16_rows() -> Vec<Fig16Row> {
    let mut combos: Vec<(ModelSpec, usize, System)> = Vec::new();
    for spec in ModelSpec::paper_models() {
        for n in 2..=8usize {
            for system in [System::DataParallel, System::PipelineParallel, System::PacPlus] {
                combos.push((spec.clone(), n, system));
            }
        }
    }
    crate::util::par_map(combos.len(), |i| {
        let (spec, n, system) = &combos[i];
        let env = Env::nanos(*n);
        // batch size = number of devices (paper §VI-G), seq 128
        let minibatch = *n;
        let prof = profile(spec, Method::pa(false), 128);
        let job = TrainJob::new(1000, 1, 128, minibatch);
        let r = run_system(*system, &prof, &env, job).ok();
        let throughput = r.as_ref().map(|r| 1000.0 / r.epoch1);
        let weight_mem = r.as_ref().map(|r| {
            r.plan
                .stages
                .iter()
                .map(|s| {
                    prof.graph.span_weight_bytes(s.range.0, s.range.1, Precision::FP32)
                })
                .max()
                .unwrap_or(0)
        });
        Fig16Row {
            model: spec.name.clone(),
            n_devices: *n,
            system: system.name().into(),
            throughput,
            weight_mem,
        }
    })
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig16() -> Vec<Fig16Row> {
    fig16_rows()
}

/// Fig. 16 as a typed [`Report`].
pub fn fig16_report() -> Report {
    let mut r = Report::new(
        "fig16",
        "Fig. 16 — throughput & weight memory, 2-8 Nano-H, Parallel Adapters",
    )
    .column("model", ColType::Str)
    .column("n_devices", ColType::Int)
    .column("system", ColType::Str)
    .column("throughput", ColType::Float)
    .column("weight_mem", ColType::Bytes)
    .meta("envs", "2-8 x Nano-H")
    .meta("seq", 128);
    for row in fig16_rows() {
        r.push(vec![
            Cell::Str(row.model),
            Cell::Int(row.n_devices as i64),
            Cell::Str(row.system),
            Cell::opt(row.throughput, Cell::Float),
            Cell::opt(row.weight_mem, Cell::Bytes),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig16() {
    print!("{}", fig16_report().to_text());
}

// ---------------------------------------------------------------------------
// Fig. 17 — planner grouping configurations
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig17Row {
    pub model: String,
    pub n_devices: usize,
    pub grouping: String,
    pub stages: usize,
}

fn fig17_rows() -> Vec<Fig17Row> {
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        for n in 2..=8usize {
            let env = Env::nanos(n);
            let prof = profile(&spec, Method::pa(false), 128);
            let opts = PlannerOptions {
                microbatch: n.max(4) / 2,
                n_microbatches: 4,
                ..Default::default()
            };
            if let Ok(p) = plan(&prof, &env, &opts) {
                rows.push(Fig17Row {
                    model: spec.name.clone(),
                    n_devices: n,
                    grouping: p.grouping(),
                    stages: p.n_stages(),
                });
            }
        }
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig17() -> Vec<Fig17Row> {
    fig17_rows()
}

/// Fig. 17 as a typed [`Report`].
pub fn fig17_report() -> Report {
    let mut r = Report::new("fig17", "Fig. 17 — PAC+ device groupings (hybrid parallelism)")
        .column("model", ColType::Str)
        .column("n_devices", ColType::Int)
        .column("stages", ColType::Int)
        .column("grouping", ColType::Str)
        .meta("envs", "2-8 x Nano-H");
    for row in fig17_rows() {
        r.push(vec![
            Cell::Str(row.model),
            Cell::Int(row.n_devices as i64),
            Cell::Int(row.stages as i64),
            Cell::Str(row.grouping),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig17() {
    print!("{}", fig17_report().to_text());
}

// ---------------------------------------------------------------------------
// Fig. 18 — activation-cache benefit vs epoch count
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig18Row {
    pub model: String,
    pub epochs: usize,
    pub hours_no_cache: f64,
    pub hours_cache: f64,
    pub reduction: f64,
}

fn fig18_rows() -> Vec<Fig18Row> {
    let env = Env::env_a();
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        for epochs in [1usize, 2, 3, 5, 10] {
            let job = TrainJob::new(Task::Mrpc.train_samples(), epochs, TABLE_SEQ, 16);
            let no_cache = run_system(
                System::PacPlus,
                &profile(&spec, Method::pa(false), TABLE_SEQ),
                &env,
                job,
            );
            let cache = run_system(
                System::PacPlus,
                &profile(&spec, Method::pa(true), TABLE_SEQ),
                &env,
                job,
            );
            if let (Ok(n), Ok(c)) = (no_cache, cache) {
                rows.push(Fig18Row {
                    model: spec.name.clone(),
                    epochs,
                    hours_no_cache: n.total / 3600.0,
                    hours_cache: c.total / 3600.0,
                    reduction: 1.0 - c.total / n.total,
                });
            }
        }
    }
    rows
}

#[deprecated(note = "typed-row surface kept for one release: resolve the experiment \
                     by name through exp::ExperimentRegistry and consume the Report")]
pub fn fig18() -> Vec<Fig18Row> {
    fig18_rows()
}

/// Fig. 18 as a typed [`Report`].
pub fn fig18_report() -> Report {
    let mut r = Report::new(
        "fig18",
        "Fig. 18 — fine-tuning time with/without activation cache (MRPC, Env.A)",
    )
    .column("model", ColType::Str)
    .column("epochs", ColType::Int)
    .column("hours_no_cache", ColType::Float)
    .column("hours_cache", ColType::Float)
    .column("reduction", ColType::Float)
    .meta("env", "Env.A")
    .meta("task", "MRPC");
    for row in fig18_rows() {
        r.push(vec![
            Cell::Str(row.model),
            Cell::Int(row.epochs as i64),
            Cell::Float(row.hours_no_cache),
            Cell::Float(row.hours_cache),
            Cell::Float(row.reduction),
        ]);
    }
    r
}

#[deprecated(note = "print surface kept for one release: render the registry Report \
                     instead (`pacpp exp run <name>`)")]
pub fn print_fig18() {
    print!("{}", fig18_report().to_text());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_rows_complete() {
        let rows = fig3_rows();
        assert_eq!(rows.len(), 3 * 6);
        // inference < PA < LoRA < Full for every model
        for spec in ModelSpec::paper_models() {
            let get = |t: &str| {
                rows.iter()
                    .find(|r| r.model == spec.name && r.technique == t)
                    .unwrap()
                    .tflops
            };
            assert!(get("Inference") < get("P.A. (ours)"));
            assert!(get("P.A. (ours)") < get("LoRA"));
            assert!(get("LoRA") < get("Full"));
            assert!(get("P.A.+cache") < get("Inference"));
        }
    }

    #[test]
    fn table1_totals() {
        let rows = table1_rows();
        let full = rows.iter().find(|r| r.technique == "Full").unwrap();
        assert!((full.total_gb - 10.83).abs() < 1.1);
        let pa_cache = rows.iter().find(|r| r.technique == "P.A.+cache").unwrap();
        assert!(pa_cache.total_gb < 0.3 * full.total_gb);
    }

    #[test]
    fn table5_oom_pattern() {
        let rows = table5_rows();
        let find = |model: &str, tech: &str, sys_prefix: &str| {
            rows.iter()
                .find(|r| r.model == model && r.technique == tech && r.system.starts_with(sys_prefix))
                .unwrap()
        };
        // T5-Large full: OOM everywhere (Table V bottom-left block)
        for sys in ["Standalone", "PP", "DP"] {
            assert!(
                find("T5-Large", "Full", sys).hours.iter().all(Option::is_none),
                "T5-Large Full {sys} should OOM"
            );
        }
        // PAC+ never OOMs and is the fastest entry per model/task
        for spec in ModelSpec::paper_models() {
            let pac = find(&spec.name, "ParallelAdapters", "PAC+");
            for (i, h) in pac.hours.iter().enumerate() {
                let pac_h = h.expect("PAC+ OOM");
                for r in rows.iter().filter(|r| r.model == spec.name && r.system != "PAC+") {
                    if let Some(other) = r.hours[i] {
                        assert!(
                            pac_h < other,
                            "{} {} {} task{} beat PAC+",
                            r.model,
                            r.technique,
                            r.system,
                            i
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fig12_speedup_band() {
        let rows = fig12_rows();
        // PAC+ vs HetPipe speedups: paper reports 3.2-9.7x (1 ep) and
        // 7.6-14.7x (3 ep); assert the shape (>2x, growing with epochs)
        for spec in ModelSpec::paper_models() {
            for epochs in [1usize, 3] {
                let get = |sys: &str| {
                    rows.iter()
                        .find(|r| r.model == spec.name && r.epochs == epochs && r.system == sys)
                        .and_then(|r| r.hours)
                };
                if let (Some(pac), Some(het)) = (get("PAC+"), get("HetPipe")) {
                    let speedup = het / pac;
                    assert!(speedup > 2.0, "{}: speedup {speedup}", spec.name);
                }
                if let (Some(pac), Some(ast)) = (get("PAC+"), get("Asteroid")) {
                    assert!(ast / pac > 1.5, "{}: vs asteroid {}", spec.name, ast / pac);
                }
            }
        }
    }

    #[test]
    fn fig12_report_speedup_column_matches_hours() {
        let rep = fig12_report();
        for i in 0..rep.n_rows() {
            let (hours, speedup) =
                (rep.cell(i, "hours").unwrap(), rep.cell(i, "vs_pacplus").unwrap());
            if rep.cell(i, "system").and_then(Cell::as_str) == Some("PAC+") {
                if let Some(s) = speedup.as_f64() {
                    assert!((s - 1.0).abs() < 1e-12, "PAC+ speedup vs itself is 1.0");
                }
            }
            if hours.is_missing() {
                assert!(speedup.is_missing(), "row {i}: no hours => no speedup");
            }
        }
    }

    #[test]
    fn fig16_shapes() {
        let rows = fig16_rows();
        // DP OOMs for T5-Large at every n: the full replica alone exceeds
        // a Nano's budget (the paper additionally reports BART-Large DP
        // OOM; our memory model puts BART-Large PA replicas just under
        // the budget — see EXPERIMENTS.md deviations)
        assert!(rows
            .iter()
            .filter(|r| r.model == "T5-Large" && r.system == "DP (EDDL)")
            .all(|r| r.throughput.is_none()));
        // PAC+ throughput >= PP throughput for every (model, n)
        for spec in ModelSpec::paper_models() {
            for n in 2..=8usize {
                let get = |sys: &str| {
                    rows.iter()
                        .find(|r| r.model == spec.name && r.n_devices == n && r.system == sys)
                        .and_then(|r| r.throughput)
                };
                if let (Some(pac), Some(pp)) = (get("PAC+"), get("PP (Eco-FL)")) {
                    assert!(pac >= pp * 0.999, "{} n={n}: PAC+ {pac} < PP {pp}", spec.name);
                }
                // PP weight memory per device shrinks vs DP
                let wm = |sys: &str| {
                    rows.iter()
                        .find(|r| r.model == spec.name && r.n_devices == n && r.system == sys)
                        .and_then(|r| r.weight_mem)
                };
                if let (Some(pp), Some(dp)) = (wm("PP (Eco-FL)"), wm("DP (EDDL)")) {
                    assert!(pp < dp);
                }
            }
        }
    }

    #[test]
    fn fig17_groupings_scale() {
        let rows = fig17_rows();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.stages <= r.n_devices);
        }
        // larger models need more stages on the same devices
        let stages_of = |model: &str, n: usize| {
            rows.iter()
                .find(|r| r.model == model && r.n_devices == n)
                .map(|r| r.stages)
        };
        if let (Some(base), Some(large)) = (stages_of("T5-Base", 8), stages_of("T5-Large", 8)) {
            assert!(large >= base);
        }
    }

    #[test]
    fn fig18_monotone_reduction() {
        let rows = fig18_rows();
        for spec in ModelSpec::paper_models() {
            let series: Vec<&Fig18Row> =
                rows.iter().filter(|r| r.model == spec.name).collect();
            for w in series.windows(2) {
                assert!(
                    w[1].reduction >= w[0].reduction - 1e-9,
                    "{}: reduction not monotone",
                    spec.name
                );
            }
            let last = series.last().unwrap();
            assert!(last.reduction > 0.5, "{}: 10-epoch reduction {}", spec.name, last.reduction);
        }
    }
}
