//! Simulator-backed experiment harnesses (timing/memory tables & figures).
//!
//! Systems are resolved through the strategy layer (`run_system` is a
//! thin adapter over the registry), and the multi-system comparisons
//! (Table V, Fig. 12, Fig. 16) evaluate their cells on worker threads
//! via [`crate::util::par_map`] — every cell is an independent
//! plan+simulate, so the tables regenerate at core-count speed.

use crate::baselines::{run_system, System, TrainJob};
use crate::cluster::Env;
use crate::data::Task;
use crate::model::graph::LayerGraph;
use crate::model::{cost, Method, ModelSpec, Precision, Workload};
use crate::planner::{plan, PlanError, PlannerOptions};
use crate::profiler::Profile;
use crate::util::fmt_bytes;

/// Sequence length used by the timing tables — the paper's stated 128.
/// (Absolute hours come out ~2–3× the paper's Table V, whose timings
/// imply shorter effective sequences; the ratios and OOM pattern are the
/// reproduction target — see EXPERIMENTS.md.)
pub const TABLE_SEQ: usize = 128;

fn profile(spec: &ModelSpec, method: Method, seq: usize) -> Profile {
    Profile::new(LayerGraph::new(spec.clone()), method, Precision::FP32, seq)
}

// ---------------------------------------------------------------------------
// Fig. 3 — FLOPs of fine-tuning techniques
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub model: String,
    pub technique: String,
    /// TFLOPs per mini-batch (16 × 128 tokens).
    pub tflops: f64,
    /// forward share of the total
    pub fwd_share: f64,
}

pub fn fig3() -> Vec<Fig3Row> {
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        let fwd = cost::flops_inference_per_token(&spec, wl.seq) * wl.tokens() as f64;
        let entries: Vec<(&str, f64)> = vec![
            ("Full", cost::flops_train_per_token(&spec, Method::FullFT, wl.seq)),
            ("Adapters", cost::flops_train_per_token(&spec, Method::adapters_default(), wl.seq)),
            ("LoRA", cost::flops_train_per_token(&spec, Method::lora_default(), wl.seq)),
            ("P.A. (ours)", cost::flops_train_per_token(&spec, Method::pa(false), wl.seq)),
            ("P.A.+cache", cost::flops_train_cached_per_token(&spec, Method::pa(true), wl.seq)),
            ("Inference", cost::flops_inference_per_token(&spec, wl.seq)),
        ];
        for (name, per_token) in entries {
            let total = per_token * wl.tokens() as f64;
            rows.push(Fig3Row {
                model: spec.name.clone(),
                technique: name.into(),
                tflops: total / 1e12,
                fwd_share: (fwd / total).min(1.0),
            });
        }
    }
    rows
}

pub fn print_fig3() {
    println!("Fig. 3 — FLOPs per mini-batch (B=16, S=128)");
    println!("{:<12} {:<14} {:>10} {:>10}", "model", "technique", "TFLOPs", "fwd%");
    for r in fig3() {
        println!(
            "{:<12} {:<14} {:>10.2} {:>9.0}%",
            r.model, r.technique, r.tflops, r.fwd_share * 100.0
        );
    }
}

// ---------------------------------------------------------------------------
// Table I — memory breakdown
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub technique: String,
    pub trainable_m: f64,
    pub weights_gb: f64,
    pub activations_gb: f64,
    pub gradients_gb: f64,
    pub total_gb: f64,
}

pub fn table1() -> Vec<Table1Row> {
    let spec = ModelSpec::t5_large();
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for (name, method) in [
        ("Full", Method::FullFT),
        ("Adapters", Method::adapters_default()),
        ("LoRA", Method::lora_default()),
        ("P.A. (ours)", Method::pa(false)),
        ("P.A.+cache", Method::pa(true)),
    ] {
        let m = cost::memory(&spec, method, Precision::FP32, wl);
        rows.push(Table1Row {
            technique: name.into(),
            trainable_m: method.trainable_params(&spec) as f64 / 1e6,
            weights_gb: cost::gb(m.weights),
            activations_gb: cost::gb(m.activations),
            gradients_gb: cost::gb(m.gradients),
            total_gb: cost::gb(m.total()),
        });
    }
    rows.push(Table1Row {
        technique: "Inference".into(),
        trainable_m: 0.0,
        weights_gb: cost::gb(cost::memory_inference(&spec, Precision::FP32)),
        activations_gb: 0.0,
        gradients_gb: 0.0,
        total_gb: cost::gb(cost::memory_inference(&spec, Precision::FP32)),
    });
    rows
}

pub fn print_table1() {
    println!("Table I — memory breakdown, T5-Large, B=16, S=128 (GB)");
    println!(
        "{:<12} {:>10} {:>9} {:>12} {:>10} {:>8}",
        "technique", "train(M)", "weights", "activations", "gradients", "total"
    );
    for r in table1() {
        println!(
            "{:<12} {:>10.1} {:>9.2} {:>12.2} {:>10.2} {:>8.2}",
            r.technique, r.trainable_m, r.weights_gb, r.activations_gb, r.gradients_gb, r.total_gb
        );
    }
}

// ---------------------------------------------------------------------------
// Table V — end-to-end fine-tuning durations, Env.A
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Table5Row {
    pub model: String,
    pub technique: String,
    pub system: String,
    /// hours per task, or None = OOM (Table V's "OOM" cells).
    pub hours: Vec<Option<f64>>,
}

pub fn table5() -> Vec<Table5Row> {
    let env = Env::env_a();
    let tasks = Task::all();
    // flatten every (model, technique, system) row, then evaluate the
    // rows on worker threads — each cell is an independent plan+simulate
    let mut combos: Vec<(ModelSpec, &str, Method, System)> = Vec::new();
    for spec in ModelSpec::paper_models() {
        let entries: Vec<(&str, Method, System)> = vec![
            ("Full", Method::FullFT, System::Standalone),
            ("Full", Method::FullFT, System::PipelineParallel),
            ("Full", Method::FullFT, System::DataParallel),
            ("Adapters", Method::adapters_default(), System::Standalone),
            ("Adapters", Method::adapters_default(), System::PipelineParallel),
            ("Adapters", Method::adapters_default(), System::DataParallel),
            ("LoRA", Method::lora_default(), System::Standalone),
            ("LoRA", Method::lora_default(), System::PipelineParallel),
            ("LoRA", Method::lora_default(), System::DataParallel),
            ("ParallelAdapters", Method::pa(true), System::PacPlus),
        ];
        for (tech, method, system) in entries {
            combos.push((spec.clone(), tech, method, system));
        }
    }
    crate::util::par_map(combos.len(), |i| {
        let (spec, tech, method, system) = &combos[i];
        let prof = profile(spec, *method, TABLE_SEQ);
        let hours: Vec<Option<f64>> = tasks
            .iter()
            .map(|t| {
                let job = TrainJob::new(t.train_samples(), t.epochs(), TABLE_SEQ, 16);
                match run_system(*system, &prof, &env, job) {
                    Ok(r) => Some(r.total / 3600.0),
                    Err(PlanError::InsufficientMemory) => None,
                    Err(_) => None,
                }
            })
            .collect();
        Table5Row {
            model: spec.name.clone(),
            technique: (*tech).into(),
            system: system.name().into(),
            hours,
        }
    })
}

pub fn print_table5() {
    println!("Table V — fine-tuning durations in hours, Env.A (4x Nano-H)");
    println!("  (3 epochs for MRPC/STS-B, 1 epoch for SST-2/QNLI; OOM = out of memory)");
    println!(
        "{:<12} {:<18} {:<14} {:>8} {:>8} {:>8} {:>8}",
        "model", "technique", "system", "MRPC", "STS-B", "SST-2", "QNLI"
    );
    for r in table5() {
        let cells: Vec<String> = r
            .hours
            .iter()
            .map(|h| match h {
                Some(v) => format!("{v:.2}"),
                None => "OOM".into(),
            })
            .collect();
        println!(
            "{:<12} {:<18} {:<14} {:>8} {:>8} {:>8} {:>8}",
            r.model, r.technique, r.system, cells[0], cells[1], cells[2], cells[3]
        );
    }
}

// ---------------------------------------------------------------------------
// Fig. 12 — existing systems under heterogeneity (Env.B)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig12Row {
    pub model: String,
    pub system: String,
    pub epochs: usize,
    pub hours: Option<f64>,
}

pub fn fig12() -> Vec<Fig12Row> {
    let env = Env::env_b();
    let mut combos: Vec<(ModelSpec, usize, System, Method)> = Vec::new();
    for spec in ModelSpec::paper_models() {
        for epochs in [1usize, 3] {
            for (system, method) in [
                (System::HetPipe, Method::FullFT),
                (System::Asteroid, Method::FullFT),
                (System::PacHomo, Method::pa(true)),
                (System::PacPlus, Method::pa(true)),
            ] {
                combos.push((spec.clone(), epochs, system, method));
            }
        }
    }
    crate::util::par_map(combos.len(), |i| {
        let (spec, epochs, system, method) = &combos[i];
        let prof = profile(spec, *method, TABLE_SEQ);
        let job = TrainJob::new(Task::Mrpc.train_samples(), *epochs, TABLE_SEQ, 16);
        let hours = run_system(*system, &prof, &env, job)
            .ok()
            .map(|r| r.total / 3600.0);
        Fig12Row {
            model: spec.name.clone(),
            system: system.name().into(),
            epochs: *epochs,
            hours,
        }
    })
}

pub fn print_fig12() {
    println!("Fig. 12 — total fine-tuning time on MRPC, Env.B (heterogeneous)");
    println!(
        "{:<12} {:<14} {:>7} {:>10} {:>14}",
        "model", "system", "epochs", "hours", "vs PAC+ (x)"
    );
    let rows = fig12();
    for spec in ModelSpec::paper_models() {
        for epochs in [1usize, 3] {
            let pac = rows
                .iter()
                .find(|r| r.model == spec.name && r.epochs == epochs && r.system == "PAC+")
                .and_then(|r| r.hours)
                .unwrap_or(f64::NAN);
            for r in rows.iter().filter(|r| r.model == spec.name && r.epochs == epochs) {
                match r.hours {
                    Some(h) => println!(
                        "{:<12} {:<14} {:>7} {:>10.2} {:>13.1}x",
                        r.model, r.system, r.epochs, h, h / pac
                    ),
                    None => println!(
                        "{:<12} {:<14} {:>7} {:>10} {:>14}",
                        r.model, r.system, r.epochs, "OOM", "-"
                    ),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fig. 13 — per-sample time & memory breakdown (8 × Nano-H)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub technique: String,
    /// average per-sample training time (s) on the 8-Nano cluster
    pub sample_time: Option<f64>,
    /// peak per-device memory breakdown (bytes)
    pub weights: u64,
    pub activations: u64,
    pub gradients: u64,
}

pub fn fig13() -> Vec<Fig13Row> {
    let env = Env::nanos(8);
    let spec = ModelSpec::t5_large();
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for (name, method) in [
        ("Full", Method::FullFT),
        ("Adapters", Method::adapters_default()),
        ("LoRA", Method::lora_default()),
        ("P.A.", Method::pa(false)),
        ("P.A.+cache", Method::pa(true)),
    ] {
        let prof = profile(&spec, method, wl.seq);
        let opts = PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() };
        let sample_time = plan(&prof, &env, &opts).ok().map(|p| {
            let t = if method.skips_backbone_with_cache() {
                crate::sched::training::epoch_time_cached(&prof, &env, 16, 16) / 16.0
            } else {
                crate::sched::simulate_minibatch(&p, &prof, &env.network).minibatch_time
                    / p.minibatch_samples() as f64
            };
            t
        });
        // single-device-equivalent memory breakdown (paper reports the
        // per-device peak across the cluster; we report the cost-model
        // breakdown scaled to the planned per-device share)
        let m = cost::memory(&spec, method, Precision::FP32, wl);
        let stages = plan(
            &prof,
            &env,
            &PlannerOptions { microbatch: 4, n_microbatches: 4, ..Default::default() },
        )
        .map(|p| p.n_stages() as u64)
        .unwrap_or(1);
        rows.push(Fig13Row {
            technique: name.into(),
            sample_time,
            weights: m.weights / stages,
            activations: m.activations / stages,
            gradients: m.gradients / stages,
        });
    }
    rows
}

pub fn print_fig13() {
    println!("Fig. 13 — per-sample time & per-device memory (8x Nano-H, T5-Large)");
    println!(
        "{:<12} {:>14} {:>12} {:>12} {:>12}",
        "technique", "s/sample", "weights", "acts", "grads"
    );
    for r in fig13() {
        println!(
            "{:<12} {:>14} {:>12} {:>12} {:>12}",
            r.technique,
            r.sample_time.map(|t| format!("{t:.3}")).unwrap_or("OOM".into()),
            fmt_bytes(r.weights),
            fmt_bytes(r.activations),
            fmt_bytes(r.gradients)
        );
    }
}

// ---------------------------------------------------------------------------
// Fig. 15 — memory vs model size under quantization
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig15Row {
    pub params_m: f64,
    pub technique: String,
    pub total_gb: f64,
}

pub fn fig15() -> Vec<Fig15Row> {
    // a family of T5-style models of growing size (paper: varies hidden
    // size / layers / heads)
    let family: Vec<ModelSpec> = vec![
        ModelSpec { name: "t5-60m".into(), enc_layers: 6, dec_layers: 6, d_model: 512, n_heads: 8, d_ff: 2048, vocab: 32128, reduction: 8 },
        ModelSpec::t5_base(),
        ModelSpec::bart_large(),
        ModelSpec::t5_large(),
        ModelSpec { name: "t5-1b".into(), enc_layers: 24, dec_layers: 24, d_model: 1280, n_heads: 20, d_ff: 5120, vocab: 32128, reduction: 8 },
    ];
    let wl = Workload::paper_default();
    let mut rows = Vec::new();
    for spec in &family {
        let mut push = |tech: &str, method: Method, prec: Precision| {
            let m = cost::memory(spec, method, prec, wl);
            rows.push(Fig15Row {
                params_m: spec.params_total() as f64 / 1e6,
                technique: tech.into(),
                total_gb: cost::gb(m.total()),
            });
        };
        push("Full FP32", Method::FullFT, Precision::FP32);
        push("LoRA FP32", Method::lora_default(), Precision::FP32);
        push("Adapters FP32", Method::adapters_default(), Precision::FP32);
        push("P.A. FP32", Method::pa(false), Precision::FP32);
        push("P.A. INT8", Method::pa(false), Precision::INT8);
        push("P.A. INT4", Method::pa(false), Precision::INT4);
    }
    rows
}

pub fn print_fig15() {
    println!("Fig. 15 — fine-tuning memory vs model size (GB)");
    println!("{:<10} {:<14} {:>10}", "params(M)", "technique", "total GB");
    for r in fig15() {
        println!("{:<10.0} {:<14} {:>10.2}", r.params_m, r.technique, r.total_gb);
    }
}

// ---------------------------------------------------------------------------
// Fig. 16 — scalability of DP / PP / PAC+ over 2–8 Nanos
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig16Row {
    pub model: String,
    pub n_devices: usize,
    pub system: String,
    /// samples/s, None = OOM
    pub throughput: Option<f64>,
    /// peak per-device weight bytes
    pub weight_mem: Option<u64>,
}

pub fn fig16() -> Vec<Fig16Row> {
    let mut combos: Vec<(ModelSpec, usize, System)> = Vec::new();
    for spec in ModelSpec::paper_models() {
        for n in 2..=8usize {
            for system in [System::DataParallel, System::PipelineParallel, System::PacPlus] {
                combos.push((spec.clone(), n, system));
            }
        }
    }
    crate::util::par_map(combos.len(), |i| {
        let (spec, n, system) = &combos[i];
        let env = Env::nanos(*n);
        // batch size = number of devices (paper §VI-G), seq 128
        let minibatch = *n;
        let prof = profile(spec, Method::pa(false), 128);
        let job = TrainJob::new(1000, 1, 128, minibatch);
        let r = run_system(*system, &prof, &env, job).ok();
        let throughput = r.as_ref().map(|r| 1000.0 / r.epoch1);
        let weight_mem = r.as_ref().map(|r| {
            r.plan
                .stages
                .iter()
                .map(|s| {
                    prof.graph.span_weight_bytes(s.range.0, s.range.1, Precision::FP32)
                })
                .max()
                .unwrap_or(0)
        });
        Fig16Row {
            model: spec.name.clone(),
            n_devices: *n,
            system: system.name().into(),
            throughput,
            weight_mem,
        }
    })
}

pub fn print_fig16() {
    println!("Fig. 16 — throughput & weight memory, 2-8 Nano-H, Parallel Adapters");
    println!(
        "{:<12} {:>4} {:<14} {:>14} {:>12}",
        "model", "n", "system", "samples/s", "w-mem/dev"
    );
    for r in fig16() {
        println!(
            "{:<12} {:>4} {:<14} {:>14} {:>12}",
            r.model,
            r.n_devices,
            r.system,
            r.throughput.map(|t| format!("{t:.2}")).unwrap_or("OOM".into()),
            r.weight_mem.map(fmt_bytes).unwrap_or("-".into())
        );
    }
}

// ---------------------------------------------------------------------------
// Fig. 17 — planner grouping configurations
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig17Row {
    pub model: String,
    pub n_devices: usize,
    pub grouping: String,
    pub stages: usize,
}

pub fn fig17() -> Vec<Fig17Row> {
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        for n in 2..=8usize {
            let env = Env::nanos(n);
            let prof = profile(&spec, Method::pa(false), 128);
            let opts = PlannerOptions {
                microbatch: n.max(4) / 2,
                n_microbatches: 4,
                ..Default::default()
            };
            if let Ok(p) = plan(&prof, &env, &opts) {
                rows.push(Fig17Row {
                    model: spec.name.clone(),
                    n_devices: n,
                    grouping: p.grouping(),
                    stages: p.n_stages(),
                });
            }
        }
    }
    rows
}

pub fn print_fig17() {
    println!("Fig. 17 — PAC+ device groupings (hybrid parallelism)");
    println!("{:<12} {:>4} {:>7}  {}", "model", "n", "stages", "grouping");
    for r in fig17() {
        println!("{:<12} {:>4} {:>7}  {}", r.model, r.n_devices, r.stages, r.grouping);
    }
}

// ---------------------------------------------------------------------------
// Fig. 18 — activation-cache benefit vs epoch count
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig18Row {
    pub model: String,
    pub epochs: usize,
    pub hours_no_cache: f64,
    pub hours_cache: f64,
    pub reduction: f64,
}

pub fn fig18() -> Vec<Fig18Row> {
    let env = Env::env_a();
    let mut rows = Vec::new();
    for spec in ModelSpec::paper_models() {
        for epochs in [1usize, 2, 3, 5, 10] {
            let job = TrainJob::new(Task::Mrpc.train_samples(), epochs, TABLE_SEQ, 16);
            let no_cache = run_system(
                System::PacPlus,
                &profile(&spec, Method::pa(false), TABLE_SEQ),
                &env,
                job,
            );
            let cache = run_system(
                System::PacPlus,
                &profile(&spec, Method::pa(true), TABLE_SEQ),
                &env,
                job,
            );
            if let (Ok(n), Ok(c)) = (no_cache, cache) {
                rows.push(Fig18Row {
                    model: spec.name.clone(),
                    epochs,
                    hours_no_cache: n.total / 3600.0,
                    hours_cache: c.total / 3600.0,
                    reduction: 1.0 - c.total / n.total,
                });
            }
        }
    }
    rows
}

pub fn print_fig18() {
    println!("Fig. 18 — fine-tuning time with/without activation cache (MRPC, Env.A)");
    println!(
        "{:<12} {:>7} {:>12} {:>12} {:>11}",
        "model", "epochs", "no-cache(h)", "cache(h)", "reduction"
    );
    for r in fig18() {
        println!(
            "{:<12} {:>7} {:>12.2} {:>12.2} {:>10.0}%",
            r.model, r.epochs, r.hours_no_cache, r.hours_cache, r.reduction * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_rows_complete() {
        let rows = fig3();
        assert_eq!(rows.len(), 3 * 6);
        // inference < PA < LoRA < Full for every model
        for spec in ModelSpec::paper_models() {
            let get = |t: &str| {
                rows.iter()
                    .find(|r| r.model == spec.name && r.technique == t)
                    .unwrap()
                    .tflops
            };
            assert!(get("Inference") < get("P.A. (ours)"));
            assert!(get("P.A. (ours)") < get("LoRA"));
            assert!(get("LoRA") < get("Full"));
            assert!(get("P.A.+cache") < get("Inference"));
        }
    }

    #[test]
    fn table1_totals() {
        let rows = table1();
        let full = rows.iter().find(|r| r.technique == "Full").unwrap();
        assert!((full.total_gb - 10.83).abs() < 1.1);
        let pa_cache = rows.iter().find(|r| r.technique == "P.A.+cache").unwrap();
        assert!(pa_cache.total_gb < 0.3 * full.total_gb);
    }

    #[test]
    fn table5_oom_pattern() {
        let rows = table5();
        let find = |model: &str, tech: &str, sys_prefix: &str| {
            rows.iter()
                .find(|r| r.model == model && r.technique == tech && r.system.starts_with(sys_prefix))
                .unwrap()
        };
        // T5-Large full: OOM everywhere (Table V bottom-left block)
        for sys in ["Standalone", "PP", "DP"] {
            assert!(
                find("T5-Large", "Full", sys).hours.iter().all(Option::is_none),
                "T5-Large Full {sys} should OOM"
            );
        }
        // PAC+ never OOMs and is the fastest entry per model/task
        for spec in ModelSpec::paper_models() {
            let pac = find(&spec.name, "ParallelAdapters", "PAC+");
            for (i, h) in pac.hours.iter().enumerate() {
                let pac_h = h.expect("PAC+ OOM");
                for r in rows.iter().filter(|r| r.model == spec.name && r.system != "PAC+") {
                    if let Some(other) = r.hours[i] {
                        assert!(
                            pac_h < other,
                            "{} {} {} task{} beat PAC+",
                            r.model,
                            r.technique,
                            r.system,
                            i
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fig12_speedup_band() {
        let rows = fig12();
        // PAC+ vs HetPipe speedups: paper reports 3.2-9.7x (1 ep) and
        // 7.6-14.7x (3 ep); assert the shape (>2x, growing with epochs)
        for spec in ModelSpec::paper_models() {
            for epochs in [1usize, 3] {
                let get = |sys: &str| {
                    rows.iter()
                        .find(|r| r.model == spec.name && r.epochs == epochs && r.system == sys)
                        .and_then(|r| r.hours)
                };
                if let (Some(pac), Some(het)) = (get("PAC+"), get("HetPipe")) {
                    let speedup = het / pac;
                    assert!(speedup > 2.0, "{}: speedup {speedup}", spec.name);
                }
                if let (Some(pac), Some(ast)) = (get("PAC+"), get("Asteroid")) {
                    assert!(ast / pac > 1.5, "{}: vs asteroid {}", spec.name, ast / pac);
                }
            }
        }
    }

    #[test]
    fn fig16_shapes() {
        let rows = fig16();
        // DP OOMs for T5-Large at every n: the full replica alone exceeds
        // a Nano's budget (the paper additionally reports BART-Large DP
        // OOM; our memory model puts BART-Large PA replicas just under
        // the budget — see EXPERIMENTS.md deviations)
        assert!(rows
            .iter()
            .filter(|r| r.model == "T5-Large" && r.system == "DP (EDDL)")
            .all(|r| r.throughput.is_none()));
        // PAC+ throughput >= PP throughput for every (model, n)
        for spec in ModelSpec::paper_models() {
            for n in 2..=8usize {
                let get = |sys: &str| {
                    rows.iter()
                        .find(|r| r.model == spec.name && r.n_devices == n && r.system == sys)
                        .and_then(|r| r.throughput)
                };
                if let (Some(pac), Some(pp)) = (get("PAC+"), get("PP (Eco-FL)")) {
                    assert!(pac >= pp * 0.999, "{} n={n}: PAC+ {pac} < PP {pp}", spec.name);
                }
                // PP weight memory per device shrinks vs DP
                let wm = |sys: &str| {
                    rows.iter()
                        .find(|r| r.model == spec.name && r.n_devices == n && r.system == sys)
                        .and_then(|r| r.weight_mem)
                };
                if let (Some(pp), Some(dp)) = (wm("PP (Eco-FL)"), wm("DP (EDDL)")) {
                    assert!(pp < dp);
                }
            }
        }
    }

    #[test]
    fn fig17_groupings_scale() {
        let rows = fig17();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.stages <= r.n_devices);
        }
        // larger models need more stages on the same devices
        let stages_of = |model: &str, n: usize| {
            rows.iter()
                .find(|r| r.model == model && r.n_devices == n)
                .map(|r| r.stages)
        };
        if let (Some(base), Some(large)) = (stages_of("T5-Base", 8), stages_of("T5-Large", 8)) {
            assert!(large >= base);
        }
    }

    #[test]
    fn fig18_monotone_reduction() {
        let rows = fig18();
        for spec in ModelSpec::paper_models() {
            let series: Vec<&Fig18Row> =
                rows.iter().filter(|r| r.model == spec.name).collect();
            for w in series.windows(2) {
                assert!(
                    w[1].reduction >= w[0].reduction - 1e-9,
                    "{}: reduction not monotone",
                    spec.name
                );
            }
            let last = series.last().unwrap();
            assert!(last.reduction > 0.5, "{}: 10-epoch reduction {}", spec.name, last.reduction);
        }
    }
}
