//! Experiment harnesses regenerating every table and figure of the
//! paper's evaluation (§VI). Each function prints the corresponding
//! table/series and returns the rows for programmatic checks.
//!
//! | fn            | reproduces |
//! |---------------|------------|
//! | [`fig3`]      | Fig. 3 — FLOPs of fine-tuning techniques |
//! | [`table1`]    | Table I — memory breakdown (T5-Large) |
//! | [`table5`]    | Table V — end-to-end fine-tuning hours, Env.A |
//! | [`fig12`]     | Fig. 12 — PAC+ vs Asteroid/HetPipe, Env.B |
//! | [`fig13`]     | Fig. 13 — per-sample time + memory breakdown |
//! | [`fig15`]     | Fig. 15 — memory vs model size × precision |
//! | [`fig16`]     | Fig. 16 — scalability 2–8 devices |
//! | [`fig17`]     | Fig. 17 — planner device groupings |
//! | [`fig18`]     | Fig. 18 — cache benefit vs epochs |
//!
//! The accuracy-side experiments (Table VI, Table VII, Fig. 14) run real
//! training through the PJRT engine and live in `exp::accuracy`.

pub mod ablations;
pub mod accuracy;
pub mod tables;

pub use tables::*;
