//! The experiment layer: a typed `Experiment`/`Report` API over every
//! table, figure and ablation of the paper's evaluation (§VI).
//!
//! Experiments are addressed **by name** through an
//! [`ExperimentRegistry`] (same open design as
//! [`crate::strategy::StrategyRegistry`]), each producing a [`Report`] —
//! a named table with typed columns ([`ColType`]), rows of [`Cell`]s and
//! provenance metadata — renderable as aligned text, JSON (round-trips
//! through [`crate::util::json`]) and CSV. Independent experiments run
//! concurrently ([`ExperimentRegistry::run_all`]).
//!
//! | name                  | reproduces |
//! |-----------------------|------------|
//! | `fig3`                | Fig. 3 — FLOPs of fine-tuning techniques |
//! | `table1`              | Table I — memory breakdown (T5-Large) |
//! | `table5`              | Table V — end-to-end fine-tuning hours, Env.A |
//! | `table6`              | Table VI — quality parity (real training) |
//! | `table7`              | Table VII — quantized backbone (real training) |
//! | `fig12`               | Fig. 12 — PAC+ vs Asteroid/HetPipe, Env.B |
//! | `fig13`               | Fig. 13 — per-sample time + memory breakdown |
//! | `fig14`               | Fig. 14 — adapter weight-init (real training) |
//! | `fig15`               | Fig. 15 — memory vs model size × precision |
//! | `fig16`               | Fig. 16 — scalability 2–8 devices |
//! | `fig17`               | Fig. 17 — planner device groupings |
//! | `fig18`               | Fig. 18 — cache benefit vs epochs |
//! | `ablate_schedule`     | 1F1B vs GPipe ablation (DESIGN.md §5) |
//! | `ablate_bandwidth`    | LAN vs Wi-Fi sensitivity ablation |
//! | `ablate_microbatches` | pipelining depth M sweep |
//! | `sweep`               | registry-only env × model × strategy grid |
//! | `fleet`               | multi-tenant scheduling: policy × trace × env, stable pool |
//! | `fleet_churn`         | the same grid under device churn (joins/leaves/degrades) |
//! | `fleet_checkpoint`    | checkpoint interval k vs restart loss/overhead under churn |
//! | `fleet_users`         | per-user SLO breakdown: p95, deadline hits, fairness shares |
//! | `fed`                 | federated adapter aggregation: selection × straggler grid |
//! | `fed_select`          | client selection × availability trace × network grid |
//! | `fleet_learn`         | in-sim DQN training curve + held-out eval vs FIFO/backfill/EDF |
//!
//! CLI: `pacpp exp list`, `pacpp exp run <name> [--format text|json|csv]
//! [--out FILE]`, `pacpp exp all`. See the crate docs ("Adding a new
//! experiment") for how to register your own.
//!
//! The pre-registry surfaces — typed-row functions (`table5()`, ...) and
//! `print_*` — are deprecated wrappers kept for one release; the golden
//! tests (`tests/exp_golden.rs`) pin them value-identical to the
//! registry Reports.

pub mod ablations;
pub mod accuracy;
pub mod fed;
pub mod fleet;
pub mod learn;
pub mod registry;
pub mod report;
pub mod tables;

pub use fed::{fed_report, fed_row, fed_schema, fed_select_report};
pub use fleet::{
    fleet_checkpoint_report, fleet_churn_report, fleet_report, fleet_row, fleet_schema,
    fleet_users_report, fleet_users_schema,
};
pub use learn::{fleet_learn_report, learn_report, learn_report_observed, learn_schema};
pub use registry::{sweep_report, sweep_schema, ExpContext, Experiment, ExperimentRegistry};
pub use report::{Cell, ColType, Column, Format, Report, ELAPSED_SECS_META};
pub use tables::*;
