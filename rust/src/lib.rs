//! # PAC+ — Resource-Efficient Personal LLM Fine-Tuning with Collaborative Edge Computing
//!
//! Rust reproduction of the PAC+ system (Ye et al., CS.DC 2024): an
//! algorithm/system co-design that fine-tunes personal LLMs across a pool
//! of proximate edge devices using Parallel Adapters, an activation cache,
//! block-wise backbone quantization, and hybrid data+pipeline parallelism
//! driven by a dynamic-programming planner.
//!
//! This crate is the **L3 coordinator** of a three-layer stack:
//!
//! * **L1** (build-time Python): Pallas kernels — block-dequant GEMM,
//!   flash attention, fused adapter combine (`python/compile/kernels/`).
//! * **L2** (build-time Python): the JAX model — frozen transformer
//!   backbone + Parallel Adapters, AOT-lowered to HLO text
//!   (`python/compile/model.py`, `aot.py`).
//! * **L3** (this crate): planning, scheduling, the activation cache, the
//!   cluster substrate, the PJRT runtime that executes the AOT artifacts,
//!   and every baseline system the paper compares against.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module      | role |
//! |-------------|------|
//! | [`model`]   | transformer layer graph + analytic FLOPs/memory cost model |
//! | [`cluster`] | edge-device performance models, network, environment presets |
//! | [`profiler`]| per-(device, layer, batch) FP/BP time tables |
//! | [`planner`] | the paper's DP planner (Eq. 3–7, Alg. 1), threaded σ-search |
//! | [`strategy`]| the `ParallelismStrategy` trait + name-addressed registry of all systems |
//! | [`sched`]   | 1F1B hybrid-parallel schedule construction + event simulation |
//! | [`cache`]   | the PAC+ activation cache |
//! | [`baselines`]| compatibility adapters (`System` enum) over the strategy registry |
//! | [`runtime`] | PJRT client wrapper: load + execute HLO artifacts (`pjrt` feature) |
//! | [`exec`]    | real multi-threaded hybrid-parallel training engine |
//! | [`fleet`]   | discrete-event multi-tenant scheduler: arrivals, churn, queue + placement policies, deadlines/SLOs, checkpointing |
//! | [`fleet::eventq`] | pluggable event-queue backends for the fleet loop: calendar/bucket queue (default) and binary heap, bit-identical orderings |
//! | [`fed`]     | federated adapter-aggregation simulator: sync rounds or FedBuff-style async buffered folding, client selection (incl. Oort-style utility), straggler policies, availability churn, staleness accounting, secure-agg/DP knobs |
//! | [`learn`]   | in-simulator RL scheduling: dependency-free DQN over fleet decision points, exported as a loadable queue policy |
//! | [`obs`]     | observability: typed metric registry, virtual-time span tracing (Chrome/Perfetto + JSONL export), wall-clock phase timers, all behind a zero-cost-when-disabled `Observer` |
//! | [`obs::analyze`] | offline trace analyzer: per-(category, name) span aggregates, critical-path/straggler attribution, gap/bubble accounting over exported traces (`pacpp trace summarize`) |
//! | [`obs::regress`] | benchmark history + regression gate: declarative series extraction from `BENCH_*.json`, append-only JSONL history, deterministic baseline/median verdicts (`pacpp bench`) |
//! | [`quant`]   | block-wise INT8/INT4 quantization (paper Eq. 1–2) |
//! | [`data`]    | synthetic GLUE-like workload generators |
//! | [`exp`]     | typed `Experiment`/`Report` API + name-addressed registry of every paper table/figure |
//! | [`util`]    | JSON, RNG, CLI, bench, property-testing (offline-image stand-ins) |
//!
//! ## Adding a new parallelism strategy
//!
//! Planning is open: every system — PAC+ itself included — goes through
//! the [`strategy::ParallelismStrategy`] trait. To add one (say, a
//! split-placement scheme in the PrivateLoRA direction):
//!
//! 1. implement the trait — [`name`](strategy::ParallelismStrategy::name)
//!    (stable display name), [`options`](strategy::ParallelismStrategy::options)
//!    (how a `TrainJob` maps to planner knobs) and
//!    [`plan`](strategy::ParallelismStrategy::plan); override
//!    [`run`](strategy::ParallelismStrategy::run) only when the epoch
//!    model differs from plan-then-simulate (see `strategy::HetPipe`);
//! 2. register it: `StrategyRegistry::with_defaults()` for the paper
//!    line-up plus yours via [`strategy::StrategyRegistry::register`] —
//!    or add it to `with_defaults` if it should ship by default;
//! 3. run `cargo test`: the conformance suite
//!    (`tests/strategy_conformance.rs`) automatically checks every
//!    registered strategy's plans for feasibility (coverage, dispatch
//!    sums, memory budgets) on the paper's environment presets.
//!
//! The CLI (`pacpp simulate --system <name>`, `pacpp strategies`) and the
//! experiment tables resolve strategies by registry name, so a registered
//! strategy is immediately addressable everywhere.
//!
//! ## Adding a new experiment
//!
//! The evaluation surface is open the same way: every table, figure and
//! ablation is an [`exp::Experiment`] producing a typed [`exp::Report`]
//! (named columns — `Str`/`Int`/`Float`/`Bytes`/`Secs`/`Speedup` — rows
//! of cells, and env/model/strategy metadata) that renders as text, JSON
//! or CSV. To add one (say, a new scenario grid):
//!
//! 1. implement the trait — [`name`](exp::Experiment::name) (stable
//!    registry name), optional [`aliases`](exp::Experiment::aliases) /
//!    [`description`](exp::Experiment::description), and
//!    [`run`](exp::Experiment::run), which builds a [`exp::Report`]
//!    (`Report::new(..).column(..)` then `push` typed rows — arity and
//!    types are checked). Draw shared inputs (artifact runtime, training
//!    budget) from the [`exp::ExpContext`]. Set
//!    [`parallel_safe`](exp::Experiment::parallel_safe) to `false` only
//!    if the experiment mutates process-global state (real training);
//! 2. register it: [`exp::ExperimentRegistry::register`] on top of
//!    [`with_defaults`](exp::ExperimentRegistry::with_defaults) — or add
//!    it to `with_defaults` if it should ship by default;
//! 3. run `cargo test`: the registry tests pin the default line-up, and
//!    `tests/exp_golden.rs` shows how to golden-test a report (JSON
//!    round-trip via [`exp::Report::from_json`] included).
//!
//! A registered experiment is immediately listed by `pacpp exp list`,
//! runs by name (`pacpp exp run <name> --format json --out FILE`), and
//! participates in `pacpp exp all` and the bench harness.
//!
//! ## Adding a placement policy
//!
//! The fleet layer is open the same way: how jobs claim devices from
//! the shared pool is a [`fleet::PlacementPolicy`] resolved by name
//! through [`fleet::PolicyRegistry`]. To add one (say, a
//! shortest-job-first or deadline-aware scheme):
//!
//! 1. implement the trait — [`name`](fleet::PlacementPolicy::name)
//!    (stable display name),
//!    [`place`](fleet::PlacementPolicy::place) (pick a device subset
//!    for the queue-head job, or `None` to wait; cost candidate
//!    subsets through the provided [`fleet::PlanOracle`] — never
//!    re-derive timing), and optionally
//!    [`on_churn`](fleet::PlacementPolicy::on_churn) (`Restart` loses
//!    the attempt, `Replan` keeps progress and pays the cache-migration
//!    cost);
//! 2. register it: [`fleet::PolicyRegistry::register`] on top of
//!    [`with_defaults`](fleet::PolicyRegistry::with_defaults) — or add
//!    it to `with_defaults` if it should ship by default;
//! 3. run `cargo test`: the fleet tests exercise every registered
//!    policy on the experiment grids, and the property suite
//!    (`tests/prop_invariants.rs`) pins event-loop determinism.
//!
//! The fleet experiments (`pacpp exp run fleet fleet_churn`) and the
//! `pacpp fleet` CLI (`--policy <name>`) resolve policies by registry
//! name, so a registered policy is immediately comparable against the
//! built-ins on every trace × environment cell.
//!
//! ## Adding a queue policy
//!
//! *Which* queued job runs next is the other open axis of the fleet
//! layer: a [`fleet::QueuePolicy`] resolved by name through
//! [`fleet::QueuePolicyRegistry`], composing with any placement
//! policy. To add one (say, earliest-deadline-first):
//!
//! 1. implement the trait — [`name`](fleet::QueuePolicy::name) (stable
//!    display name) and [`next`](fleet::QueuePolicy::next), which picks
//!    a queue position + placement from a [`fleet::QueueCtx`] (the
//!    queued jobs, free devices, running jobs with scheduled finishes,
//!    durable per-job progress, and the run's placement policy/oracle —
//!    use [`try_place`](fleet::QueueCtx::try_place) to test candidate
//!    placements and
//!    [`attempt_duration`](fleet::QueueCtx::attempt_duration) for
//!    checkpoint-aware finish estimates), or `None` to wait;
//! 2. register it: [`fleet::QueuePolicyRegistry::register`] on top of
//!    [`with_defaults`](fleet::QueuePolicyRegistry::with_defaults)
//!    (FIFO, EASY-backfill, SJF) — or add it to `with_defaults` if it
//!    should ship by default;
//! 3. run `cargo test`: `tests/fleet.rs` pins same-seed determinism
//!    per queue policy, and `tests/prop_invariants.rs` shows how to
//!    property-test a discipline's guarantee (EASY's no-head-delay)
//!    against FIFO on the same seed.
//!
//! `pacpp fleet --queue <name>` and [`fleet::FleetOptions::queue`]
//! resolve disciplines by registry name. Deadlines
//! (`--deadline`, [`fleet::FleetOptions::deadline_scale`]) and
//! checkpointing (`--ckpt`, [`fleet::CheckpointSpec`]) compose with
//! every discipline; the `fleet_checkpoint` and `fleet_users`
//! experiments surface the k-vs-overhead tradeoff and the per-user
//! SLO/fairness breakdown.
//!
//! ## Training a policy in-sim (the `learn` subsystem)
//!
//! Queue disciplines don't have to be hand-written: [`learn`] trains
//! one *inside* the fleet simulator. Every dispatch decision becomes a
//! state, every placeable queued job an action
//! ([`learn::featurize`] — queue depth, oracle ETA, deadline slack,
//! laxity, pool occupancy), and the per-job outcome the reward. The
//! stack is dependency-free and bit-deterministic: a seeded dense net
//! ([`learn::Mlp`]), a bounded replay buffer ([`learn::Replay`]), and
//! an ε-greedy fitted-Q agent ([`learn::DqnAgent`]).
//!
//! 1. **train**: `pacpp learn --episodes 40 --jobs 60 --weights w.json`
//!    runs [`learn::train`] — episodes of
//!    [`fleet::simulate_fleet_with`] under the exploring
//!    [`learn::TrainerQueue`], over Weibull/UUniFast-diversified seeded
//!    workloads ([`learn::workload`]) — then dumps the weights as JSON
//!    (bit-exact round trip via [`util::json`]);
//! 2. **evaluate**: the same invocation reloads the dump and runs
//!    [`learn::evaluate`] on held-out seeds
//!    ([`learn::held_out_seed`] — provably disjoint from every
//!    training seed) against FIFO, EASY-backfill and EDF; the
//!    `fleet_learn` experiment emits the training curve + eval table
//!    as a typed [`exp::Report`];
//! 3. **deploy**: wrap the weights in [`learn::LearnedQueue`]
//!    (inference-only, implements [`fleet::QueuePolicy`]) and pass it
//!    to [`fleet::simulate_fleet_with`] — it composes with every
//!    placement policy like the built-in disciplines do.
//!
//! Same seed, same weights, bit for bit: `tests/prop_invariants.rs`
//! pins training determinism, and `tests/learn.rs` pins the
//! held-out-seed acceptance comparison against the hand-written
//! disciplines.
//!
//! ## Adding a client-selection policy
//!
//! The federated layer ([`fed`]) is open the same way: which available
//! clients join a round is a [`fed::ClientSelection`] resolved by name
//! through [`fed::SelectionRegistry`], composing with any
//! [`fed::StragglerPolicy`] and aggregation mode. To add one (say, a
//! gradient-norm-informed sampler):
//!
//! 1. implement the trait — [`name`](fed::ClientSelection::name)
//!    (stable display name) and
//!    [`select`](fed::ClientSelection::select), which picks up to
//!    `want` client ids from a [`fed::SelectCtx`] of
//!    [`fed::Candidate`]s (each carries the oracle's round-time
//!    estimate, the availability trace's remaining up-time and
//!    long-run fraction, and the client's participation count). Draw
//!    all randomness from the provided seeded `rng` — that is what
//!    keeps same-seed runs bit-identical under your policy;
//! 2. register it: [`fed::SelectionRegistry::register`] on top of
//!    [`with_defaults`](fed::SelectionRegistry::with_defaults)
//!    (uniform, power-of-d, availability-aware, fair-share,
//!    Oort-style utility) — or add it to `with_defaults` if it should
//!    ship by default;
//! 3. run `cargo test`: `tests/fed.rs` pins same-seed determinism
//!    across every selection × straggler combination — and across
//!    every selection policy in async mode — and shows how the
//!    availability-aware acceptance comparison is engineered.
//!
//! `pacpp fed --select <name>` and [`fed::FedOptions::select`] resolve
//! policies by registry name; the `fed` / `fed_select` experiments
//! compare every registered policy on the shared grids.
//!
//! ## Adding an aggregation mode
//!
//! *When* deltas combine is the third open axis of the federated
//! layer: [`fed::AggregationMode`] picks the round engine.
//! `Sync` runs cohort rounds (select K, wait per the straggler
//! policy, aggregate, advance); `Async` is FedBuff-style buffered
//! folding (deltas fold on arrival, a logical round closes every
//! [`fed::FedOptions::buffer_k`] folds, no barrier, staleness
//! tracked). The two engines live side by side in `fed::round`
//! behind one options struct. To add a mode (say, semi-synchronous
//! tiers or staleness-weighted folding):
//!
//! 1. add the variant to [`fed::AggregationMode`] (its `ALL`, `name`
//!    and `parse` tables — the CLI, experiment metadata and reports
//!    all go through them), and give it an engine function in
//!    `fed::round` next to `run_sync`/`run_async`, dispatched from
//!    `simulate_fed_with_observed`. Engines share the prepared
//!    inputs (feasibility-filtered clients, oracle base estimates,
//!    traces) and return the same `RawFed` tallies — derive a
//!    distinct seed salt for any new randomness stream so modes
//!    never share RNG state;
//! 2. surface it: `pacpp fed --agg-mode <name>` parses through
//!    [`fed::AggregationMode`]; extend the `fed` experiment grid if
//!    the mode should appear in the shipped reports;
//! 3. run `cargo test`: `tests/fed.rs` pins bit-determinism per mode,
//!    that sync ignores async-only knobs, and the async-vs-wait-all
//!    throughput acceptance; `tests/prop_invariants.rs` pins that
//!    tracing never changes either engine's metrics. Mirror those
//!    four pins for any new mode.
//!
//! [`fed::FedMetrics`] reports the async-specific accounting
//! (`staleness_p50`/`p95`, `rounds_per_hour`) as `Option`s that stay
//! `None`/mode-neutral under `Sync`, so one metrics struct serves
//! every mode.
//!
//! ## Adding an instrumentation point
//!
//! Observability is one substrate ([`obs`]) with three faces — named
//! metrics ([`obs::Metrics`]), virtual-time trace events
//! ([`obs::trace`]) and wall-clock phase timers ([`obs::timer`]) —
//! carried through the simulators by the [`obs::Observer`] handle
//! (`&Observer`, [`disabled`](obs::Observer::disabled) by default). To
//! instrument new code:
//!
//! 1. **counter/gauge/histogram**: register into the run's
//!    [`obs::Metrics`] (`metrics.counter("my_counter")` returns a
//!    shared [`obs::Counter`] handle — `inc()` in the hot path, read
//!    it back when assembling the run's metrics struct, as
//!    `fleet::sim` does for `events`/`oracle_hits`). Counters owned by
//!    a collaborator join the registry via
//!    [`adopt_counter`](obs::Metrics::adopt_counter);
//! 2. **trace event**: call
//!    [`obs.instant(cat, name, id, ts)`](obs::Observer::instant) or
//!    [`obs.span(cat, name, id, ts, dur)`](obs::Observer::span) with
//!    the **virtual** clock — sampling (`id % N`) and ring bounding
//!    are applied inside; a disabled observer costs one branch;
//! 3. **wall-clock phase**: wrap the region in
//!    [`obs.time("phase", f)`](obs::Observer::time) or hold an RAII
//!    [`obs.timer("phase")`](obs::Observer::timer) guard. Wall
//!    readings are non-deterministic, so surface them only in report
//!    *metadata* / CLI footers, never in equality-tested metrics;
//! 4. run `cargo test`: `tests/prop_invariants.rs` pins that tracing
//!    on vs. off never changes `FleetMetrics`/`FedMetrics`, and the
//!    trace round-trip test shows the export-reparse contract.
//!
//! `pacpp fleet|fed|learn --trace-out FILE [--trace-sample N]` exports
//! Chrome trace-event JSON (Perfetto-loadable; `.jsonl` extension
//! switches to JSONL), and every `exp` run stamps `elapsed_secs` into
//! its report metadata. `pacpp trace summarize FILE` then reads either
//! export back offline ([`obs::analyze`]): per-(category, name) span
//! aggregates, the longest (category, id) span groups with straggler
//! attribution (`critical_<cat>` metadata names each category's worst
//! group), per-category gap/bubble accounting, and ring-coverage
//! stats from the recorded/dropped tallies the exports embed.
//!
//! ## Trending a benchmark
//!
//! Any machine-readable artifact the CLI writes — a report
//! (`--format json --out`), a `BENCH_OUT=<file> cargo bench` dump, a
//! `--trace-out` Chrome trace — can be tracked across commits without
//! bespoke scripts ([`obs::regress`]):
//!
//! 1. **record**: `pacpp bench record BENCH_fleet.json --history
//!    bench_history.jsonl --label $(git rev-parse --short HEAD)`
//!    flattens the artifact into named scalar series
//!    (`fleet.meta.events_total`, `fleet.row.<env>/<policy>.goodput`,
//!    `bench.<suite>.<case>.p50`, ...) and appends one JSONL point per
//!    series. `--extract name=rows[0][2]` adds custom key-path pulls;
//! 2. **gate**: `pacpp bench compare BENCH_fleet.json --baseline
//!    ci/bench_baseline.json` re-extracts and fails (nonzero exit,
//!    after printing the verdict table) on any series off its baseline
//!    by more than the tolerance, in its worse direction
//!    ([`obs::regress::Direction`] is inferred from the series name —
//!    `*.p95`, `*.makespan` lower-better; goodput-style higher-better
//!    — and can be pinned per series in the baseline file). Seed a
//!    baseline with `bench record --baseline-out`: only deterministic
//!    series are gated, wall-clock ones (`*.wall.*`, `bench.*`) are
//!    recorded for trending but never gate;
//! 3. **trend**: `pacpp bench compare --history bench_history.jsonl`
//!    gates the newest point against the median of the trailing
//!    `--window` instead of a fixed baseline, and `pacpp bench trend`
//!    prints per-series first/median/last with the relative change.
//!
//! CI runs the record → compare loop on every push (see
//! `.github/workflows/ci.yml`, "Bench regression gate") and uploads
//! the history; `ci/bench_baseline.json` holds the committed gate.
//!
//! ## Scaling knobs
//!
//! The simulators are sized for 1M-job fleet traces and 100k-client
//! federated populations. Every scaling path is same-seed
//! bit-identical to the simple implementation it replaced —
//! `tests/prop_invariants.rs` pins the equivalences — so the knobs
//! below trade only speed, never results:
//!
//! * [`fleet::FleetOptions::event_queue`] — event-queue backend
//!   ([`fleet::EventQueueKind`]): `Calendar` (default; O(1) amortized
//!   bucket queue) or `Heap` (the reference `BinaryHeap`). CLI:
//!   `pacpp fleet --event-queue calendar|heap`.
//! * [`fleet::FleetOptions::incremental_queue`] — incremental dispatch
//!   order (default `true`): SJF/EDF keep sorted orders and the
//!   backfill/SJF/EDF/LLF paths memoize oracle estimates and placement
//!   failures across dispatch attempts, invalidated by pool/state
//!   epochs, instead of rescanning the whole backlog per event. CLI:
//!   `pacpp fleet --legacy-dispatch` turns it off.
//! * [`fed::FedOptions::shards`] — per-client quoting/trace shards
//!   (`0` = auto: all cores at ≥ [`fed::PAR_CLIENT_THRESHOLD`]
//!   clients). Property-tested shard-count-invariant. CLI:
//!   `pacpp fed --shards N`.
//! * [`util::stats::SKETCH_EXACT_LIMIT`] — percentile accounting
//!   switches from exact sorted samples to the deterministic P²-style
//!   [`util::stats::QuantileSketch`] above this many observations
//!   (exact below it, streaming O(1)-memory above).
//!
//! The observe counters ride along in every report's metadata
//! (`events_total`, `oracle_hits_total`, `oracle_misses_total`,
//! `rescans_avoided_total`) and in [`fleet::FleetMetrics`] /
//! [`fed::FedMetrics`], so scaling regressions show up in the diffable
//! `BENCH_*.json` artifacts. `cargo bench --bench bench_fleet` /
//! `--bench bench_fed` carry 100k/1M-job and 100k-client scale cases
//! (events/sec and rounds/sec printed per case); CI smokes the same
//! paths via `BENCH_fleet_scale.json` / `BENCH_fed_scale.json`.

pub mod baselines;
pub mod cache;
pub mod cluster;
pub mod data;
pub mod exec;
pub mod exp;
pub mod fed;
pub mod fleet;
pub mod learn;
pub mod model;
pub mod obs;
pub mod planner;
pub mod profiler;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod strategy;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
