//! The open queueing layer: *which* queued job is attempted next.
//!
//! [`super::policy::PlacementPolicy`] decides how a job claims devices;
//! a [`QueuePolicy`] decides which queued job gets that chance. The
//! split mirrors real cluster schedulers (Slurm/Flux): the queue
//! discipline composes with any placement policy, and both resolve by
//! name through their registries ([`QueuePolicyRegistry`],
//! [`super::policy::PolicyRegistry`]).
//!
//! Built-ins:
//!
//! * [`FifoQueue`] — strict head-of-line (the PR-3 behavior);
//! * [`EasyBackfill`] — EASY backfilling: when the head job cannot be
//!   placed, compute its *shadow time* (the earliest instant it becomes
//!   feasible, assuming running jobs release their devices at their
//!   scheduled finishes) and let a later job jump the line only if it
//!   is certain to finish — checkpoint pauses included — by that
//!   instant. On a churn-free run a backfilled job therefore never
//!   delays the blocked head's start (property-tested in
//!   `tests/prop_invariants.rs`); under churn finish times are
//!   estimates and the guarantee is best-effort, like every real
//!   backfill scheduler's;
//! * [`ShortestJobFirst`] — place the placeable job with the smallest
//!   whole-pool service estimate (via the same [`PlanOracle`] quotes
//!   the placements use). Minimizes mean wait; can starve large jobs;
//! * [`EarliestDeadlineFirst`] — attempt queued jobs in absolute-
//!   deadline order (ties in queue order), placing the first that
//!   fits. Deadline-less jobs sort last;
//! * [`LeastLaxity`] — attempt queued jobs by laxity: deadline minus
//!   now minus the whole-pool remaining-work estimate (checkpoint
//!   pauses and durable progress included). A job with zero slack gets
//!   the next free slot even when its deadline is later than a short
//!   job's.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use crate::cluster::Device;

use super::ckpt::{AttemptTimeline, CheckpointSpec};
use super::policy::{Placement, PlacementCtx, PlacementPolicy, PlanOracle};
use super::trace::Job;

/// One running job as the queue layer sees it: its scheduled finish
/// and the devices (with current kinds) it will release then.
#[derive(Debug, Clone)]
pub struct RunningSnapshot {
    pub job: usize,
    pub finish: f64,
    pub devices: Vec<Device>,
}

/// What a queue decision sees. `queue` holds job ids front-first
/// (borrowed straight from the simulator — no per-dispatch copy, the
/// backlog can be thousands of jobs); `running` is ascending by
/// scheduled finish; `done` is the durable completed fraction per job
/// id (last checkpoint — 0.0 for fresh jobs).
pub struct QueueCtx<'a> {
    pub jobs: &'a [Job],
    pub queue: &'a VecDeque<usize>,
    /// Idle devices, ascending id order.
    pub free: &'a [Device],
    /// Devices present in the pool (busy + free).
    pub present: usize,
    /// Jobs currently running (always populated, unlike `running`).
    pub n_running: usize,
    /// Running-job snapshots, ascending by scheduled finish — built
    /// only for policies whose [`QueuePolicy::wants_running`] is true
    /// (empty otherwise).
    pub running: &'a [RunningSnapshot],
    pub done: &'a [f64],
    /// Per-job absolute deadlines, indexed by job id
    /// (`f64::INFINITY` = none) — what the deadline-aware disciplines
    /// ([`EarliestDeadlineFirst`], [`LeastLaxity`]) order by.
    pub deadlines: &'a [f64],
    pub now: f64,
    pub placement: &'a dyn PlacementPolicy,
    pub oracle: &'a dyn PlanOracle,
    pub ckpt: Option<&'a CheckpointSpec>,
    /// Incremental dispatch state maintained by the simulator
    /// ([`super::FleetOptions::incremental_queue`]); `None` runs every
    /// policy on its exact legacy path (kept for the equivalence
    /// property tests).
    pub index: Option<&'a QueueIndex>,
}

impl QueueCtx<'_> {
    /// Attempt to place `job` on a (possibly hypothetical) free set
    /// with `running` jobs active, through the run's placement policy.
    pub fn try_place(&self, job: &Job, free: &[Device], running: usize) -> Option<Placement> {
        let ctx = PlacementCtx {
            job,
            free,
            present: self.present,
            running,
            oracle: self.oracle,
        };
        self.placement.place(&ctx)
    }

    /// Wall-clock duration the quoted placement implies for `job`'s
    /// next attempt, checkpoint pauses included. Queued jobs resume
    /// from their durable checkpoint, so `p0` and `durable` coincide.
    pub fn attempt_duration(&self, job: &Job, quote: f64) -> f64 {
        let done = self.done[job.id];
        AttemptTimeline::new(done, done, 0.0, quote, job.epochs, self.ckpt).duration()
    }
}

/// A queue decision: start the job at `queue_pos` (0 = head) with this
/// placement.
#[derive(Debug, Clone)]
pub struct QueueDecision {
    pub queue_pos: usize,
    pub placement: Placement,
}

/// `f64` → `u64` preserving `total_cmp` order, so float keys can live
/// in ordered integer sets.
fn key_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 0 {
        b | (1 << 63)
    } else {
        !b
    }
}

#[derive(Debug, Clone, Copy)]
enum IndexOp {
    Enqueue(usize, i64),
    Dequeue(usize),
}

/// One incrementally-maintained sort of the queue, valid for a single
/// pool epoch: `(key bits, rank, job)` — rank order equals queue-
/// position order, so iteration reproduces the legacy
/// sort-by-(key, position) exactly.
#[derive(Debug)]
struct SortedOrder {
    epoch: u64,
    set: BTreeSet<(u64, i64, usize)>,
    key_of: BTreeMap<usize, (u64, i64)>,
}

/// Incremental dispatch state shared between the simulator and the
/// queue policies, so EASY/SJF/EDF/LLF stop rescanning or re-sorting
/// the whole backlog on every dispatch:
///
/// * a **sorted order** over the queue (EDF's deadlines, SJF's
///   whole-pool estimates) kept by sorted insert against the
///   simulator's enqueue/dequeue notifications — O(log n) per queue
///   change instead of an O(n log n) sort per dispatch — and rebuilt
///   only when churn moves the pool (the keys' epoch);
/// * **oracle estimates** keyed by `(job, pool epoch)`, so each queued
///   job is quoted once per pool change instead of once per dispatch;
/// * **placement failures** keyed by the free/running state epoch: a
///   job that could not be placed stays unplaceable until a start,
///   finish or churn event changes the state, so re-dispatches within
///   the same state skip it outright (counted in
///   [`rescans_avoided`](QueueIndex::rescans_avoided));
/// * EASY's **shadow time**, a pure function of the same state.
///
/// Ranks replicate queue order without tracking index shifts: arrivals
/// take increasing back ranks, churn-requeues decreasing front ranks,
/// and interior removals keep relative order — exactly like the
/// `VecDeque` itself.
///
/// Policies receive a shared reference through [`QueueCtx::index`]
/// (they are stateless and `Sync`-shared across experiment threads;
/// per-run state has to travel with the run), hence the interior
/// mutability. Everything here is a cache of pure functions of
/// simulator state, so the incremental paths are bit-identical to the
/// legacy ones — property-tested in `tests/prop_invariants.rs`.
#[derive(Debug, Default)]
pub struct QueueIndex {
    pool_epoch: Cell<u64>,
    state_epoch: Cell<u64>,
    back_rank: Cell<i64>,
    front_rank: Cell<i64>,
    ranks: RefCell<BTreeMap<usize, i64>>,
    /// Queue changes since the last order sync (only fed while an
    /// order is live — policies that never sort skip the cost).
    log: RefCell<Vec<IndexOp>>,
    order: RefCell<Option<SortedOrder>>,
    /// `(state epoch, jobs that failed to place in it)`.
    place_fail: RefCell<(u64, BTreeSet<usize>)>,
    /// `(state epoch, head job, shadow)` memo for EASY backfill.
    shadow: RefCell<Option<(u64, usize, Option<f64>)>>,
    /// `(pool epoch, job → whole-pool estimate)`; infeasible = ∞.
    est: RefCell<(u64, BTreeMap<usize, f64>)>,
    rescans_avoided: Cell<usize>,
}

impl QueueIndex {
    pub fn new() -> QueueIndex {
        QueueIndex::default()
    }

    /// The simulator enqueued `job` at the back (arrival).
    pub fn on_enqueue_back(&self, job: usize) {
        let r = self.back_rank.get();
        self.back_rank.set(r + 1);
        self.ranks.borrow_mut().insert(job, r);
        if self.order.borrow().is_some() {
            self.log.borrow_mut().push(IndexOp::Enqueue(job, r));
        }
    }

    /// The simulator re-queued `job` at the front (churn restart).
    pub fn on_enqueue_front(&self, job: usize) {
        let r = self.front_rank.get() - 1;
        self.front_rank.set(r);
        self.ranks.borrow_mut().insert(job, r);
        if self.order.borrow().is_some() {
            self.log.borrow_mut().push(IndexOp::Enqueue(job, r));
        }
    }

    /// The simulator removed `job` from the queue (dispatch or prune).
    pub fn on_dequeue(&self, job: usize) {
        self.ranks.borrow_mut().remove(&job);
        if self.order.borrow().is_some() {
            self.log.borrow_mut().push(IndexOp::Dequeue(job));
        }
    }

    /// Churn changed pool membership or a device kind: whole-pool
    /// estimates and orders keyed on them are stale.
    pub fn on_pool_change(&self) {
        self.pool_epoch.set(self.pool_epoch.get() + 1);
        self.state_epoch.set(self.state_epoch.get() + 1);
        *self.order.borrow_mut() = None;
        self.log.borrow_mut().clear();
    }

    /// A start, finish or churn changed the free/running state:
    /// placement outcomes and shadows are stale (pool-epoch caches
    /// survive — the device multiset did not move).
    pub fn on_state_change(&self) {
        self.state_epoch.set(self.state_epoch.get() + 1);
    }

    /// Observe counter: dispatch work skipped thanks to the caches
    /// (placement-failure hits + per-dispatch re-sorts avoided).
    pub fn rescans_avoided(&self) -> usize {
        self.rescans_avoided.get()
    }

    /// Whole-pool service estimate for `job` (∞ when infeasible),
    /// cached per pool epoch. Same value the legacy paths compute —
    /// the oracle is pure. Crate-visible so [`crate::learn`]'s queue
    /// policies share the memo instead of re-quoting.
    pub(crate) fn pool_est(&self, ctx: &QueueCtx, pool: &[Device], job: usize) -> f64 {
        let epoch = self.pool_epoch.get();
        let mut est = self.est.borrow_mut();
        if est.0 != epoch {
            *est = (epoch, BTreeMap::new());
        }
        if let Some(&v) = est.1.get(&job) {
            return v;
        }
        let v = ctx
            .oracle
            .service_time(&ctx.jobs[job], pool)
            .unwrap_or(f64::INFINITY);
        est.1.insert(job, v);
        v
    }

    /// Did `job` already fail to place in the current state?
    pub(crate) fn known_unplaceable(&self, job: usize) -> bool {
        let epoch = self.state_epoch.get();
        let mut pf = self.place_fail.borrow_mut();
        if pf.0 != epoch {
            *pf = (epoch, BTreeSet::new());
            return false;
        }
        if pf.1.contains(&job) {
            self.rescans_avoided.set(self.rescans_avoided.get() + 1);
            true
        } else {
            false
        }
    }

    pub(crate) fn note_unplaceable(&self, job: usize) {
        let epoch = self.state_epoch.get();
        let mut pf = self.place_fail.borrow_mut();
        if pf.0 != epoch {
            *pf = (epoch, BTreeSet::new());
        }
        pf.1.insert(job);
    }

    /// EASY's shadow time for `head`, memoized per state epoch.
    fn shadow_of(&self, head: usize, compute: impl FnOnce() -> Option<f64>) -> Option<f64> {
        let epoch = self.state_epoch.get();
        if let Some((e, h, s)) = *self.shadow.borrow() {
            if e == epoch && h == head {
                self.rescans_avoided.set(self.rescans_avoided.get() + 1);
                return s;
            }
        }
        let s = compute();
        *self.shadow.borrow_mut() = Some((epoch, head, s));
        s
    }

    /// Run `f` over the queue sorted by `(key_fn, queue order)`,
    /// syncing the sorted order first: rebuilt after pool churn,
    /// otherwise patched from the enqueue/dequeue log.
    fn with_order<R>(
        &self,
        ctx: &QueueCtx,
        key_fn: impl Fn(usize) -> f64,
        f: impl FnOnce(&BTreeSet<(u64, i64, usize)>) -> R,
    ) -> R {
        let epoch = self.pool_epoch.get();
        let mut slot = self.order.borrow_mut();
        let fresh = !matches!(slot.as_ref(), Some(o) if o.epoch == epoch);
        if fresh {
            let ranks = self.ranks.borrow();
            let mut set = BTreeSet::new();
            let mut key_of = BTreeMap::new();
            for &job in ctx.queue {
                let rank = ranks[&job];
                let bits = key_bits(key_fn(job));
                set.insert((bits, rank, job));
                key_of.insert(job, (bits, rank));
            }
            drop(ranks);
            self.log.borrow_mut().clear();
            *slot = Some(SortedOrder { epoch, set, key_of });
        } else {
            let ops: Vec<IndexOp> = std::mem::take(&mut *self.log.borrow_mut());
            let o = slot.as_mut().expect("order exists when not fresh");
            for op in ops {
                match op {
                    IndexOp::Enqueue(job, rank) => {
                        let bits = key_bits(key_fn(job));
                        o.set.insert((bits, rank, job));
                        o.key_of.insert(job, (bits, rank));
                    }
                    IndexOp::Dequeue(job) => {
                        if let Some((bits, rank)) = o.key_of.remove(&job) {
                            o.set.remove(&(bits, rank, job));
                        }
                    }
                }
            }
            self.rescans_avoided.set(self.rescans_avoided.get() + 1);
        }
        f(&slot.as_ref().expect("order just synced").set)
    }
}

/// A pluggable queueing discipline. Implementations must be stateless
/// (or internally synchronized): the registry hands out shared
/// references and the fleet experiments run policies from worker
/// threads.
pub trait QueuePolicy: Send + Sync {
    /// Canonical display name (stable: used in tables, JSON, the CLI).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`QueuePolicyRegistry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `pacpp fleet` docs.
    fn description(&self) -> &str {
        ""
    }

    /// Whether [`next`](QueuePolicy::next) reads [`QueueCtx::running`].
    /// The dispatch loop is the simulator's hottest path; disciplines
    /// that never look at the running set (FIFO) let the simulator
    /// skip building the per-dispatch snapshot entirely.
    fn wants_running(&self) -> bool {
        true
    }

    /// Pick the next job to start, or `None` to wait (the simulator
    /// retries at the next state change and fails permanently
    /// unplaceable jobs itself).
    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision>;
}

/// Strict head-of-line: only the queue head is ever attempted.
#[derive(Debug, Clone, Copy, Default)]
pub struct FifoQueue;

impl QueuePolicy for FifoQueue {
    fn name(&self) -> &str {
        "FIFO"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fifo", "hol", "head-of-line"]
    }

    fn description(&self) -> &str {
        "strict head-of-line: a blocked head job blocks everything behind it"
    }

    fn wants_running(&self) -> bool {
        false // only the running *count* is read, which travels separately
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        let &head = ctx.queue.front()?;
        let placement = ctx.try_place(&ctx.jobs[head], ctx.free, ctx.n_running)?;
        Some(QueueDecision { queue_pos: 0, placement })
    }
}

/// EASY backfilling: the head keeps an implicit reservation at its
/// shadow time; later jobs may run now only if they provably finish by
/// then. Conservative by design — a candidate that *might* overrun the
/// shadow waits.
#[derive(Debug, Clone, Copy, Default)]
pub struct EasyBackfill;

impl QueuePolicy for EasyBackfill {
    fn name(&self) -> &str {
        "EASY-backfill"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["backfill", "easy", "easy-backfill"]
    }

    fn description(&self) -> &str {
        "small jobs jump the line only when they cannot delay the head job's earliest start"
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        let &head_id = ctx.queue.front()?;
        let head = &ctx.jobs[head_id];
        if ctx.index.is_none_or(|ix| !ix.known_unplaceable(head_id)) {
            if let Some(placement) = ctx.try_place(head, ctx.free, ctx.n_running) {
                return Some(QueueDecision { queue_pos: 0, placement });
            }
            if let Some(ix) = ctx.index {
                ix.note_unplaceable(head_id);
            }
        }
        // shadow time: replay the scheduled finishes, accumulating the
        // devices they release, until the head becomes feasible. A pure
        // function of the free/running state, so the index memoizes it
        // across the dispatch retries within one state.
        let compute_shadow = || {
            let mut avail: Vec<Device> = ctx.free.to_vec();
            let mut shadow = None;
            for (i, r) in ctx.running.iter().enumerate() {
                avail.extend(r.devices.iter().cloned());
                avail.sort_by_key(|d| d.id);
                if ctx.try_place(head, &avail, ctx.n_running - (i + 1)).is_some() {
                    shadow = Some(r.finish);
                    break;
                }
            }
            shadow
        };
        // head infeasible even on everything: let the simulator's
        // failed-job pruning deal with it
        let shadow = match ctx.index {
            Some(ix) => ix.shadow_of(head_id, compute_shadow)?,
            None => compute_shadow()?,
        };
        for pos in 1..ctx.queue.len() {
            let job = ctx.queue[pos];
            if ctx.index.is_some_and(|ix| ix.known_unplaceable(job)) {
                continue;
            }
            let cand = &ctx.jobs[job];
            if let Some(placement) = ctx.try_place(cand, ctx.free, ctx.n_running) {
                if ctx.now + ctx.attempt_duration(cand, placement.service_time) <= shadow {
                    return Some(QueueDecision { queue_pos: pos, placement });
                }
                // placed but overruns the shadow: not cached — the
                // check depends on `now`, which moves between calls
            } else if let Some(ix) = ctx.index {
                ix.note_unplaceable(job);
            }
        }
        None
    }
}

/// Shortest-job-first by whole-pool service estimate: the canonical
/// "job size" is what the oracle quotes for the job on every present
/// device, so repeated shapes cost one planner call.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestJobFirst;

impl QueuePolicy for ShortestJobFirst {
    fn name(&self) -> &str {
        "SJF"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["sjf", "shortest", "shortest-job-first"]
    }

    fn description(&self) -> &str {
        "place the placeable job with the smallest service estimate; can starve large jobs"
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        if ctx.queue.is_empty() {
            return None;
        }
        let mut pool: Vec<Device> = ctx.free.to_vec();
        for r in ctx.running {
            pool.extend(r.devices.iter().cloned());
        }
        pool.sort_by_key(|d| d.id);
        if let Some(ix) = ctx.index {
            // incremental path: the queue stays sorted by (estimate,
            // queue order) across dispatches; estimates re-quote only
            // when churn moves the pool
            let hit = ix.with_order(
                ctx,
                |j| ix.pool_est(ctx, &pool, j),
                |sorted| {
                    for &(_, _, job) in sorted {
                        if ix.known_unplaceable(job) {
                            continue;
                        }
                        if let Some(p) = ctx.try_place(&ctx.jobs[job], ctx.free, ctx.n_running)
                        {
                            return Some((job, p));
                        }
                        ix.note_unplaceable(job);
                    }
                    None
                },
            );
            let (job, placement) = hit?;
            let queue_pos =
                ctx.queue.iter().position(|&j| j == job).expect("sorted job is queued");
            return Some(QueueDecision { queue_pos, placement });
        }
        let est: Vec<f64> = ctx
            .queue
            .iter()
            .map(|&j| {
                ctx.oracle
                    .service_time(&ctx.jobs[j], &pool)
                    .unwrap_or(f64::INFINITY)
            })
            .collect();
        let mut order: Vec<usize> = (0..ctx.queue.len()).collect();
        order.sort_by(|&a, &b| est[a].total_cmp(&est[b]).then(a.cmp(&b)));
        for pos in order {
            let cand = &ctx.jobs[ctx.queue[pos]];
            if let Some(placement) = ctx.try_place(cand, ctx.free, ctx.n_running) {
                return Some(QueueDecision { queue_pos: pos, placement });
            }
        }
        None
    }
}

/// Earliest-deadline-first: attempt queued jobs in absolute-deadline
/// order (arrival order among equal deadlines), place the first that
/// fits. The classic deadline discipline; non-preemptive here, so it
/// orders *starts*, not running jobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct EarliestDeadlineFirst;

impl QueuePolicy for EarliestDeadlineFirst {
    fn name(&self) -> &str {
        "EDF"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["edf", "earliest-deadline", "earliest-deadline-first"]
    }

    fn description(&self) -> &str {
        "attempt queued jobs in absolute-deadline order; deadline-less jobs go last"
    }

    fn wants_running(&self) -> bool {
        false // deadlines and the free set are all it reads
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        if let Some(ix) = ctx.index {
            // incremental path: deadlines are fixed per job, so the
            // sorted order only ever changes by sorted insert/remove
            let hit = ix.with_order(
                ctx,
                |j| ctx.deadlines[j],
                |sorted| {
                    for &(_, _, job) in sorted {
                        if ix.known_unplaceable(job) {
                            continue;
                        }
                        if let Some(p) = ctx.try_place(&ctx.jobs[job], ctx.free, ctx.n_running)
                        {
                            return Some((job, p));
                        }
                        ix.note_unplaceable(job);
                    }
                    None
                },
            );
            let (job, placement) = hit?;
            let queue_pos =
                ctx.queue.iter().position(|&j| j == job).expect("sorted job is queued");
            return Some(QueueDecision { queue_pos, placement });
        }
        let mut order: Vec<usize> = (0..ctx.queue.len()).collect();
        order.sort_by(|&a, &b| {
            let (da, db) = (ctx.deadlines[ctx.queue[a]], ctx.deadlines[ctx.queue[b]]);
            da.total_cmp(&db).then(a.cmp(&b))
        });
        for pos in order {
            let cand = &ctx.jobs[ctx.queue[pos]];
            if let Some(placement) = ctx.try_place(cand, ctx.free, ctx.n_running) {
                return Some(QueueDecision { queue_pos: pos, placement });
            }
        }
        None
    }
}

/// Least-laxity-first: attempt queued jobs by slack — deadline minus
/// now minus the whole-pool remaining-work estimate (durable progress
/// and checkpoint pauses included via
/// [`QueueCtx::attempt_duration`]). Unlike EDF, a long job with a late
/// but already-tight deadline outranks a short job with an earlier,
/// comfortable one.
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLaxity;

impl QueuePolicy for LeastLaxity {
    fn name(&self) -> &str {
        "LLF"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["llf", "least-laxity", "laxity", "least-laxity-first"]
    }

    fn description(&self) -> &str {
        "attempt queued jobs by slack: deadline - now - remaining-work estimate"
    }

    fn next(&self, ctx: &QueueCtx) -> Option<QueueDecision> {
        if ctx.queue.is_empty() {
            return None;
        }
        // the same canonical "job size" SJF uses: the whole-pool quote.
        // Laxity depends on `now`, so a persisted sorted order cannot
        // reproduce the legacy float rounding exactly; instead the
        // index caches the expensive part — the per-job quote, valid
        // for a whole pool epoch — and placement failures, leaving the
        // per-dispatch arithmetic (and therefore the dispatch order)
        // bit-identical to the legacy path.
        let mut pool: Vec<Device> = ctx.free.to_vec();
        for r in ctx.running {
            pool.extend(r.devices.iter().cloned());
        }
        pool.sort_by_key(|d| d.id);
        let laxity: Vec<f64> = ctx
            .queue
            .iter()
            .map(|&j| {
                let deadline = ctx.deadlines[j];
                if deadline.is_infinite() {
                    return f64::INFINITY; // no deadline, no urgency
                }
                let est = match ctx.index {
                    Some(ix) => ix.pool_est(ctx, &pool, j),
                    None => ctx
                        .oracle
                        .service_time(&ctx.jobs[j], &pool)
                        .unwrap_or(f64::INFINITY),
                };
                if est.is_finite() {
                    deadline - ctx.now - ctx.attempt_duration(&ctx.jobs[j], est)
                } else {
                    f64::INFINITY // unplaceable anywhere: the simulator prunes it
                }
            })
            .collect();
        let mut order: Vec<usize> = (0..ctx.queue.len()).collect();
        order.sort_by(|&a, &b| laxity[a].total_cmp(&laxity[b]).then(a.cmp(&b)));
        for pos in order {
            let job = ctx.queue[pos];
            if ctx.index.is_some_and(|ix| ix.known_unplaceable(job)) {
                continue;
            }
            let cand = &ctx.jobs[job];
            if let Some(placement) = ctx.try_place(cand, ctx.free, ctx.n_running) {
                return Some(QueueDecision { queue_pos: pos, placement });
            }
            if let Some(ix) = ctx.index {
                ix.note_unplaceable(job);
            }
        }
        None
    }
}

impl crate::util::registry::Registered for dyn QueuePolicy {
    fn name(&self) -> &str {
        QueuePolicy::name(self)
    }
    fn aliases(&self) -> &'static [&'static str] {
        QueuePolicy::aliases(self)
    }
    fn describe(&self) -> &str {
        self.description()
    }
}

/// An ordered, name-addressed collection of queue policies — a
/// [`crate::util::registry::Registry`] instantiation (uniform
/// resolution semantics; see [`crate::util::registry`]).
///
/// Registration order is preserved; canonical names match
/// case-insensitively; aliases are lowercase. Mirrors
/// [`super::policy::PolicyRegistry`].
pub type QueuePolicyRegistry = crate::util::registry::Registry<dyn QueuePolicy>;

impl QueuePolicyRegistry {
    /// An empty registry (build-your-own line-ups).
    pub fn empty() -> QueuePolicyRegistry {
        crate::util::registry::Registry::new("queue policy")
    }

    /// The built-in disciplines: FIFO, EASY-backfill, SJF, EDF, LLF.
    /// [`crate::learn::LearnedQueue`] is *not* a default — it needs
    /// trained weights, so callers register it explicitly.
    pub fn with_defaults() -> QueuePolicyRegistry {
        let mut r = QueuePolicyRegistry::empty();
        r.register(Arc::new(FifoQueue));
        r.register(Arc::new(EasyBackfill));
        r.register(Arc::new(ShortestJobFirst));
        r.register(Arc::new(EarliestDeadlineFirst));
        r.register(Arc::new(LeastLaxity));
        r
    }
}

impl Default for QueuePolicyRegistry {
    fn default() -> Self {
        QueuePolicyRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceKind;
    use crate::fleet::policy::BestFit;
    use crate::model::ModelSpec;

    /// Oracle for queue tests: a job needs `job.seq` devices (test-local
    /// encoding) and its service time is `job.samples / n_devices`
    /// seconds, so job "size" is directly scriptable.
    struct ScriptedOracle;

    impl PlanOracle for ScriptedOracle {
        fn service_time(&self, job: &Job, devices: &[Device]) -> Option<f64> {
            if devices.len() >= job.seq {
                Some(job.samples as f64 / devices.len() as f64)
            } else {
                None
            }
        }
    }

    fn job(id: usize, need_devices: usize, samples: usize) -> Job {
        let mut j = Job::new(id, 0.0, ModelSpec::tiny(), samples, 2);
        j.seq = need_devices;
        j
    }

    fn devices(ids: &[usize]) -> Vec<Device> {
        ids.iter().map(|&i| Device::new(i, DeviceKind::NanoH)).collect()
    }

    struct Fixture {
        jobs: Vec<Job>,
        queue: VecDeque<usize>,
        free: Vec<Device>,
        running: Vec<RunningSnapshot>,
        done: Vec<f64>,
        deadlines: Vec<f64>,
    }

    impl Fixture {
        fn ctx<'a>(&'a self, ckpt: Option<&'a CheckpointSpec>) -> QueueCtx<'a> {
            QueueCtx {
                jobs: &self.jobs,
                queue: &self.queue,
                free: &self.free,
                present: self.free.len()
                    + self.running.iter().map(|r| r.devices.len()).sum::<usize>(),
                n_running: self.running.len(),
                running: &self.running,
                done: &self.done,
                deadlines: &self.deadlines,
                now: 0.0,
                placement: &BestFit,
                oracle: &ScriptedOracle,
                ckpt,
                index: None,
            }
        }

        /// The same context with an incremental index attached.
        fn ctx_ix<'a>(
            &'a self,
            ckpt: Option<&'a CheckpointSpec>,
            ix: &'a QueueIndex,
        ) -> QueueCtx<'a> {
            QueueCtx { index: Some(ix), ..self.ctx(ckpt) }
        }
    }

    /// Job 0 runs on devices {0,1} until t=1000; device 2 is free. Job 1
    /// (head) needs 3 devices; job 2 is a long 1-device job; job 3 a
    /// short one.
    fn blocked_head_fixture() -> Fixture {
        let jobs = vec![
            job(0, 2, 2000),
            job(1, 3, 3000),
            job(2, 1, 2000), // 2000 s on one device: overruns the shadow
            job(3, 1, 500),  // 500 s: fits before the shadow
        ];
        Fixture {
            jobs,
            queue: VecDeque::from(vec![1, 2, 3]),
            free: devices(&[2]),
            running: vec![RunningSnapshot {
                job: 0,
                finish: 1000.0,
                devices: devices(&[0, 1]),
            }],
            done: vec![0.0; 4],
            deadlines: vec![f64::INFINITY; 4],
        }
    }

    #[test]
    fn fifo_only_attempts_the_head() {
        let f = blocked_head_fixture();
        assert!(FifoQueue.next(&f.ctx(None)).is_none(), "blocked head blocks fifo");
        // placeable head is taken even when shorter jobs wait behind it
        let mut f = blocked_head_fixture();
        f.queue = VecDeque::from(vec![3, 2]);
        let d = FifoQueue.next(&f.ctx(None)).expect("head placeable");
        assert_eq!(d.queue_pos, 0);
        assert_eq!(d.placement.devices.len(), 1);
    }

    #[test]
    fn backfill_takes_short_job_that_fits_before_shadow() {
        let f = blocked_head_fixture();
        let d = EasyBackfill.next(&f.ctx(None)).expect("short job backfills");
        // job 2 (pos 1) would run past t=1000; job 3 (pos 2) fits
        assert_eq!(d.queue_pos, 2);
        assert!((d.placement.service_time - 500.0).abs() < 1e-9);
    }

    #[test]
    fn backfill_prefers_a_placeable_head() {
        let mut f = blocked_head_fixture();
        f.queue = VecDeque::from(vec![3, 2]);
        let d = EasyBackfill.next(&f.ctx(None)).expect("head placeable");
        assert_eq!(d.queue_pos, 0);
    }

    #[test]
    fn backfill_counts_checkpoint_pauses_against_the_shadow() {
        let mut f = blocked_head_fixture();
        // job 3 now takes 980 s of work: fits raw, but not with the
        // checkpoint pause its 2-epoch/k=1 schedule adds
        f.jobs[3].samples = 980;
        let spec = CheckpointSpec::new(1, 60.0);
        assert!(EasyBackfill.next(&f.ctx(Some(&spec))).is_none());
        assert!(EasyBackfill.next(&f.ctx(None)).is_some(), "without ckpt it fits");
    }

    #[test]
    fn backfill_waits_when_head_is_infeasible_on_everything() {
        let mut f = blocked_head_fixture();
        f.jobs[1].seq = 99; // more devices than the pool will ever have
        assert!(
            EasyBackfill.next(&f.ctx(None)).is_none(),
            "no shadow, no backfill: the simulator prunes doomed jobs"
        );
    }

    #[test]
    fn sjf_picks_the_smallest_placeable_job() {
        let mut f = blocked_head_fixture();
        // all three queued jobs placeable on the single free device
        f.jobs[1].seq = 1;
        f.jobs[1].samples = 9000;
        let d = ShortestJobFirst.next(&f.ctx(None)).expect("smallest places");
        assert_eq!(d.queue_pos, 2, "job 3 has the smallest whole-pool estimate");
        // infeasible-estimate jobs sort last but feasible ones still go
        f.jobs[3].seq = 99;
        let d = ShortestJobFirst.next(&f.ctx(None)).expect("next smallest");
        assert_eq!(d.queue_pos, 1, "job 2 is the smallest remaining");
    }

    /// EDF attempts jobs in deadline order, falling through blocked
    /// ones; deadline-less jobs sort last; equal deadlines keep queue
    /// order.
    #[test]
    fn edf_orders_by_deadline_and_skips_blocked() {
        let mut f = blocked_head_fixture();
        // all three queued jobs fit the single free device
        f.jobs[1].seq = 1;
        // head (job 1) has the latest deadline; job 3 the earliest
        f.deadlines = vec![f64::INFINITY, 9000.0, 700.0, 500.0];
        let d = EarliestDeadlineFirst.next(&f.ctx(None)).expect("placeable");
        assert_eq!(d.queue_pos, 2, "job 3 has the earliest deadline");
        // the earliest-deadline job is blocked: EDF falls through to the
        // next deadline instead of idling the device
        f.jobs[3].seq = 99;
        let d = EarliestDeadlineFirst.next(&f.ctx(None)).expect("falls through");
        assert_eq!(d.queue_pos, 1, "job 2 is next by deadline");
        // no deadlines at all: EDF degenerates to first-placeable in
        // queue order
        f.deadlines = vec![f64::INFINITY; 4];
        let d = EarliestDeadlineFirst.next(&f.ctx(None)).expect("queue order");
        assert_eq!(d.queue_pos, 0, "infinite deadlines tie back to queue order");
    }

    /// LLF ranks by slack, not raw deadline: a long job whose deadline
    /// is later but already tight outranks a short comfortable one.
    #[test]
    fn llf_orders_by_slack_not_deadline() {
        let mut f = blocked_head_fixture();
        f.queue = VecDeque::from(vec![2, 3]);
        // whole pool = 3 devices; ScriptedOracle: service = samples/3.
        // job 2: 2000 samples -> est 666.7 s; job 3: 500 -> est 166.7 s.
        // deadlines: job 3 earlier (800) but slack 633; job 2 later
        // (900) but slack 233 -> LLF starts job 2, EDF would pick job 3.
        f.deadlines = vec![f64::INFINITY, f64::INFINITY, 900.0, 800.0];
        let d = LeastLaxity.next(&f.ctx(None)).expect("placeable");
        assert_eq!(d.queue_pos, 0, "job 2 has the least laxity");
        let d = EarliestDeadlineFirst.next(&f.ctx(None)).expect("placeable");
        assert_eq!(d.queue_pos, 1, "EDF disagrees: job 3's deadline is earlier");
        // deadline-less jobs have infinite laxity and go last
        f.deadlines = vec![f64::INFINITY, f64::INFINITY, f64::INFINITY, 800.0];
        let d = LeastLaxity.next(&f.ctx(None)).expect("placeable");
        assert_eq!(d.queue_pos, 1, "the only deadlined job is most urgent");
    }

    /// Every policy's incremental path must pick the same job with the
    /// same placement as its legacy path, including on cache-warm
    /// re-queries (the full-simulation bit-identity check lives in
    /// `tests/prop_invariants.rs`).
    #[test]
    fn incremental_paths_match_legacy_decisions() {
        let policies: Vec<Box<dyn QueuePolicy>> = vec![
            Box::new(EasyBackfill),
            Box::new(ShortestJobFirst),
            Box::new(EarliestDeadlineFirst),
            Box::new(LeastLaxity),
        ];
        let mut f = blocked_head_fixture();
        f.jobs[1].seq = 1; // every queued job fits the free device
        f.deadlines = vec![f64::INFINITY, 9000.0, 700.0, 500.0];
        for p in &policies {
            let legacy =
                p.next(&f.ctx(None)).map(|d| (d.queue_pos, d.placement.service_time));
            let ix = QueueIndex::new();
            for &j in &f.queue {
                ix.on_enqueue_back(j);
            }
            let inc =
                p.next(&f.ctx_ix(None, &ix)).map(|d| (d.queue_pos, d.placement.service_time));
            assert_eq!(legacy, inc, "{}", p.name());
            let warm =
                p.next(&f.ctx_ix(None, &ix)).map(|d| (d.queue_pos, d.placement.service_time));
            assert_eq!(legacy, warm, "{} (cache-warm)", p.name());
        }
    }

    /// The sorted order survives enqueue/dequeue churn via the log and
    /// rebuilds after a pool change.
    #[test]
    fn index_order_syncs_across_queue_changes() {
        let mut f = blocked_head_fixture();
        f.jobs[1].seq = 1;
        f.deadlines = vec![f64::INFINITY, 9000.0, 700.0, 500.0];
        let ix = QueueIndex::new();
        for &j in &f.queue {
            ix.on_enqueue_back(j);
        }
        let d = EarliestDeadlineFirst.next(&f.ctx_ix(None, &ix)).unwrap();
        assert_eq!(d.queue_pos, 2, "job 3 has the earliest deadline");
        // dispatch it: dequeue + state change
        let job = f.queue.remove(2).unwrap();
        ix.on_dequeue(job);
        ix.on_state_change();
        let d = EarliestDeadlineFirst.next(&f.ctx_ix(None, &ix)).unwrap();
        assert_eq!(d.queue_pos, 1, "job 2 (deadline 700) is next");
        // churn-requeue at the front: the pool epoch moved, so the
        // order rebuilds from the live queue
        f.queue.push_front(job);
        ix.on_enqueue_front(job);
        ix.on_pool_change();
        let d = EarliestDeadlineFirst.next(&f.ctx_ix(None, &ix)).unwrap();
        assert_eq!(d.queue_pos, 0, "requeued job 3 still sorts first");
        assert!(ix.rescans_avoided() > 0, "warm queries reused the order");
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        let r = QueuePolicyRegistry::with_defaults();
        assert_eq!(r.names(), vec!["FIFO", "EASY-backfill", "SJF", "EDF", "LLF"]);
        for (query, want) in [
            ("fifo", "FIFO"),
            ("FIFO", "FIFO"),
            ("backfill", "EASY-backfill"),
            ("easy", "EASY-backfill"),
            ("EASY-BACKFILL", "EASY-backfill"),
            ("sjf", "SJF"),
            ("shortest", "SJF"),
            ("edf", "EDF"),
            ("earliest-deadline", "EDF"),
            ("llf", "LLF"),
            ("least-laxity", "LLF"),
        ] {
            assert_eq!(r.get(query).map(|p| p.name()), Some(want), "query {query:?}");
        }
        assert!(r.get("lifo").is_none());
    }

    #[test]
    fn register_replaces_by_name() {
        struct Shadow;
        impl QueuePolicy for Shadow {
            fn name(&self) -> &str {
                "FIFO"
            }
            fn next(&self, _ctx: &QueueCtx) -> Option<QueueDecision> {
                None
            }
        }
        let mut r = QueuePolicyRegistry::with_defaults();
        let n = r.len();
        r.register(Arc::new(Shadow));
        assert_eq!(r.len(), n, "replace, not append");
    }
}
