//! Job arrival and device-churn trace generators.
//!
//! A fleet run is driven by two seeded, deterministic traces:
//!
//! * an **arrival trace** — a stream of personal fine-tuning [`Job`]s
//!   ([`generate_jobs`]) following one of three [`TraceKind`] patterns
//!   (steady Poisson, diurnal day/night modulation, bursty on/off);
//! * a **churn trace** — timed [`ChurnEvent`]s ([`generate_churn`])
//!   under which devices leave the pool, new ones join, or a present
//!   device degrades to its low-power mode mid-run.
//!
//! Both generators are pure functions of their seed (xoshiro256** via
//! [`crate::util::rng::Rng`]), so the same seed always produces the
//! same trace — the foundation of the simulator's bit-identical
//! reproducibility guarantee.

use crate::cluster::{DeviceKind, Env};
use crate::model::ModelSpec;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// Default deadline slack: a job is "on time" within 3× its ideal
/// full-pool service time (see [`crate::fleet::simulate_fleet`] for how
/// the multiplier becomes an absolute deadline).
pub const DEFAULT_DEADLINE_MULT: f64 = 3.0;

/// One personal fine-tuning job: a user's model, dataset and budget.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: usize,
    /// Arrival time on the virtual clock, seconds.
    pub arrival: f64,
    pub model: ModelSpec,
    /// Training samples in the user's dataset.
    pub samples: usize,
    pub epochs: usize,
    pub seq: usize,
    pub minibatch: usize,
    /// Submitting user (several jobs may share one) — the dimension the
    /// per-user SLO/fairness metrics aggregate over.
    pub user: usize,
    /// Deadline slack as a multiple of the job's ideal full-pool
    /// service time; the simulator turns it into an absolute deadline
    /// (`arrival + mult × scale × reference`).
    pub deadline_mult: f64,
}

impl Job {
    pub fn new(id: usize, arrival: f64, model: ModelSpec, samples: usize, epochs: usize) -> Job {
        Job {
            id,
            arrival,
            model,
            samples,
            epochs,
            seq: 128,
            minibatch: 16,
            user: 0,
            deadline_mult: DEFAULT_DEADLINE_MULT,
        }
    }

    /// Builder: assign the submitting user.
    pub fn with_user(mut self, user: usize) -> Job {
        self.user = user;
        self
    }

    /// Builder: override the deadline slack multiplier.
    pub fn with_deadline_mult(mut self, mult: f64) -> Job {
        self.deadline_mult = mult;
        self
    }
}

/// The arrival patterns a shared edge pool sees in practice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Poisson arrivals at a constant rate.
    Steady,
    /// Rate modulated by a 24 h sinusoid (daytime peak, night trough).
    Diurnal,
    /// On/off: quiet stretches punctuated by tight arrival bursts.
    Bursty,
}

impl TraceKind {
    pub const ALL: [TraceKind; 3] = [TraceKind::Steady, TraceKind::Diurnal, TraceKind::Bursty];

    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Steady => "steady",
            TraceKind::Diurnal => "diurnal",
            TraceKind::Bursty => "bursty",
        }
    }

    pub fn parse(s: &str) -> Option<TraceKind> {
        match s.to_ascii_lowercase().as_str() {
            "steady" | "poisson" => Some(TraceKind::Steady),
            "diurnal" | "daily" => Some(TraceKind::Diurnal),
            "bursty" | "burst" => Some(TraceKind::Bursty),
            _ => None,
        }
    }
}

/// Mean gap between arrivals in the steady pattern, seconds.
const MEAN_GAP: f64 = 20.0 * 60.0;

/// Exponential variate with the given mean.
fn expo(rng: &mut Rng, mean: f64) -> f64 {
    -mean * (1.0 - rng.f64()).max(1e-12).ln()
}

/// Sample one job's personal workload: model size, dataset size and
/// epoch budget. Dataset sizes are drawn from power-of-two buckets so
/// repeated shapes share planner work (the simulator memoizes plans by
/// job shape). Each job is stamped with a submitting user from a pool
/// of `n_users` and a deadline slack multiplier in [1.5, 4).
fn sample_job(id: usize, arrival: f64, n_users: usize, rng: &mut Rng) -> Job {
    let model = match rng.range(0, 10) {
        0..=5 => ModelSpec::t5_base(),
        6..=7 => ModelSpec::bart_large(),
        _ => ModelSpec::t5_large(),
    };
    let samples = 512 << rng.range(0, 4); // 512..4096
    let epochs = rng.range(2, 5);
    let user = rng.range(0, n_users.max(1));
    let mult = 1.5 + 2.5 * rng.f64();
    Job::new(id, arrival, model, samples, epochs)
        .with_user(user)
        .with_deadline_mult(mult)
}

/// Generate `n` jobs following `kind`, deterministically from `seed`.
/// Jobs come back sorted by arrival time with ids `0..n`, spread over
/// `max(1, n/5)` users.
pub fn generate_jobs(kind: TraceKind, n: usize, seed: u64) -> Vec<Job> {
    let n_users = (n / 5).max(1);
    let mut rng = Rng::new(seed ^ 0xF1EE7);
    let mut jobs = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut burst_left = 0usize;
    for id in 0..n {
        let gap = match kind {
            TraceKind::Steady => expo(&mut rng, MEAN_GAP),
            TraceKind::Diurnal => {
                // intensity peaks mid-day, bottoms out at night
                let day_phase = (t / 86_400.0) * std::f64::consts::TAU;
                let intensity = 1.0 + 0.9 * day_phase.sin();
                expo(&mut rng, MEAN_GAP) / intensity.max(0.1)
            }
            TraceKind::Bursty => {
                if burst_left > 0 {
                    burst_left -= 1;
                    expo(&mut rng, 60.0)
                } else if rng.range(0, 4) == 0 {
                    burst_left = rng.range(2, 6);
                    expo(&mut rng, 60.0)
                } else {
                    expo(&mut rng, 2.5 * MEAN_GAP)
                }
            }
        };
        t += gap;
        jobs.push(sample_job(id, t, n_users, &mut rng));
    }
    jobs
}

/// One churn action on the shared pool.
///
/// Device ids are explicit everywhere — a `Join` carries the id the new
/// device will have, so a trace means the same thing to every consumer
/// and [`crate::fleet::simulate_fleet`] can validate it up front
/// (joins must be fresh ids, leave/degrade must name a device present
/// at that point of the trace).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnKind {
    /// Device `id` leaves the pool (user walks away, battery dies).
    Leave(usize),
    /// A fresh device with this (unused) id and kind joins the pool.
    Join(usize, DeviceKind),
    /// Device `id` drops to its low-power mode (thermal/battery saver).
    Degrade(usize),
}

/// A timed churn action.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    pub time: f64,
    pub kind: ChurnKind,
}

/// Generate a churn trace over `horizon` seconds against the initial
/// pool of `env`, at roughly `events_per_hour`. The generator tracks a
/// virtual present-set so `Leave`/`Degrade` always name a device that
/// is present at that point of the trace (churn is independent of job
/// activity, so this is exact), and it never shrinks the pool below
/// two devices.
pub fn generate_churn(env: &Env, horizon: f64, events_per_hour: f64, seed: u64) -> Vec<ChurnEvent> {
    let mut rng = Rng::new(seed ^ 0xC4A1B);
    let mut present: Vec<usize> = env.devices.iter().map(|d| d.id).collect();
    let mut next_id = present.iter().max().map(|m| m + 1).unwrap_or(0);
    let mut events = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += expo(&mut rng, 3600.0 / events_per_hour.max(1e-9));
        if t >= horizon {
            break;
        }
        let kind = match rng.range(0, 10) {
            0..=3 if present.len() > 2 => {
                let id = present.remove(rng.range(0, present.len()));
                ChurnKind::Leave(id)
            }
            4..=6 => {
                let kind = *rng.choose(&[DeviceKind::NanoH, DeviceKind::Tx2H]);
                let id = next_id;
                next_id += 1;
                present.push(id);
                ChurnKind::Join(id, kind)
            }
            _ => ChurnKind::Degrade(*rng.choose(&present)),
        };
        events.push(ChurnEvent { time: t, kind });
    }
    events
}

/// Serialize a churn trace as a JSON event list — the
/// `pacpp fleet --churn-file` format, so real availability datasets
/// (or generated traces) can be replayed instead of sampled:
///
/// ```json
/// [
///   {"time": 120.0, "kind": "leave", "id": 3},
///   {"time": 300.0, "kind": "join", "id": 9, "device": "Nano-H"},
///   {"time": 480.0, "kind": "degrade", "id": 1}
/// ]
/// ```
pub fn churn_to_json(events: &[ChurnEvent]) -> Json {
    events
        .iter()
        .map(|e| {
            let mut pairs: Vec<(&str, Json)> = vec![("time", e.time.into())];
            match e.kind {
                ChurnKind::Leave(id) => {
                    pairs.push(("kind", "leave".into()));
                    pairs.push(("id", id.into()));
                }
                ChurnKind::Join(id, kind) => {
                    pairs.push(("kind", "join".into()));
                    pairs.push(("id", id.into()));
                    pairs.push(("device", kind.name().into()));
                }
                ChurnKind::Degrade(id) => {
                    pairs.push(("kind", "degrade".into()));
                    pairs.push(("id", id.into()));
                }
            }
            obj(pairs)
        })
        .collect()
}

/// Parse a churn trace from the [`churn_to_json`] event-list format.
/// Every event needs a finite non-negative `time`, a `kind` of
/// `leave`/`join`/`degrade`, an integer `id`, and (joins only) a
/// `device` kind name; anything else is an error naming the offending
/// event index. Semantic validation (fresh join ids, present
/// leave/degrade targets) stays where it always was, in
/// [`crate::fleet::simulate_fleet`].
pub fn churn_from_json(json: &Json) -> crate::Result<Vec<ChurnEvent>> {
    let arr = json
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("churn trace: expected a JSON array of events"))?;
    let mut events = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let time = e
            .get("time")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("churn trace event {i}: missing numeric \"time\""))?;
        anyhow::ensure!(
            time.is_finite() && time >= 0.0,
            "churn trace event {i}: time {time} must be finite and non-negative"
        );
        let id = e
            .get("id")
            .and_then(Json::as_f64)
            .filter(|v| v.fract() == 0.0 && *v >= 0.0)
            .map(|v| v as usize)
            .ok_or_else(|| {
                anyhow::anyhow!("churn trace event {i}: missing non-negative integer \"id\"")
            })?;
        let kind_str = e
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("churn trace event {i}: missing string \"kind\""))?;
        let kind = match kind_str.to_ascii_lowercase().as_str() {
            "leave" => ChurnKind::Leave(id),
            "degrade" => ChurnKind::Degrade(id),
            "join" => {
                let device = e
                    .get("device")
                    .and_then(Json::as_str)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "churn trace event {i}: join needs a \"device\" kind name"
                        )
                    })?;
                let device_kind = DeviceKind::parse(device).ok_or_else(|| {
                    anyhow::anyhow!(
                        "churn trace event {i}: unknown device kind {device:?} \
                         (nano-h|nano-l|tx2-h|tx2-l)"
                    )
                })?;
                ChurnKind::Join(id, device_kind)
            }
            other => anyhow::bail!(
                "churn trace event {i}: unknown kind {other:?} (leave|join|degrade)"
            ),
        };
        events.push(ChurnEvent { time, kind });
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_sorted_and_deterministic() {
        for kind in TraceKind::ALL {
            let a = generate_jobs(kind, 50, 9);
            let b = generate_jobs(kind, 50, 9);
            assert_eq!(a.len(), 50);
            for w in a.windows(2) {
                assert!(w[0].arrival <= w[1].arrival, "{kind:?} not sorted");
            }
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
                assert_eq!(x.model.name, y.model.name);
                assert_eq!((x.samples, x.epochs), (y.samples, y.epochs));
                assert_eq!(x.user, y.user);
                assert_eq!(x.deadline_mult.to_bits(), y.deadline_mult.to_bits());
            }
            assert_ne!(
                generate_jobs(kind, 50, 10)[0].arrival.to_bits(),
                a[0].arrival.to_bits(),
                "different seeds must differ"
            );
        }
    }

    #[test]
    fn bursty_has_tighter_gaps_than_steady() {
        let min_gap = |jobs: &[Job]| {
            jobs.windows(2).map(|w| w[1].arrival - w[0].arrival).fold(f64::MAX, f64::min)
        };
        let steady = generate_jobs(TraceKind::Steady, 100, 3);
        let bursty = generate_jobs(TraceKind::Bursty, 100, 3);
        assert!(min_gap(&bursty) < min_gap(&steady));
    }

    #[test]
    fn jobs_carry_users_and_deadline_slack() {
        let jobs = generate_jobs(TraceKind::Steady, 40, 17);
        let n_users = 40 / 5;
        for j in &jobs {
            assert!(j.user < n_users, "user {} out of pool", j.user);
            assert!(
                (1.5..4.0).contains(&j.deadline_mult),
                "mult {} outside [1.5, 4)",
                j.deadline_mult
            );
        }
        let mut users: Vec<usize> = jobs.iter().map(|j| j.user).collect();
        users.sort_unstable();
        users.dedup();
        assert!(users.len() >= 2, "40 jobs over 8 users must hit more than one");
        // tiny traces collapse to a single user
        for j in generate_jobs(TraceKind::Bursty, 4, 17) {
            assert_eq!(j.user, 0);
        }
        // builders
        let j = Job::new(0, 0.0, ModelSpec::tiny(), 64, 2)
            .with_user(9)
            .with_deadline_mult(7.5);
        assert_eq!(j.user, 9);
        assert_eq!(j.deadline_mult, 7.5);
    }

    #[test]
    fn trace_kind_parse() {
        assert_eq!(TraceKind::parse("steady"), Some(TraceKind::Steady));
        assert_eq!(TraceKind::parse("DIURNAL"), Some(TraceKind::Diurnal));
        assert_eq!(TraceKind::parse("burst"), Some(TraceKind::Bursty));
        assert_eq!(TraceKind::parse("nope"), None);
    }

    #[test]
    fn churn_is_deterministic_and_names_present_devices() {
        let env = Env::env_a();
        let a = generate_churn(&env, 86_400.0, 4.0, 5);
        let b = generate_churn(&env, 86_400.0, 4.0, 5);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // replay the trace against a virtual present-set: every
        // leave/degrade names a device present at that moment and every
        // join carries a fresh id
        let mut present: Vec<usize> = env.devices.iter().map(|d| d.id).collect();
        for e in &a {
            match e.kind {
                ChurnKind::Leave(id) => {
                    let pos = present.iter().position(|&p| p == id);
                    assert!(pos.is_some(), "leave of absent device {id}");
                    present.remove(pos.unwrap());
                    assert!(present.len() >= 2, "pool shrank below 2");
                }
                ChurnKind::Join(id, _) => {
                    assert!(!present.contains(&id), "join of present device {id}");
                    present.push(id);
                }
                ChurnKind::Degrade(id) => {
                    assert!(present.contains(&id), "degrade of absent device {id}");
                }
            }
        }
    }

    #[test]
    fn churn_respects_horizon() {
        let env = Env::env_a();
        for e in generate_churn(&env, 3600.0, 10.0, 1) {
            assert!(e.time < 3600.0);
        }
    }

    /// The `--churn-file` format: write → parse is the identity, on an
    /// engineered trace and on a generated one (every kind covered).
    #[test]
    fn churn_json_roundtrip() {
        let engineered = vec![
            ChurnEvent { time: 120.0, kind: ChurnKind::Leave(3) },
            ChurnEvent { time: 300.5, kind: ChurnKind::Join(9, DeviceKind::Tx2H) },
            ChurnEvent { time: 480.0, kind: ChurnKind::Degrade(1) },
        ];
        let back = churn_from_json(&churn_to_json(&engineered)).unwrap();
        assert_eq!(back, engineered);

        let env = Env::env_a();
        let generated = generate_churn(&env, 86_400.0, 6.0, 11);
        assert!(!generated.is_empty());
        // through the full text pipeline, like the CLI reads it
        let text = churn_to_json(&generated).to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(churn_from_json(&parsed).unwrap(), generated);
    }

    #[test]
    fn churn_json_rejects_malformed_events() {
        use crate::util::json::Json;
        for (src, needle) in [
            (r#"{"time": 1}"#, "expected a JSON array"),
            (r#"[{"kind": "leave", "id": 1}]"#, "missing numeric \"time\""),
            (r#"[{"time": -5, "kind": "leave", "id": 1}]"#, "non-negative"),
            (r#"[{"time": 1, "kind": "leave"}]"#, "integer \"id\""),
            (r#"[{"time": 1, "kind": "leave", "id": 1.5}]"#, "integer \"id\""),
            (r#"[{"time": 1, "id": 1}]"#, "missing string \"kind\""),
            (r#"[{"time": 1, "kind": "explode", "id": 1}]"#, "unknown kind"),
            (r#"[{"time": 1, "kind": "join", "id": 1}]"#, "needs a \"device\""),
            (
                r#"[{"time": 1, "kind": "join", "id": 1, "device": "a100"}]"#,
                "unknown device kind",
            ),
        ] {
            let err = churn_from_json(&Json::parse(src).unwrap()).unwrap_err().to_string();
            assert!(err.contains(needle), "{src}: {err}");
        }
    }
}
