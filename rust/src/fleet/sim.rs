//! The deterministic discrete-event fleet simulator.
//!
//! A binary-heap event loop over a virtual clock processes three event
//! classes — job arrivals, job completions, and churn — against a
//! mutable device pool. Placement is delegated to a
//! [`PlacementPolicy`]; plan costing is delegated to the
//! [`StrategyOracle`], which resolves every candidate device subset
//! through the existing [`crate::strategy`] registry (the paper's
//! planner + 1F1B schedule simulation + cached-epoch model), so the
//! fleet layer adds queueing and churn semantics without reimplementing
//! any timing.
//!
//! Determinism: events are ordered by `(time, insertion sequence)` with
//! a total order on `f64` times, all interior maps are `BTreeMap`s, and
//! the only randomness lives in the seeded trace generators — the same
//! `(pool, jobs, churn, policy, options)` tuple always produces a
//! bit-identical [`FleetMetrics`] (enforced by a property test).

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::cluster::{Device, DeviceKind, Env};
use crate::model::graph::LayerGraph;
use crate::model::{Method, Precision};
use crate::profiler::Profile;
use crate::sched::training;
use crate::strategy::{ParallelismStrategy, StrategyRegistry, TrainJob};

use super::metrics::FleetMetrics;
use super::policy::{ChurnResponse, PlacementCtx, PlacementPolicy, PlanOracle};
use super::trace::{ChurnEvent, ChurnKind, Job};

/// Knobs of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Registry name of the parallelism strategy used for every
    /// placement plan (`"pac+"`, `"dp"`, ...).
    pub strategy: String,
    /// Virtual-time cutoff, seconds: events beyond it do not run and
    /// unfinished jobs count as incomplete.
    pub horizon: f64,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions { strategy: "pac+".into(), horizon: 48.0 * 3600.0 }
    }
}

/// Plan-costing oracle over a [`ParallelismStrategy`]: service time of
/// a job on a device subset via `strategy.run` (hybrid epoch 1 + cache
/// redistribution + cached epochs), and the churn-migration cost via
/// the same redistribution model. Results are memoized by job shape ×
/// device-kind multiset — device *identity* never affects timing, so
/// repeated shapes (the common case in a fleet) cost one planner call.
pub struct StrategyOracle<'a> {
    strategy: &'a dyn ParallelismStrategy,
    network: crate::cluster::Network,
    service_memo: RefCell<BTreeMap<String, Option<f64>>>,
    migration_memo: RefCell<BTreeMap<String, f64>>,
}

impl<'a> StrategyOracle<'a> {
    pub fn new(strategy: &'a dyn ParallelismStrategy, network: crate::cluster::Network) -> Self {
        StrategyOracle {
            strategy,
            network,
            service_memo: RefCell::new(BTreeMap::new()),
            migration_memo: RefCell::new(BTreeMap::new()),
        }
    }

    fn memo_key(job: &Job, devices: &[Device]) -> String {
        let mut kinds: Vec<&str> = devices.iter().map(|d| d.kind.name()).collect();
        kinds.sort_unstable();
        format!(
            "{}|{}|{}|{}|{}|{}",
            job.model.name,
            job.samples,
            job.epochs,
            job.seq,
            job.minibatch,
            kinds.join(",")
        )
    }

    fn sub_env(&self, devices: &[Device]) -> Env {
        Env {
            name: "fleet-slice".into(),
            // renumber so planner device indices are dense regardless of
            // which pool members were picked
            devices: devices
                .iter()
                .enumerate()
                .map(|(i, d)| Device::new(i, d.kind))
                .collect(),
            network: self.network,
        }
    }

    fn profile(&self, job: &Job) -> Profile {
        Profile::new(LayerGraph::new(job.model.clone()), Method::pa(true), Precision::FP32, job.seq)
    }

    /// Checkpoint/activation-cache migration cost of re-homing `job`
    /// onto `devices` mid-run (§V-B redistribution over the survivors).
    pub fn migration_time(&self, job: &Job, devices: &[Device]) -> f64 {
        let key = Self::memo_key(job, devices);
        if let Some(v) = self.migration_memo.borrow().get(&key) {
            return *v;
        }
        let env = self.sub_env(devices);
        let t = training::redistribution_time(&self.profile(job), &env, job.samples);
        self.migration_memo.borrow_mut().insert(key, t);
        t
    }
}

impl PlanOracle for StrategyOracle<'_> {
    fn service_time(&self, job: &Job, devices: &[Device]) -> Option<f64> {
        if devices.is_empty() {
            return None;
        }
        let key = Self::memo_key(job, devices);
        if let Some(v) = self.service_memo.borrow().get(&key) {
            return *v;
        }
        let env = self.sub_env(devices);
        let tj = TrainJob::new(job.samples, job.epochs, job.seq, job.minibatch);
        let t = self
            .strategy
            .run(&self.profile(job), &env, tj)
            .ok()
            .map(|r| r.total)
            .filter(|t| t.is_finite() && *t > 0.0);
        self.service_memo.borrow_mut().insert(key, t);
        t
    }
}

#[derive(Debug, Clone)]
enum EventKind {
    Arrival(usize),
    Finish { job: usize, token: u64 },
    Churn(ChurnKind),
}

#[derive(Debug, Clone)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Whole-job fraction still outstanding after an attempt ran for
/// `active` seconds. The attempt began with `frac_left` of the job
/// outstanding, spent its first `migration` seconds moving state (no
/// progress), and executes whole-job work at one full job per
/// `service_full` seconds — so progress is measured against the *whole
/// job*, never against the attempt, and repeated churn can never
/// re-charge work a previous replan already preserved.
fn replan_frac_left(frac_left: f64, migration: f64, service_full: f64, active: f64) -> f64 {
    let worked = (active - migration).max(0.0);
    let done = if service_full > 0.0 { worked / service_full } else { frac_left };
    (frac_left - done).clamp(0.0, 1.0)
}

#[derive(Debug, Clone)]
struct RunningJob {
    devices: Vec<usize>,
    /// Start of the current attempt (reset by replans).
    start: f64,
    /// Start of this placement chain (preserved across replans): a
    /// restart discards everything since this instant, progress kept
    /// by intermediate replans included.
    chain_start: f64,
    finish: f64,
    /// Fraction of the whole job still outstanding when this attempt
    /// began: 1.0 on (re)placement, shrinking across replans so that
    /// repeated churn never re-charges work a previous replan already
    /// preserved.
    frac_left: f64,
    /// Migration prefix of this attempt (no job progress during it).
    migration: f64,
    /// Full-job service time quoted for this attempt's device slice.
    service_full: f64,
    token: u64,
}

struct Sim<'a> {
    jobs: &'a [Job],
    policy: &'a dyn PlacementPolicy,
    oracle: StrategyOracle<'a>,
    horizon: f64,

    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    now: f64,

    /// Device id → current kind, for every device present in the pool.
    present: BTreeMap<usize, DeviceKind>,
    /// Device id → running job id, for busy devices.
    assigned: BTreeMap<usize, usize>,
    queue: VecDeque<usize>,
    running: BTreeMap<usize, RunningJob>,
    /// Per-job finish-token generation: stale Finish events are skipped.
    tokens: Vec<u64>,
    pending_joins: usize,

    joined_at: BTreeMap<usize, f64>,
    presence_acc: BTreeMap<usize, f64>,
    busy_since: BTreeMap<usize, f64>,
    busy_acc: BTreeMap<usize, f64>,

    latencies: Vec<f64>,
    failed: usize,
    replans: usize,
    restarts: usize,
    work_lost: f64,
    migration_overhead: f64,
    events: usize,
}

impl Sim<'_> {
    fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Event { time, seq, kind }));
    }

    fn free_devices(&self) -> Vec<Device> {
        self.present
            .iter()
            .filter(|(id, _)| !self.assigned.contains_key(id))
            .map(|(&id, &kind)| Device::new(id, kind))
            .collect()
    }

    fn all_present(&self) -> Vec<Device> {
        self.present.iter().map(|(&id, &kind)| Device::new(id, kind)).collect()
    }

    /// Close a device's busy span and free it.
    fn release(&mut self, id: usize, now: f64) {
        self.assigned.remove(&id);
        if let Some(since) = self.busy_since.remove(&id) {
            *self.busy_acc.entry(id).or_insert(0.0) += now - since;
        }
    }

    fn start_job(&mut self, job: usize, devices: Vec<Device>, service: f64, now: f64) {
        let ids: Vec<usize> = devices.iter().map(|d| d.id).collect();
        for &id in &ids {
            self.assigned.insert(id, job);
            self.busy_since.insert(id, now);
        }
        let token = self.tokens[job];
        self.running.insert(
            job,
            RunningJob {
                devices: ids,
                start: now,
                chain_start: now,
                finish: now + service,
                frac_left: 1.0,
                migration: 0.0,
                service_full: service,
                token,
            },
        );
        self.push(now + service, EventKind::Finish { job, token });
    }

    /// Drain the queue head-of-line: place while the policy accepts,
    /// and fail jobs that can never run (infeasible on the full pool
    /// with no joins pending).
    fn try_dispatch(&mut self, now: f64) {
        loop {
            let Some(&head) = self.queue.front() else { break };
            let free = self.free_devices();
            let ctx = PlacementCtx {
                job: &self.jobs[head],
                free: &free,
                present: self.present.len(),
                running: self.running.len(),
                oracle: &self.oracle,
            };
            if let Some(pl) = self.policy.place(&ctx) {
                self.queue.pop_front();
                self.start_job(head, pl.devices, pl.service_time, now);
                continue;
            }
            let everything = self.all_present();
            if self.pending_joins == 0
                && self.oracle.service_time(&self.jobs[head], &everything).is_none()
            {
                self.queue.pop_front();
                self.failed += 1;
                continue;
            }
            break;
        }
    }

    /// Churn hit a device of running job `job`. `left` is the id of the
    /// device that departed (already released), or `None` for an
    /// in-place degrade.
    fn churn_running_job(&mut self, job: usize, left: Option<usize>, now: f64) {
        let rj = self.running.remove(&job).expect("churned job is running");
        self.tokens[job] += 1; // invalidate the scheduled Finish
        let survivors: Vec<usize> =
            rj.devices.iter().copied().filter(|&d| Some(d) != left).collect();

        if self.policy.on_churn() == ChurnResponse::Replan && !survivors.is_empty() {
            let devices: Vec<Device> = survivors
                .iter()
                .map(|&id| Device::new(id, self.present[&id]))
                .collect();
            if let Some(t_new) = self.oracle.service_time(&self.jobs[job], &devices) {
                let frac_left =
                    replan_frac_left(rj.frac_left, rj.migration, rj.service_full, now - rj.start);
                let migration = self.oracle.migration_time(&self.jobs[job], &devices);
                let remaining = frac_left * t_new + migration;
                self.replans += 1;
                self.migration_overhead += migration;
                let token = self.tokens[job];
                self.running.insert(
                    job,
                    RunningJob {
                        devices: survivors,
                        start: now,
                        chain_start: rj.chain_start,
                        finish: now + remaining,
                        frac_left,
                        migration,
                        service_full: t_new,
                        token,
                    },
                );
                self.push(now + remaining, EventKind::Finish { job, token });
                return;
            }
        }

        // restart: the whole placement chain's work is lost — including
        // progress that intermediate replans had preserved — and the
        // job re-queues ahead of everything else (it has been waiting
        // longest)
        self.restarts += 1;
        self.work_lost += now - rj.chain_start;
        for id in survivors {
            self.release(id, now);
        }
        self.queue.push_front(job);
    }

    fn apply_churn(&mut self, kind: ChurnKind, now: f64) {
        match kind {
            ChurnKind::Join(id, device_kind) => {
                self.present.insert(id, device_kind);
                self.joined_at.insert(id, now);
                self.pending_joins -= 1;
            }
            ChurnKind::Leave(id) => {
                if self.present.remove(&id).is_none() {
                    return;
                }
                if let Some(t0) = self.joined_at.remove(&id) {
                    *self.presence_acc.entry(id).or_insert(0.0) += now - t0;
                }
                let victim = self.assigned.get(&id).copied();
                self.release(id, now);
                if let Some(job) = victim {
                    self.churn_running_job(job, Some(id), now);
                }
            }
            ChurnKind::Degrade(id) => {
                let Some(kind) = self.present.get_mut(&id) else { return };
                let low = kind.low_power();
                if *kind == low {
                    return; // already in the low-power mode
                }
                *kind = low;
                if let Some(&job) = self.assigned.get(&id) {
                    self.churn_running_job(job, None, now);
                }
            }
        }
    }
}

/// Run one fleet simulation: `jobs` (ids must equal their index,
/// arrival-sorted) arrive into a queue, `policy` places them onto the
/// churning pool seeded from `env`, every placement is costed through
/// the strategy named in `opts`, and the run ends when the event queue
/// drains or the horizon closes.
pub fn simulate_fleet(
    env: &Env,
    jobs: &[Job],
    churn: &[ChurnEvent],
    policy: &dyn PlacementPolicy,
    opts: &FleetOptions,
) -> crate::Result<FleetMetrics> {
    let registry = StrategyRegistry::with_defaults();
    let strategy = registry.get(&opts.strategy).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown strategy {:?}; registered: {}",
            opts.strategy,
            registry.names().join(", ")
        )
    })?;
    for (i, j) in jobs.iter().enumerate() {
        anyhow::ensure!(j.id == i, "job ids must equal their index ({} at {i})", j.id);
    }
    // validate the churn trace against the initial pool before running:
    // joins must carry fresh ids and leave/degrade must name a device
    // present at that point of the trace (churn is independent of job
    // activity, so membership is decidable up front) — a mis-authored
    // trace must fail loudly, not silently no-op mid-run
    {
        let mut order: Vec<&ChurnEvent> = churn.iter().collect();
        order.sort_by(|a, b| a.time.total_cmp(&b.time));
        let mut virt: std::collections::BTreeSet<usize> =
            env.devices.iter().map(|d| d.id).collect();
        for e in order {
            match e.kind {
                ChurnKind::Join(id, _) => anyhow::ensure!(
                    virt.insert(id),
                    "churn trace: join of already-present device id {id}"
                ),
                ChurnKind::Leave(id) => anyhow::ensure!(
                    virt.remove(&id),
                    "churn trace: leave of absent device id {id}"
                ),
                ChurnKind::Degrade(id) => anyhow::ensure!(
                    virt.contains(&id),
                    "churn trace: degrade of absent device id {id}"
                ),
            }
        }
    }

    let mut sim = Sim {
        jobs,
        policy,
        oracle: StrategyOracle::new(strategy.as_ref(), env.network),
        horizon: opts.horizon,
        heap: BinaryHeap::new(),
        seq: 0,
        now: 0.0,
        present: env.devices.iter().map(|d| (d.id, d.kind)).collect(),
        assigned: BTreeMap::new(),
        queue: VecDeque::new(),
        running: BTreeMap::new(),
        tokens: vec![0; jobs.len()],
        pending_joins: churn
            .iter()
            .filter(|e| matches!(e.kind, ChurnKind::Join(..)))
            .count(),
        joined_at: env.devices.iter().map(|d| (d.id, 0.0)).collect(),
        presence_acc: BTreeMap::new(),
        busy_since: BTreeMap::new(),
        busy_acc: BTreeMap::new(),
        latencies: Vec::new(),
        failed: 0,
        replans: 0,
        restarts: 0,
        work_lost: 0.0,
        migration_overhead: 0.0,
        events: 0,
    };
    for job in jobs {
        sim.push(job.arrival, EventKind::Arrival(job.id));
    }
    for e in churn {
        sim.push(e.time, EventKind::Churn(e.kind));
    }

    let mut hit_horizon = false;
    while let Some(Reverse(ev)) = sim.heap.pop() {
        if ev.time > sim.horizon {
            hit_horizon = true;
            break;
        }
        sim.now = ev.time;
        sim.events += 1;
        match ev.kind {
            EventKind::Arrival(id) => sim.queue.push_back(id),
            EventKind::Finish { job, token } => {
                if sim.tokens[job] != token {
                    continue; // superseded by a replan or restart
                }
                let rj = sim.running.remove(&job).expect("finished job is running");
                for id in rj.devices {
                    sim.release(id, ev.time);
                }
                sim.latencies.push(ev.time - sim.jobs[job].arrival);
            }
            EventKind::Churn(kind) => sim.apply_churn(kind, ev.time),
        }
        sim.try_dispatch(ev.time);
    }

    let end = if hit_horizon { sim.horizon } else { sim.now };
    // close open presence/busy spans at the end of virtual time
    let open_busy: Vec<usize> = sim.busy_since.keys().copied().collect();
    for id in open_busy {
        if let Some(since) = sim.busy_since.remove(&id) {
            *sim.busy_acc.entry(id).or_insert(0.0) += end - since;
        }
    }
    let still_present: Vec<usize> = sim.joined_at.keys().copied().collect();
    for id in still_present {
        if let Some(t0) = sim.joined_at.remove(&id) {
            *sim.presence_acc.entry(id).or_insert(0.0) += end - t0;
        }
    }
    let per_device: Vec<(usize, f64, f64)> = sim
        .presence_acc
        .iter()
        .map(|(&id, &presence)| {
            (id, sim.busy_acc.get(&id).copied().unwrap_or(0.0), presence)
        })
        .collect();

    let completed = sim.latencies.len();
    Ok(FleetMetrics::assemble(
        sim.latencies,
        sim.failed,
        jobs.len() - completed - sim.failed,
        end,
        per_device,
        sim.replans,
        sim.restarts,
        sim.work_lost,
        sim.migration_overhead,
        sim.events,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::policy::{BestFit, FifoExclusive, PreemptReplan};
    use crate::fleet::trace::{generate_churn, generate_jobs, TraceKind};
    use crate::model::ModelSpec;

    fn small_jobs(n: usize) -> Vec<Job> {
        // uniform small jobs: one planner call, fast tests
        (0..n)
            .map(|i| Job::new(i, i as f64 * 600.0, ModelSpec::t5_base(), 512, 2))
            .collect()
    }

    #[test]
    fn drains_all_jobs_without_churn() {
        let env = Env::env_a();
        let jobs = small_jobs(8);
        for policy in [&FifoExclusive as &dyn PlacementPolicy, &BestFit, &PreemptReplan] {
            let m =
                simulate_fleet(&env, &jobs, &[], policy, &FleetOptions::default()).unwrap();
            assert_eq!(m.completed, 8, "{}", policy.name());
            assert_eq!(m.failed + m.incomplete, 0, "{}", policy.name());
            assert!(m.jobs_per_hour > 0.0);
            assert!(m.latency_p50.unwrap() <= m.latency_p99.unwrap());
            assert!(m.utilization > 0.0 && m.utilization <= 1.0);
            assert_eq!(m.replans + m.restarts, 0);
            assert!(m.events >= 16, "arrival+finish per job");
        }
    }

    #[test]
    fn best_fit_runs_jobs_concurrently() {
        let env = Env::env_a();
        // all jobs arrive at once: exclusive runs them serially,
        // best-fit packs them side by side
        let jobs: Vec<Job> = (0..4)
            .map(|i| Job::new(i, 0.0, ModelSpec::t5_base(), 512, 2))
            .collect();
        let opts = FleetOptions::default();
        let fifo = simulate_fleet(&env, &jobs, &[], &FifoExclusive, &opts).unwrap();
        let bf = simulate_fleet(&env, &jobs, &[], &BestFit, &opts).unwrap();
        assert_eq!(fifo.completed, 4);
        assert_eq!(bf.completed, 4);
        assert!(
            bf.latency_p99.unwrap() < fifo.latency_p99.unwrap(),
            "multi-tenant packing must cut tail latency: bf {:?} fifo {:?}",
            bf.latency_p99,
            fifo.latency_p99
        );
    }

    #[test]
    fn invalid_churn_trace_is_rejected() {
        let env = Env::env_a(); // device ids 0..=3
        let jobs = small_jobs(1);
        for (churn, want) in [
            (ChurnKind::Leave(99), "leave of absent"),
            (ChurnKind::Join(0, DeviceKind::NanoH), "join of already-present"),
            (ChurnKind::Degrade(7), "degrade of absent"),
        ] {
            let trace = vec![ChurnEvent { time: 10.0, kind: churn }];
            let err = simulate_fleet(&env, &jobs, &trace, &BestFit, &FleetOptions::default())
                .unwrap_err()
                .to_string();
            assert!(err.contains(want), "{churn:?}: {err}");
        }
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let env = Env::env_a();
        let err = simulate_fleet(
            &env,
            &small_jobs(1),
            &[],
            &BestFit,
            &FleetOptions { strategy: "zero-3".into(), ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown strategy"), "{err}");
    }

    #[test]
    fn horizon_cuts_the_run() {
        let env = Env::env_a();
        let jobs = small_jobs(12);
        let m = simulate_fleet(
            &env,
            &jobs,
            &[],
            &FifoExclusive,
            &FleetOptions { horizon: 1800.0, ..Default::default() },
        )
        .unwrap();
        assert!(m.completed < 12);
        assert_eq!(m.completed + m.incomplete + m.failed, 12);
        assert!(m.makespan <= 1800.0);
    }

    #[test]
    fn infeasible_job_fails_instead_of_hanging() {
        // T5-Large full pool of ONE Nano cannot host under PA either
        let env = Env::standalone(crate::cluster::DeviceKind::NanoH);
        let jobs = vec![Job::new(0, 0.0, ModelSpec::t5_large(), 4096, 3)];
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &FleetOptions::default()).unwrap();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 0);
    }

    /// Generated churn keeps every accounting invariant (the *engineered*
    /// churn scenarios that pin exact replan/restart behavior live in
    /// `tests/fleet.rs`, where the hit is constructed, not sampled).
    #[test]
    fn generated_churn_keeps_invariants() {
        let env = Env::env_a();
        let jobs = generate_jobs(TraceKind::Steady, 20, 11);
        let churn = generate_churn(&env, 48.0 * 3600.0, 2.0, 11);
        let opts = FleetOptions::default();
        for policy in [&FifoExclusive as &dyn PlacementPolicy, &PreemptReplan] {
            let m = simulate_fleet(&env, &jobs, &churn, policy, &opts).unwrap();
            assert_eq!(
                m.completed + m.failed + m.incomplete,
                20,
                "{}: every job accounted for: {m:?}",
                policy.name()
            );
            assert!(m.completed > 0, "{}: {m:?}", policy.name());
            assert!(m.work_lost >= 0.0 && m.work_lost.is_finite());
            assert!(m.migration_overhead >= 0.0 && m.migration_overhead.is_finite());
            assert!(m.utilization >= 0.0 && m.utilization <= 1.0, "{m:?}");
            for (_, u) in &m.per_device_util {
                assert!(*u >= 0.0 && *u <= 1.0 + 1e-9, "{m:?}");
            }
        }
    }

    /// Regression: progress must be measured against the whole job, not
    /// the current attempt — a second replan used to re-charge work the
    /// first replan had already preserved.
    #[test]
    fn replan_fraction_does_not_compound() {
        // attempt 1: no migration, full job takes 100 s, churn at 50 s
        let f1 = replan_frac_left(1.0, 0.0, 100.0, 50.0);
        assert!((f1 - 0.5).abs() < 1e-12);
        // attempt 2: 10 s migration, full job now 80 s, churn 30 s in:
        // 20 s of work = 0.25 of the whole job -> 0.25 left
        let f2 = replan_frac_left(f1, 10.0, 80.0, 30.0);
        assert!((f2 - 0.25).abs() < 1e-12, "got {f2}");
        // the old attempt-relative formula would have kept
        // 1 - 30/(0.5*80 + 10) = 0.4 of the job outstanding
        assert!((f2 - 0.4).abs() > 0.1);
        // churn during the migration prefix makes no progress
        assert_eq!(replan_frac_left(0.5, 10.0, 80.0, 5.0), 0.5);
        // and the fraction never goes negative
        assert_eq!(replan_frac_left(0.1, 0.0, 100.0, 500.0), 0.0);
    }

    #[test]
    fn same_seed_bit_identical() {
        let env = Env::env_b();
        let jobs = generate_jobs(TraceKind::Bursty, 15, 21);
        let churn = generate_churn(&env, 48.0 * 3600.0, 3.0, 21);
        let opts = FleetOptions::default();
        let a = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
        let b = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
        assert_eq!(a, b);
    }
}
