//! The deterministic discrete-event fleet simulator.
//!
//! An event loop over a virtual clock (a pluggable [`EventQueue`] —
//! calendar queue by default, the original binary heap behind
//! [`FleetOptions::event_queue`]) processes three event classes — job
//! arrivals, job completions, and churn — against a
//! mutable device pool. *Which* queued job runs next is delegated to a
//! [`QueuePolicy`] (FIFO / EASY-backfill / SJF, resolved by name from
//! [`FleetOptions::queue`]); *how* it claims devices is delegated to a
//! [`PlacementPolicy`]; plan costing is delegated to the
//! [`StrategyOracle`], which resolves every candidate device subset
//! through the existing [`crate::strategy`] registry (the paper's
//! planner + 1F1B schedule simulation + cached-epoch model), so the
//! fleet layer adds queueing, deadline and churn semantics without
//! reimplementing any timing.
//!
//! Deadlines: every job's absolute deadline is `arrival +
//! deadline_mult × deadline_scale × reference`, where the reference is
//! the oracle's quote for the job on the *initial full pool* — the
//! fastest service the fleet could ever have given it — so deadline
//! attainment measures queueing/sharing/churn delay, not model size.
//! Checkpointing ([`CheckpointSpec`]) bounds what a churn-forced
//! restart loses to one checkpoint interval (see [`super::ckpt`]).
//!
//! Determinism: events are ordered by `(time, insertion sequence)` with
//! a total order on `f64` times, all interior maps are `BTreeMap`s, and
//! the only randomness lives in the seeded trace generators — the same
//! `(pool, jobs, churn, policies, options)` tuple always produces a
//! bit-identical [`FleetMetrics`] (enforced by a property test).

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

use crate::cluster::{Device, DeviceKind, Env};
use crate::model::graph::LayerGraph;
use crate::model::{Method, Precision};
use crate::obs::{Counter, Metrics, Observer, PhaseGuard};
use crate::profiler::Profile;
use crate::sched::training;
use crate::strategy::{ParallelismStrategy, StrategyRegistry, TrainJob};

use super::ckpt::{AttemptTimeline, CheckpointSpec};
use super::eventq::{EventQueue, EventQueueKind};
use super::metrics::{FleetMetrics, JobStat, RawFleet};
use super::policy::{ChurnResponse, PlacementPolicy, PlanOracle};
use super::queue::{QueueCtx, QueueIndex, QueuePolicy, QueuePolicyRegistry, RunningSnapshot};
use super::trace::{ChurnEvent, ChurnKind, Job};

/// Knobs of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Registry name of the parallelism strategy used for every
    /// placement plan (`"pac+"`, `"dp"`, ...).
    pub strategy: String,
    /// Virtual-time cutoff, seconds: events beyond it do not run and
    /// unfinished jobs count as incomplete.
    pub horizon: f64,
    /// Registry name of the queueing discipline (`"fifo"`,
    /// `"backfill"`, `"sjf"` — see [`QueuePolicyRegistry`]).
    pub queue: String,
    /// Global multiplier on every job's deadline slack; `<= 0` disables
    /// deadlines (every job gets an infinite one, so goodput equals
    /// throughput).
    pub deadline_scale: f64,
    /// Checkpoint-interval model; `None` means churn restarts lose the
    /// whole placement chain.
    pub ckpt: Option<CheckpointSpec>,
    /// Event-queue implementation (scaling knob): the calendar queue
    /// by default, the original binary heap for the equivalence tests.
    /// Both produce bit-identical runs (property-tested).
    pub event_queue: EventQueueKind,
    /// Maintain the incremental dispatch index ([`QueueIndex`]) so
    /// EASY/SJF/EDF/LLF avoid full-queue rescans/re-sorts per dispatch
    /// (scaling knob). `false` runs the exact legacy policy paths;
    /// dispatch sequences are bit-identical either way
    /// (property-tested).
    pub incremental_queue: bool,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            strategy: "pac+".into(),
            horizon: 48.0 * 3600.0,
            queue: "fifo".into(),
            deadline_scale: 1.0,
            ckpt: None,
            event_queue: EventQueueKind::default(),
            incremental_queue: true,
        }
    }
}

/// Plan-costing oracle over a [`ParallelismStrategy`]: service time of
/// a job on a device subset via `strategy.run` (hybrid epoch 1 + cache
/// redistribution + cached epochs), and the churn-migration cost via
/// the same redistribution model. Results are memoized by job shape ×
/// device-kind multiset — device *identity* never affects timing, so
/// repeated shapes (the common case in a fleet) cost one planner call.
pub struct StrategyOracle<'a> {
    strategy: &'a dyn ParallelismStrategy,
    network: crate::cluster::Network,
    service_memo: RefCell<BTreeMap<String, Option<f64>>>,
    migration_memo: RefCell<BTreeMap<String, f64>>,
    hits: Counter,
    misses: Counter,
    /// Wall-clock observer for the miss path (the actual plan search);
    /// `None` skips the phase timer entirely.
    obs: Option<&'a Observer>,
}

impl<'a> StrategyOracle<'a> {
    pub fn new(strategy: &'a dyn ParallelismStrategy, network: crate::cluster::Network) -> Self {
        StrategyOracle {
            strategy,
            network,
            service_memo: RefCell::new(BTreeMap::new()),
            migration_memo: RefCell::new(BTreeMap::new()),
            hits: Counter::new(),
            misses: Counter::new(),
            obs: None,
        }
    }

    /// Attach an [`Observer`]: memo misses (planner calls) run under
    /// its `plan_search` wall-clock phase timer.
    pub fn observed(mut self, obs: &'a Observer) -> Self {
        self.obs = Some(obs);
        self
    }

    /// The memo-hit counter, for adoption into a run's
    /// [`Metrics`] registry (`oracle_hits`).
    pub fn hits_counter(&self) -> &Counter {
        &self.hits
    }

    /// The memo-miss counter (`oracle_misses`).
    pub fn misses_counter(&self) -> &Counter {
        &self.misses
    }

    /// Observe counters: memo `(hits, misses)` across both the service
    /// and migration memos — how many planner calls the shape
    /// memoization saved this run.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.hits.get() as usize, self.misses.get() as usize)
    }

    /// A `plan_search` wall-clock guard (no-op without an observer).
    fn plan_timer(&self) -> PhaseGuard<'_> {
        match self.obs {
            Some(o) => o.timer("plan_search"),
            None => PhaseGuard::noop(),
        }
    }

    fn memo_key(job: &Job, devices: &[Device]) -> String {
        let mut kinds: Vec<&str> = devices.iter().map(|d| d.kind.name()).collect();
        kinds.sort_unstable();
        format!(
            "{}|{}|{}|{}|{}|{}",
            job.model.name,
            job.samples,
            job.epochs,
            job.seq,
            job.minibatch,
            kinds.join(",")
        )
    }

    fn sub_env(&self, devices: &[Device]) -> Env {
        Env {
            name: "fleet-slice".into(),
            // renumber so planner device indices are dense regardless of
            // which pool members were picked
            devices: devices
                .iter()
                .enumerate()
                .map(|(i, d)| Device::new(i, d.kind))
                .collect(),
            network: self.network,
        }
    }

    fn profile(&self, job: &Job) -> Profile {
        Profile::new(LayerGraph::new(job.model.clone()), Method::pa(true), Precision::FP32, job.seq)
    }

    /// Checkpoint/activation-cache migration cost of re-homing `job`
    /// onto `devices` mid-run (§V-B redistribution over the survivors).
    pub fn migration_time(&self, job: &Job, devices: &[Device]) -> f64 {
        let key = Self::memo_key(job, devices);
        if let Some(v) = self.migration_memo.borrow().get(&key) {
            self.hits.inc();
            return *v;
        }
        self.misses.inc();
        let _plan = self.plan_timer();
        let env = self.sub_env(devices);
        let t = training::redistribution_time(&self.profile(job), &env, job.samples);
        self.migration_memo.borrow_mut().insert(key, t);
        t
    }
}

impl PlanOracle for StrategyOracle<'_> {
    fn service_time(&self, job: &Job, devices: &[Device]) -> Option<f64> {
        if devices.is_empty() {
            return None;
        }
        let key = Self::memo_key(job, devices);
        if let Some(v) = self.service_memo.borrow().get(&key) {
            self.hits.inc();
            return *v;
        }
        self.misses.inc();
        let _plan = self.plan_timer();
        let env = self.sub_env(devices);
        let tj = TrainJob::new(job.samples, job.epochs, job.seq, job.minibatch);
        let t = self
            .strategy
            .run(&self.profile(job), &env, tj)
            .ok()
            .map(|r| r.total)
            .filter(|t| t.is_finite() && *t > 0.0);
        self.service_memo.borrow_mut().insert(key, t);
        t
    }
}

#[derive(Debug, Clone)]
enum EventKind {
    Arrival(usize),
    Finish { job: usize, token: u64 },
    Churn(ChurnKind),
}

#[derive(Debug, Clone)]
struct RunningJob {
    devices: Vec<usize>,
    /// Start of the current attempt (reset by replans).
    start: f64,
    /// Start of this placement chain (preserved across replans): an
    /// un-checkpointed restart discards everything since this instant,
    /// progress kept by intermediate replans included.
    chain_start: f64,
    finish: f64,
    /// Fraction of the whole job still outstanding when this attempt
    /// began (1 − durable progress on placement, shrinking across
    /// replans so that repeated churn never re-charges work a previous
    /// replan already preserved).
    frac_left: f64,
    /// Migration prefix of this attempt (no job progress during it).
    migration: f64,
    /// Full-job service time quoted for this attempt's device slice.
    service_full: f64,
    token: u64,
}

struct Sim<'a> {
    jobs: &'a [Job],
    policy: &'a dyn PlacementPolicy,
    queue_policy: &'a dyn QueuePolicy,
    oracle: StrategyOracle<'a>,
    horizon: f64,
    ckpt: Option<CheckpointSpec>,

    /// The event queue, `(time, seq)`-ordered behind the
    /// [`EventQueue`] trait ([`FleetOptions::event_queue`]).
    eventq: Box<dyn EventQueue<EventKind>>,
    /// Incremental dispatch state handed to the queue policies
    /// (`None` = exact legacy dispatch paths).
    index: Option<QueueIndex>,
    seq: u64,
    now: f64,

    /// Device id → current kind, for every device present in the pool.
    present: BTreeMap<usize, DeviceKind>,
    /// Device id → running job id, for busy devices.
    assigned: BTreeMap<usize, usize>,
    queue: VecDeque<usize>,
    running: BTreeMap<usize, RunningJob>,
    /// Per-job finish-token generation: stale Finish events are skipped.
    tokens: Vec<u64>,
    pending_joins: usize,
    /// Churn has changed the pool since the last full-queue
    /// feasibility sweep. Feasibility-on-the-full-pool only moves when
    /// the pool does, so the sweep (O(queue) oracle lookups) runs once
    /// per churn burst instead of on every dispatch stall — the
    /// backlog can be thousands of jobs.
    pool_dirty: bool,

    joined_at: BTreeMap<usize, f64>,
    presence_acc: BTreeMap<usize, f64>,
    busy_since: BTreeMap<usize, f64>,
    busy_acc: BTreeMap<usize, f64>,
    /// User id → device-seconds consumed by that user's jobs.
    user_service: BTreeMap<usize, f64>,

    /// Per-job absolute deadlines (`INFINITY` = none).
    deadlines: Vec<f64>,
    /// Per-job durable progress: the last *completed* checkpoint
    /// (always 0.0 when checkpointing is off).
    ckpt_frac: Vec<f64>,
    first_start: Vec<Option<f64>>,
    finish_at: Vec<Option<f64>>,

    failed: usize,
    replans: usize,
    restarts: usize,
    work_lost: f64,
    migration_overhead: f64,
    ckpt_count: usize,
    ckpt_overhead: f64,
    /// Events processed, registered as `events` in the run's
    /// [`Metrics`] registry.
    events: Counter,
    /// Trace/timer sink (a disabled observer is one branch per call).
    obs: &'a Observer,
}

impl Sim<'_> {
    fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.eventq.push(time, seq, kind);
    }

    fn free_devices(&self) -> Vec<Device> {
        self.present
            .iter()
            .filter(|(id, _)| !self.assigned.contains_key(id))
            .map(|(&id, &kind)| Device::new(id, kind))
            .collect()
    }

    fn all_present(&self) -> Vec<Device> {
        self.present.iter().map(|(&id, &kind)| Device::new(id, kind)).collect()
    }

    /// The attempt timeline of a running job (checkpoint boundaries
    /// included) — the single source of progress/overhead arithmetic.
    /// `ckpt_frac[job]` is only advanced when an attempt *ends*, so at
    /// any point during (or when measuring) an attempt it still holds
    /// the durable fraction the attempt was scheduled with — including
    /// a boundary whose pause churn interrupted, which the attempt
    /// retakes (see [`AttemptTimeline::new`]).
    fn timeline(&self, job: usize, rj: &RunningJob) -> AttemptTimeline {
        AttemptTimeline::new(
            1.0 - rj.frac_left,
            self.ckpt_frac[job],
            rj.migration,
            rj.service_full,
            self.jobs[job].epochs,
            self.ckpt.as_ref(),
        )
    }

    /// Close a device's busy span, attribute it to the owning user, and
    /// free the device.
    fn release(&mut self, id: usize, now: f64) {
        let job = self.assigned.remove(&id);
        if let Some(since) = self.busy_since.remove(&id) {
            let span = now - since;
            *self.busy_acc.entry(id).or_insert(0.0) += span;
            if let Some(job) = job {
                *self.user_service.entry(self.jobs[job].user).or_insert(0.0) += span;
            }
        }
    }

    fn start_job(&mut self, job: usize, devices: Vec<Device>, service: f64, now: f64) {
        if let Some(ix) = &self.index {
            ix.on_state_change(); // the free/running sets are moving
        }
        let ids: Vec<usize> = devices.iter().map(|d| d.id).collect();
        for &id in &ids {
            self.assigned.insert(id, job);
            self.busy_since.insert(id, now);
        }
        if self.first_start[job].is_none() {
            self.first_start[job] = Some(now);
        }
        self.obs.instant("fleet.job", "dispatch", job as u64, now);
        let token = self.tokens[job];
        let rj = RunningJob {
            devices: ids,
            start: now,
            chain_start: now,
            // resume from the last durable checkpoint (1.0 outstanding
            // when checkpointing is off or nothing is durable yet)
            frac_left: 1.0 - self.ckpt_frac[job],
            finish: 0.0,
            migration: 0.0,
            service_full: service,
            token,
        };
        let finish = now + self.timeline(job, &rj).duration();
        self.running.insert(job, RunningJob { finish, ..rj });
        self.push(finish, EventKind::Finish { job, token });
    }

    /// Let the queue policy pick jobs while it can, and fail jobs that
    /// can never run (infeasible on the full pool with no joins
    /// pending) — checked across the entire queue, not just the head,
    /// so non-head-of-line orders cannot hide a doomed job. Arrivals
    /// are vetted up front and the pool only moves under churn, so the
    /// sweep is gated on [`Sim::pool_dirty`].
    fn try_dispatch(&mut self, now: f64) {
        loop {
            if self.queue.is_empty() {
                break;
            }
            let decision = {
                let free = self.free_devices();
                // the snapshot clones device lists; FIFO never reads it,
                // so the hottest loop skips building it entirely
                let mut running: Vec<RunningSnapshot> = Vec::new();
                if self.queue_policy.wants_running() {
                    running = self
                        .running
                        .iter()
                        .map(|(&job, rj)| RunningSnapshot {
                            job,
                            finish: rj.finish,
                            devices: rj
                                .devices
                                .iter()
                                .map(|&id| Device::new(id, self.present[&id]))
                                .collect(),
                        })
                        .collect();
                    running
                        .sort_by(|a, b| a.finish.total_cmp(&b.finish).then(a.job.cmp(&b.job)));
                }
                let ctx = QueueCtx {
                    jobs: self.jobs,
                    queue: &self.queue,
                    free: &free,
                    present: self.present.len(),
                    n_running: self.running.len(),
                    running: &running,
                    done: &self.ckpt_frac,
                    deadlines: &self.deadlines,
                    now,
                    placement: self.policy,
                    oracle: &self.oracle,
                    ckpt: self.ckpt.as_ref(),
                    index: self.index.as_ref(),
                };
                self.queue_policy.next(&ctx)
            };
            if let Some(d) = decision {
                let job = self.queue.remove(d.queue_pos).expect("queue decision in range");
                if let Some(ix) = &self.index {
                    ix.on_dequeue(job);
                }
                self.start_job(job, d.placement.devices, d.placement.service_time, now);
                continue;
            }
            if self.pending_joins == 0 && self.pool_dirty {
                self.pool_dirty = false;
                let everything = self.all_present();
                let doomed: Vec<usize> = self
                    .queue
                    .iter()
                    .copied()
                    .filter(|&j| self.oracle.service_time(&self.jobs[j], &everything).is_none())
                    .collect();
                if !doomed.is_empty() {
                    self.failed += doomed.len();
                    self.queue.retain(|j| !doomed.contains(j));
                    if let Some(ix) = &self.index {
                        for &j in &doomed {
                            ix.on_dequeue(j);
                        }
                    }
                    continue;
                }
            }
            break;
        }
    }

    /// Churn hit a device of running job `job`. `left` is the id of the
    /// device that departed (already released), or `None` for an
    /// in-place degrade.
    fn churn_running_job(&mut self, job: usize, left: Option<usize>, now: f64) {
        self.obs.instant("fleet.job", "preempt", job as u64, now);
        let rj = self.running.remove(&job).expect("churned job is running");
        self.tokens[job] += 1; // invalidate the scheduled Finish
        let survivors: Vec<usize> =
            rj.devices.iter().copied().filter(|&d| Some(d) != left).collect();

        // measure the aborted attempt: progress made, checkpoints that
        // completed (now durable), and checkpoint time spent
        let point = self.timeline(job, &rj).at(now - rj.start);
        self.ckpt_count += point.ckpts;
        self.ckpt_overhead += point.ckpt_time;
        if let Some(b) = point.last_ckpt {
            self.ckpt_frac[job] = self.ckpt_frac[job].max(b);
        }

        if self.policy.on_churn() == ChurnResponse::Replan && !survivors.is_empty() {
            let devices: Vec<Device> = survivors
                .iter()
                .map(|&id| Device::new(id, self.present[&id]))
                .collect();
            if let Some(t_new) = self.oracle.service_time(&self.jobs[job], &devices) {
                let migration = self.oracle.migration_time(&self.jobs[job], &devices);
                self.replans += 1;
                self.migration_overhead += migration;
                let token = self.tokens[job];
                let next = RunningJob {
                    devices: survivors,
                    start: now,
                    chain_start: rj.chain_start,
                    finish: 0.0,
                    // a replan keeps the live progress (durable or not)
                    frac_left: 1.0 - point.progress,
                    migration,
                    service_full: t_new,
                    token,
                };
                let finish = now + self.timeline(job, &next).duration();
                self.running.insert(job, RunningJob { finish, ..next });
                self.push(finish, EventKind::Finish { job, token });
                return;
            }
        }

        // restart: without checkpointing the whole placement chain's
        // work is lost — including progress intermediate replans had
        // preserved; with it, only the work since the last durable
        // checkpoint (expressed at this attempt's service rate). The
        // job re-queues ahead of everything else (it has been waiting
        // longest).
        self.restarts += 1;
        self.obs.instant("fleet.job", "restart", job as u64, now);
        if self.ckpt.is_some() {
            self.work_lost +=
                (point.progress - self.ckpt_frac[job]).max(0.0) * rj.service_full;
        } else {
            self.work_lost += now - rj.chain_start;
        }
        for id in survivors {
            self.release(id, now);
        }
        self.queue.push_front(job);
        if let Some(ix) = &self.index {
            ix.on_enqueue_front(job);
        }
    }

    fn apply_churn(&mut self, kind: ChurnKind, now: f64) {
        self.pool_dirty = true;
        if let Some(ix) = &self.index {
            ix.on_pool_change(); // pool-keyed estimates and orders are stale
        }
        match kind {
            ChurnKind::Join(id, device_kind) => {
                self.present.insert(id, device_kind);
                self.joined_at.insert(id, now);
                self.pending_joins -= 1;
            }
            ChurnKind::Leave(id) => {
                if self.present.remove(&id).is_none() {
                    return;
                }
                if let Some(t0) = self.joined_at.remove(&id) {
                    *self.presence_acc.entry(id).or_insert(0.0) += now - t0;
                }
                let victim = self.assigned.get(&id).copied();
                self.release(id, now);
                if let Some(job) = victim {
                    self.churn_running_job(job, Some(id), now);
                }
            }
            ChurnKind::Degrade(id) => {
                let Some(kind) = self.present.get_mut(&id) else { return };
                let low = kind.low_power();
                if *kind == low {
                    return; // already in the low-power mode
                }
                *kind = low;
                if let Some(&job) = self.assigned.get(&id) {
                    self.churn_running_job(job, None, now);
                }
            }
        }
    }
}

/// Run one fleet simulation: `jobs` (ids must equal their index,
/// arrival-sorted) arrive into a queue ordered by the discipline named
/// in `opts.queue`, `policy` places them onto the churning pool seeded
/// from `env`, every placement is costed through the strategy named in
/// `opts`, and the run ends when the event queue drains or the horizon
/// closes.
pub fn simulate_fleet(
    env: &Env,
    jobs: &[Job],
    churn: &[ChurnEvent],
    policy: &dyn PlacementPolicy,
    opts: &FleetOptions,
) -> crate::Result<FleetMetrics> {
    simulate_fleet_observed(env, jobs, churn, policy, opts, &Observer::disabled())
}

/// [`simulate_fleet`] under an explicit [`Observer`]: job-lifecycle
/// trace events (enqueue → dispatch → preempt → restart → complete),
/// per-event instants, and `event_loop`/`plan_search` wall-clock
/// phases are recorded into `obs` when it is enabled. Observation is
/// purely passive — the returned [`FleetMetrics`] are bit-identical
/// with tracing on or off (property-pinned).
pub fn simulate_fleet_observed(
    env: &Env,
    jobs: &[Job],
    churn: &[ChurnEvent],
    policy: &dyn PlacementPolicy,
    opts: &FleetOptions,
    obs: &Observer,
) -> crate::Result<FleetMetrics> {
    let queue_registry = QueuePolicyRegistry::with_defaults();
    let queue_policy = queue_registry.get_or_err(&opts.queue)?;
    simulate_fleet_with_observed(env, jobs, churn, policy, queue_policy.as_ref(), opts, obs)
}

/// Like [`simulate_fleet`], but over an explicit queue-policy *instance*
/// instead of the registry name in `opts.queue` (which is ignored).
///
/// This is the entry point for policies that carry state or weights the
/// name registry cannot construct — the `learn` subsystem's
/// [`crate::learn::LearnedQueue`] (inference) and its training shim
/// dispatch through here.
pub fn simulate_fleet_with(
    env: &Env,
    jobs: &[Job],
    churn: &[ChurnEvent],
    policy: &dyn PlacementPolicy,
    queue_policy: &dyn QueuePolicy,
    opts: &FleetOptions,
) -> crate::Result<FleetMetrics> {
    simulate_fleet_with_observed(env, jobs, churn, policy, queue_policy, opts, &Observer::disabled())
}

/// [`simulate_fleet_with`] under an explicit [`Observer`] — see
/// [`simulate_fleet_observed`].
pub fn simulate_fleet_with_observed(
    env: &Env,
    jobs: &[Job],
    churn: &[ChurnEvent],
    policy: &dyn PlacementPolicy,
    queue_policy: &dyn QueuePolicy,
    opts: &FleetOptions,
    obs: &Observer,
) -> crate::Result<FleetMetrics> {
    let registry = StrategyRegistry::with_defaults();
    let strategy = registry.get_or_err(&opts.strategy)?;
    for (i, j) in jobs.iter().enumerate() {
        anyhow::ensure!(j.id == i, "job ids must equal their index ({} at {i})", j.id);
    }
    // validate the churn trace against the initial pool before running:
    // joins must carry fresh ids and leave/degrade must name a device
    // present at that point of the trace (churn is independent of job
    // activity, so membership is decidable up front) — a mis-authored
    // trace must fail loudly, not silently no-op mid-run
    {
        let mut order: Vec<&ChurnEvent> = churn.iter().collect();
        order.sort_by(|a, b| a.time.total_cmp(&b.time));
        let mut virt: std::collections::BTreeSet<usize> =
            env.devices.iter().map(|d| d.id).collect();
        for e in order {
            match e.kind {
                ChurnKind::Join(id, _) => anyhow::ensure!(
                    virt.insert(id),
                    "churn trace: join of already-present device id {id}"
                ),
                ChurnKind::Leave(id) => anyhow::ensure!(
                    virt.remove(&id),
                    "churn trace: leave of absent device id {id}"
                ),
                ChurnKind::Degrade(id) => anyhow::ensure!(
                    virt.contains(&id),
                    "churn trace: degrade of absent device id {id}"
                ),
            }
        }
    }

    // The run's metric registry: the oracle's memo counters are
    // adopted so `oracle_hits`/`oracle_misses` read live, `events`
    // ticks in the loop, and `rescans_avoided` lands at the end — the
    // legacy `FleetMetrics` fields below are reads of this registry.
    let metrics = Metrics::new();
    let oracle = StrategyOracle::new(strategy.as_ref(), env.network).observed(obs);
    metrics.adopt_counter("oracle_hits", oracle.hits_counter());
    metrics.adopt_counter("oracle_misses", oracle.misses_counter());
    // absolute deadlines against the ideal full-pool reference plan
    let deadlines: Vec<f64> = jobs
        .iter()
        .map(|j| {
            if opts.deadline_scale <= 0.0 {
                return f64::INFINITY;
            }
            match oracle.service_time(j, &env.devices) {
                Some(t) => j.arrival + j.deadline_mult * opts.deadline_scale * t,
                None => f64::INFINITY,
            }
        })
        .collect();

    let mut sim = Sim {
        jobs,
        policy,
        queue_policy,
        oracle,
        horizon: opts.horizon,
        ckpt: opts.ckpt,
        eventq: opts.event_queue.make(),
        index: opts.incremental_queue.then(QueueIndex::new),
        seq: 0,
        now: 0.0,
        present: env.devices.iter().map(|d| (d.id, d.kind)).collect(),
        assigned: BTreeMap::new(),
        queue: VecDeque::new(),
        running: BTreeMap::new(),
        tokens: vec![0; jobs.len()],
        pending_joins: churn
            .iter()
            .filter(|e| matches!(e.kind, ChurnKind::Join(..)))
            .count(),
        pool_dirty: false,
        joined_at: env.devices.iter().map(|d| (d.id, 0.0)).collect(),
        presence_acc: BTreeMap::new(),
        busy_since: BTreeMap::new(),
        busy_acc: BTreeMap::new(),
        user_service: BTreeMap::new(),
        deadlines,
        ckpt_frac: vec![0.0; jobs.len()],
        first_start: vec![None; jobs.len()],
        finish_at: vec![None; jobs.len()],
        failed: 0,
        replans: 0,
        restarts: 0,
        work_lost: 0.0,
        migration_overhead: 0.0,
        ckpt_count: 0,
        ckpt_overhead: 0.0,
        events: metrics.counter("events"),
        obs,
    };
    for job in jobs {
        sim.push(job.arrival, EventKind::Arrival(job.id));
    }
    for e in churn {
        sim.push(e.time, EventKind::Churn(e.kind));
    }

    let mut hit_horizon = false;
    let loop_timer = obs.timer("event_loop");
    while let Some((time, seq, kind)) = sim.eventq.pop() {
        if time > sim.horizon {
            hit_horizon = true;
            break;
        }
        sim.now = time;
        sim.events.inc();
        sim.obs.instant("sim.event", "event", seq, time);
        match kind {
            EventKind::Arrival(id) => {
                // vet the arrival once: a job infeasible on the whole
                // current pool (with no joins pending that could still
                // grow it) can never run — fail it now instead of
                // wedging the queue. Pool changes re-vet the queue via
                // the `pool_dirty` sweep in `try_dispatch`.
                if sim.pending_joins == 0
                    && sim
                        .oracle
                        .service_time(&sim.jobs[id], &sim.all_present())
                        .is_none()
                {
                    sim.failed += 1;
                } else {
                    sim.obs.instant("fleet.job", "enqueue", id as u64, time);
                    sim.queue.push_back(id);
                    if let Some(ix) = &sim.index {
                        ix.on_enqueue_back(id);
                    }
                }
            }
            EventKind::Finish { job, token } => {
                if sim.tokens[job] != token {
                    continue; // superseded by a replan or restart
                }
                let rj = sim.running.remove(&job).expect("finished job is running");
                // every checkpoint of the completed attempt was paid
                let point = sim.timeline(job, &rj).at(time - rj.start);
                sim.ckpt_count += point.ckpts;
                sim.ckpt_overhead += point.ckpt_time;
                for id in rj.devices {
                    sim.release(id, time);
                }
                sim.finish_at[job] = Some(time);
                sim.obs.instant("fleet.job", "complete", job as u64, time);
                let arrival = sim.jobs[job].arrival;
                sim.obs.span("fleet.job", "job", job as u64, arrival, time - arrival);
                if let Some(ix) = &sim.index {
                    ix.on_state_change(); // devices were freed
                }
            }
            EventKind::Churn(kind) => sim.apply_churn(kind, time),
        }
        sim.try_dispatch(time);
    }
    drop(loop_timer);

    let end = if hit_horizon { sim.horizon } else { sim.now };
    // attempts cut off by the horizon never reach their churn/Finish
    // measurement point — walk them here so the checkpoints they did
    // complete are counted (their pause time is already in busy spans)
    let open_ckpts: Vec<(usize, f64)> = sim
        .running
        .iter()
        .map(|(&job, rj)| {
            let p = sim.timeline(job, rj).at(end - rj.start);
            (p.ckpts, p.ckpt_time)
        })
        .collect();
    for (ckpts, ckpt_time) in open_ckpts {
        sim.ckpt_count += ckpts;
        sim.ckpt_overhead += ckpt_time;
    }
    // close open presence/busy spans at the end of virtual time
    let open_busy: Vec<usize> = sim.busy_since.keys().copied().collect();
    for id in open_busy {
        if let Some(since) = sim.busy_since.remove(&id) {
            let span = end - since;
            *sim.busy_acc.entry(id).or_insert(0.0) += span;
            if let Some(&job) = sim.assigned.get(&id) {
                *sim.user_service.entry(sim.jobs[job].user).or_insert(0.0) += span;
            }
        }
    }
    let still_present: Vec<usize> = sim.joined_at.keys().copied().collect();
    for id in still_present {
        if let Some(t0) = sim.joined_at.remove(&id) {
            *sim.presence_acc.entry(id).or_insert(0.0) += end - t0;
        }
    }
    let per_device: Vec<(usize, f64, f64)> = sim
        .presence_acc
        .iter()
        .map(|(&id, &presence)| {
            (id, sim.busy_acc.get(&id).copied().unwrap_or(0.0), presence)
        })
        .collect();

    let per_job: Vec<JobStat> = jobs
        .iter()
        .map(|j| JobStat {
            id: j.id,
            user: j.user,
            arrival: j.arrival,
            first_start: sim.first_start[j.id],
            finish: sim.finish_at[j.id],
            deadline: sim.deadlines[j.id],
            met: sim.finish_at[j.id].map(|f| f <= sim.deadlines[j.id]).unwrap_or(false),
        })
        .collect();

    metrics
        .counter("rescans_avoided")
        .add(sim.index.as_ref().map_or(0, |ix| ix.rescans_avoided()) as u64);
    obs.absorb(&metrics);
    // the legacy observe fields are reads of the metric registry
    Ok(FleetMetrics::assemble(RawFleet {
        per_job,
        failed: sim.failed,
        makespan: end,
        per_device,
        user_service: sim.user_service.into_iter().collect(),
        replans: sim.replans,
        restarts: sim.restarts,
        work_lost: sim.work_lost,
        migration_overhead: sim.migration_overhead,
        ckpt_count: sim.ckpt_count,
        ckpt_overhead: sim.ckpt_overhead,
        events: metrics.value("events") as usize,
        oracle_hits: metrics.value("oracle_hits") as usize,
        oracle_misses: metrics.value("oracle_misses") as usize,
        rescans_avoided: metrics.value("rescans_avoided") as usize,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::policy::{BestFit, FifoExclusive, PreemptReplan};
    use crate::fleet::trace::{generate_churn, generate_jobs, TraceKind};
    use crate::model::ModelSpec;

    fn small_jobs(n: usize) -> Vec<Job> {
        // uniform small jobs: one planner call, fast tests
        (0..n)
            .map(|i| Job::new(i, i as f64 * 600.0, ModelSpec::t5_base(), 512, 2))
            .collect()
    }

    #[test]
    fn drains_all_jobs_without_churn() {
        let env = Env::env_a();
        let jobs = small_jobs(8);
        for policy in [&FifoExclusive as &dyn PlacementPolicy, &BestFit, &PreemptReplan] {
            let m =
                simulate_fleet(&env, &jobs, &[], policy, &FleetOptions::default()).unwrap();
            assert_eq!(m.completed, 8, "{}", policy.name());
            assert_eq!(m.failed + m.incomplete, 0, "{}", policy.name());
            assert!(m.jobs_per_hour > 0.0);
            assert!(m.latency_p50.unwrap() <= m.latency_p99.unwrap());
            assert!(m.utilization > 0.0 && m.utilization <= 1.0);
            assert_eq!(m.replans + m.restarts, 0);
            assert!(m.events >= 16, "arrival+finish per job");
            // single-user trace: fairness is exactly 1.0
            assert_eq!(m.fairness, 1.0, "{}", policy.name());
            assert_eq!(m.per_user.len(), 1);
            assert_eq!(m.per_user[0].jobs, 8);
        }
    }

    #[test]
    fn best_fit_runs_jobs_concurrently() {
        let env = Env::env_a();
        // all jobs arrive at once: exclusive runs them serially,
        // best-fit packs them side by side
        let jobs: Vec<Job> = (0..4)
            .map(|i| Job::new(i, 0.0, ModelSpec::t5_base(), 512, 2))
            .collect();
        let opts = FleetOptions::default();
        let fifo = simulate_fleet(&env, &jobs, &[], &FifoExclusive, &opts).unwrap();
        let bf = simulate_fleet(&env, &jobs, &[], &BestFit, &opts).unwrap();
        assert_eq!(fifo.completed, 4);
        assert_eq!(bf.completed, 4);
        assert!(
            bf.latency_p99.unwrap() < fifo.latency_p99.unwrap(),
            "multi-tenant packing must cut tail latency: bf {:?} fifo {:?}",
            bf.latency_p99,
            fifo.latency_p99
        );
    }

    #[test]
    fn invalid_churn_trace_is_rejected() {
        let env = Env::env_a(); // device ids 0..=3
        let jobs = small_jobs(1);
        for (churn, want) in [
            (ChurnKind::Leave(99), "leave of absent"),
            (ChurnKind::Join(0, DeviceKind::NanoH), "join of already-present"),
            (ChurnKind::Degrade(7), "degrade of absent"),
        ] {
            let trace = vec![ChurnEvent { time: 10.0, kind: churn }];
            let err = simulate_fleet(&env, &jobs, &trace, &BestFit, &FleetOptions::default())
                .unwrap_err()
                .to_string();
            assert!(err.contains(want), "{churn:?}: {err}");
        }
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let env = Env::env_a();
        let err = simulate_fleet(
            &env,
            &small_jobs(1),
            &[],
            &BestFit,
            &FleetOptions { strategy: "zero-3".into(), ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown strategy"), "{err}");
    }

    #[test]
    fn unknown_queue_policy_is_an_error() {
        let env = Env::env_a();
        let err = simulate_fleet(
            &env,
            &small_jobs(1),
            &[],
            &BestFit,
            &FleetOptions { queue: "lifo".into(), ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown queue policy"), "{err}");
        assert!(err.contains("EASY-backfill"), "must list alternatives: {err}");
    }

    #[test]
    fn horizon_cuts_the_run() {
        let env = Env::env_a();
        let jobs = small_jobs(12);
        let m = simulate_fleet(
            &env,
            &jobs,
            &[],
            &FifoExclusive,
            &FleetOptions { horizon: 1800.0, ..Default::default() },
        )
        .unwrap();
        assert!(m.completed < 12);
        assert_eq!(m.completed + m.incomplete + m.failed, 12);
        assert!(m.makespan <= 1800.0);
    }

    #[test]
    fn infeasible_job_fails_instead_of_hanging() {
        // T5-Large full pool of ONE Nano cannot host under PA either
        let env = Env::standalone(crate::cluster::DeviceKind::NanoH);
        let jobs = vec![Job::new(0, 0.0, ModelSpec::t5_large(), 4096, 3)];
        let m = simulate_fleet(&env, &jobs, &[], &BestFit, &FleetOptions::default()).unwrap();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 0);
    }

    /// Deadlines: under FIFO-exclusive the service time *is* the
    /// full-pool reference the deadline is anchored on, so with the
    /// default 3× slack both jobs provably finish in time (job 1's
    /// worst-case finish is `max(arrival, t_ref) + t_ref ≤ arrival +
    /// 3·t_ref`); a crushingly small scale makes every job miss, and
    /// `deadline_scale <= 0` disables deadlines entirely.
    #[test]
    fn deadline_scale_moves_goodput() {
        let env = Env::env_a();
        let jobs = small_jobs(2);
        let easy =
            simulate_fleet(&env, &jobs, &[], &FifoExclusive, &FleetOptions::default()).unwrap();
        assert_eq!(easy.completed, 2);
        assert_eq!(easy.deadline_met, 2, "{easy:?}");
        assert_eq!(easy.deadline_miss_rate, 0.0);
        assert!(easy.goodput_per_hour > 0.0);
        for j in &easy.per_job {
            assert!(j.deadline.is_finite());
            assert!(j.met);
        }

        let tight = simulate_fleet(
            &env,
            &jobs,
            &[],
            &FifoExclusive,
            &FleetOptions { deadline_scale: 1e-6, ..Default::default() },
        )
        .unwrap();
        assert_eq!(tight.completed, 2, "completion is deadline-independent");
        assert_eq!(tight.deadline_met, 0, "{tight:?}");
        assert_eq!(tight.deadline_miss_rate, 1.0);

        let off = simulate_fleet(
            &env,
            &jobs,
            &[],
            &FifoExclusive,
            &FleetOptions { deadline_scale: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(off.deadline_met, off.completed, "disabled deadlines are all met");
        for j in &off.per_job {
            assert!(j.deadline.is_infinite());
        }
    }

    /// Generated churn keeps every accounting invariant (the *engineered*
    /// churn scenarios that pin exact replan/restart behavior live in
    /// `tests/fleet.rs`, where the hit is constructed, not sampled).
    #[test]
    fn generated_churn_keeps_invariants() {
        let env = Env::env_a();
        let jobs = generate_jobs(TraceKind::Steady, 20, 11);
        let churn = generate_churn(&env, 48.0 * 3600.0, 2.0, 11);
        let opts = FleetOptions::default();
        for policy in [&FifoExclusive as &dyn PlacementPolicy, &PreemptReplan] {
            let m = simulate_fleet(&env, &jobs, &churn, policy, &opts).unwrap();
            assert_eq!(
                m.completed + m.failed + m.incomplete,
                20,
                "{}: every job accounted for: {m:?}",
                policy.name()
            );
            assert!(m.completed > 0, "{}: {m:?}", policy.name());
            assert!(m.work_lost >= 0.0 && m.work_lost.is_finite());
            assert!(m.migration_overhead >= 0.0 && m.migration_overhead.is_finite());
            assert!(m.utilization >= 0.0 && m.utilization <= 1.0, "{m:?}");
            for (_, u) in &m.per_device_util {
                assert!(*u >= 0.0 && *u <= 1.0 + 1e-9, "{m:?}");
            }
            assert!(m.deadline_met <= m.completed);
            assert!(m.fairness > 0.0 && m.fairness <= 1.0 + 1e-9, "{m:?}");
            assert!(m.goodput_per_hour <= m.jobs_per_hour + 1e-9);
            assert_eq!(m.per_job.len(), 20);
            assert_eq!(
                m.per_user.iter().map(|u| u.jobs).sum::<usize>(),
                20,
                "user partition covers every job"
            );
            // no checkpointing configured: nothing checkpoint-related
            assert_eq!((m.ckpt_count, m.ckpt_overhead), (0, 0.0));
        }
    }

    /// Checkpointing caps restart losses: engineered single-job run on
    /// one device, churned off mid-flight exactly once.
    #[test]
    fn checkpoint_bounds_restart_loss() {
        let env = Env::nanos(1);
        let jobs = vec![Job::new(0, 0.0, ModelSpec::t5_base(), 1024, 4)];
        // probe the uncheckpointed service time
        let probe =
            simulate_fleet(&env, &jobs, &[], &BestFit, &FleetOptions::default()).unwrap();
        assert_eq!(probe.completed, 1);
        let t1 = probe.makespan;

        // the single device leaves mid-run and a replacement joins: a
        // restart-policy job restarts; with k=1 checkpoints it resumes
        let churn = vec![
            ChurnEvent { time: 0.6 * t1, kind: ChurnKind::Leave(0) },
            ChurnEvent { time: 0.6 * t1 + 1.0, kind: ChurnKind::Join(5, DeviceKind::NanoH) },
        ];
        let opts_off = FleetOptions { horizon: 4.0 * t1, ..Default::default() };
        let off = simulate_fleet(&env, &jobs, &churn, &BestFit, &opts_off).unwrap();
        assert_eq!(off.restarts, 1, "{off:?}");
        assert_eq!(off.completed, 1);
        assert!((off.work_lost - 0.6 * t1).abs() < 1e-6, "{off:?}");
        assert_eq!((off.ckpt_count, off.ckpt_overhead), (0, 0.0));

        let opts_ck = FleetOptions {
            horizon: 4.0 * t1,
            ckpt: Some(CheckpointSpec::new(1, 1.0)),
            ..Default::default()
        };
        let ck = simulate_fleet(&env, &jobs, &churn, &BestFit, &opts_ck).unwrap();
        assert_eq!(ck.restarts, 1, "{ck:?}");
        assert_eq!(ck.completed, 1);
        assert!(ck.ckpt_count >= 3, "two before churn, at least one after: {ck:?}");
        assert!(ck.ckpt_overhead > 0.0);
        // bounded loss: at most one checkpoint interval (k/epochs of the
        // job) instead of everything since the chain start
        assert!(
            ck.work_lost <= t1 / 4.0 + 1e-6,
            "loss {} exceeds one interval {}",
            ck.work_lost,
            t1 / 4.0
        );
        assert!(ck.work_lost < off.work_lost);
        // and the checkpointed run finishes earlier than the restarted one
        assert!(
            ck.latency_p50.unwrap() < off.latency_p50.unwrap(),
            "ck {ck:?} off {off:?}"
        );
    }

    #[test]
    fn same_seed_bit_identical() {
        let env = Env::env_b();
        let jobs = generate_jobs(TraceKind::Bursty, 15, 21);
        let churn = generate_churn(&env, 48.0 * 3600.0, 3.0, 21);
        let opts = FleetOptions::default();
        let a = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
        let b = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &opts).unwrap();
        assert_eq!(a, b);
    }

    /// Scrub the observe counters that legitimately differ between the
    /// legacy and incremental dispatch paths (the caches exist exactly
    /// to skip oracle calls), leaving every simulated outcome.
    fn scrubbed(mut m: FleetMetrics) -> FleetMetrics {
        m.oracle_hits = 0;
        m.oracle_misses = 0;
        m.rescans_avoided = 0;
        m
    }

    /// The scaling paths (calendar event queue, incremental dispatch
    /// index) must be bit-identical to the original binary heap +
    /// legacy full-rescan dispatch. The broad placement × queue × churn
    /// sweep lives in `tests/prop_invariants.rs`; this pins the
    /// churn-heavy EDF case in-module.
    #[test]
    fn calendar_and_incremental_match_heap_and_legacy() {
        let env = Env::env_b();
        let jobs = generate_jobs(TraceKind::Bursty, 12, 33);
        let churn = generate_churn(&env, 48.0 * 3600.0, 3.0, 33);
        let base = FleetOptions { queue: "edf".into(), ..Default::default() };
        let legacy = FleetOptions {
            event_queue: EventQueueKind::Heap,
            incremental_queue: false,
            ..base.clone()
        };
        let a = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &base).unwrap();
        let b = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &legacy).unwrap();
        assert_eq!(scrubbed(a.clone()), scrubbed(b));

        // same dispatch path, different event queue: full equality,
        // counters included
        let heap_inc = FleetOptions { event_queue: EventQueueKind::Heap, ..base.clone() };
        let c = simulate_fleet(&env, &jobs, &churn, &PreemptReplan, &heap_inc).unwrap();
        assert_eq!(a, c);
    }
}
