//! `fleet` — a deterministic discrete-event **multi-tenant scheduler**:
//! many personal fine-tuning jobs contending for one shared, churning
//! pool of edge devices.
//!
//! The paper fine-tunes one personal LLM on one static pool. The
//! production target (ROADMAP north star) is many concurrent users on
//! shared, unreliable edge hardware — which adds exactly the dimensions
//! this module models:
//!
//! * **time** — a virtual clock driven by a binary-heap event loop
//!   ([`sim`]);
//! * **arrival** — seeded job-stream generators ([`TraceKind`]:
//!   steady / diurnal / bursty), each job carrying its own model size,
//!   dataset size and epoch budget ([`trace`]);
//! * **churn** — devices join, leave, or degrade to low-power modes
//!   mid-run ([`ChurnEvent`]);
//! * **contention** — a queue plus a pluggable [`PlacementPolicy`]
//!   ([`policy`]): FIFO-exclusive, best-fit device-partitioning, and
//!   preempt-and-replan-on-churn, resolved by name through a
//!   [`PolicyRegistry`];
//! * **accounting** — [`FleetMetrics`]: jobs/hour, p50/p95/p99
//!   completion latency, per-device utilization, replans, work lost.
//!
//! Placement never re-derives timing: every candidate device subset is
//! costed through the existing [`crate::strategy`] registry (the
//! paper's DP planner, the 1F1B schedule simulator, and the cached-
//! epoch model), so fleet-level comparisons inherit the same substrate
//! as the single-job experiments.
//!
//! Entry points: [`simulate_fleet`] (library), the `fleet` /
//! `fleet_churn` experiments in
//! [`crate::exp::ExperimentRegistry::with_defaults`], and the
//! `pacpp fleet` CLI subcommand. See the crate docs ("Adding a
//! placement policy") for how to register your own policy.

pub mod metrics;
pub mod policy;
pub mod sim;
pub mod trace;

pub use metrics::FleetMetrics;
pub use policy::{
    BestFit, ChurnResponse, FifoExclusive, Placement, PlacementCtx, PlacementPolicy,
    PlanOracle, PolicyRegistry, PreemptReplan,
};
pub use sim::{simulate_fleet, FleetOptions, StrategyOracle};
pub use trace::{generate_churn, generate_jobs, ChurnEvent, ChurnKind, Job, TraceKind};
