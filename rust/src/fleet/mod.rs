//! `fleet` — a deterministic discrete-event **multi-tenant scheduler**:
//! many personal fine-tuning jobs contending for one shared, churning
//! pool of edge devices, with deadlines, per-user SLOs and bounded-loss
//! checkpointing.
//!
//! The paper fine-tunes one personal LLM on one static pool. The
//! production target (ROADMAP north star) is many concurrent users on
//! shared, unreliable edge hardware — which adds exactly the dimensions
//! this module models:
//!
//! * **time** — a virtual clock driven by a pluggable event queue
//!   ([`sim`], [`eventq`]: calendar/bucket queue by default, binary
//!   heap for equivalence testing — bit-identical orderings);
//! * **arrival** — seeded job-stream generators ([`TraceKind`]:
//!   steady / diurnal / bursty), each job carrying its own model size,
//!   dataset size, epoch budget, submitting user and deadline slack
//!   ([`trace`]);
//! * **churn** — devices join, leave, or degrade to low-power modes
//!   mid-run ([`ChurnEvent`]);
//! * **contention** — a queue ordered by a pluggable [`QueuePolicy`]
//!   ([`queue`]: strict FIFO, EASY-backfill, shortest-job-first,
//!   earliest-deadline-first, least-laxity) over a
//!   pluggable [`PlacementPolicy`] ([`policy`]: FIFO-exclusive,
//!   best-fit device-partitioning, preempt-and-replan-on-churn), each
//!   resolved by name through its registry ([`QueuePolicyRegistry`],
//!   [`PolicyRegistry`]);
//! * **reliability** — optional checkpointing every `k` epochs
//!   ([`ckpt`]): a churn-forced restart resumes from the last completed
//!   checkpoint instead of losing the whole attempt, trading bounded
//!   loss against checkpoint overhead;
//! * **accounting** — [`FleetMetrics`]: jobs/hour, goodput (jobs
//!   finished within their deadline), deadline-miss rate, p50/p95/p99
//!   completion latency, per-user p95 + Jain fairness over per-user
//!   service ([`jain_index`]), per-device utilization, replans,
//!   restarts, work lost, migration and checkpoint overhead.
//!
//! Placement never re-derives timing: every candidate device subset is
//! costed through the existing [`crate::strategy`] registry (the
//! paper's DP planner, the 1F1B schedule simulator, and the cached-
//! epoch model), so fleet-level comparisons inherit the same substrate
//! as the single-job experiments.
//!
//! Entry points: [`simulate_fleet`] (library), the `fleet` /
//! `fleet_churn` / `fleet_checkpoint` / `fleet_users` experiments in
//! [`crate::exp::ExperimentRegistry::with_defaults`], and the
//! `pacpp fleet` CLI subcommand (`--policy`, `--queue`, `--deadline`,
//! `--ckpt`). See the crate docs ("Adding a placement policy", "Adding
//! a queue policy") for how to register your own.

pub mod ckpt;
pub mod eventq;
pub mod metrics;
pub mod policy;
pub mod queue;
pub mod sim;
pub mod trace;

pub use ckpt::{AttemptPoint, AttemptTimeline, CheckpointSpec, DEFAULT_CKPT_COST};
pub use eventq::{CalendarQueue, EventQueue, EventQueueKind, HeapQueue};
pub use metrics::{jain_index, FleetMetrics, JobStat, UserStat};
pub use policy::{
    BestFit, ChurnResponse, FifoExclusive, Placement, PlacementCtx, PlacementPolicy,
    PlanOracle, PolicyRegistry, PreemptReplan,
};
pub use queue::{
    EarliestDeadlineFirst, EasyBackfill, FifoQueue, LeastLaxity, QueueCtx, QueueDecision,
    QueueIndex, QueuePolicy, QueuePolicyRegistry, RunningSnapshot, ShortestJobFirst,
};
pub use sim::{
    simulate_fleet, simulate_fleet_observed, simulate_fleet_with, simulate_fleet_with_observed,
    FleetOptions, StrategyOracle,
};
pub use trace::{
    churn_from_json, churn_to_json, generate_churn, generate_jobs, ChurnEvent, ChurnKind,
    Job, TraceKind, DEFAULT_DEADLINE_MULT,
};
