//! The open placement layer: how queued jobs claim devices from the
//! shared pool, and how running jobs react to churn.
//!
//! Mirrors the strategy/experiment registries
//! ([`crate::strategy::StrategyRegistry`],
//! [`crate::exp::ExperimentRegistry`]): a scheme is one
//! [`PlacementPolicy`] impl plus one [`PolicyRegistry::register`] call,
//! and the fleet experiments and `pacpp fleet` CLI resolve policies by
//! name. Policies never cost plans themselves — they ask the simulator's
//! [`PlanOracle`], which routes every candidate subset through the
//! existing strategy registry (planner + 1F1B simulation), so a policy
//! is pure placement logic.

use std::sync::Arc;

use crate::cluster::Device;

use super::trace::Job;

/// Plan-costing service the simulator hands to policies: the estimated
/// end-to-end service time of `job` on exactly `devices`, or `None`
/// when no feasible plan exists (OOM on every explored configuration).
pub trait PlanOracle {
    fn service_time(&self, job: &Job, devices: &[Device]) -> Option<f64>;
}

/// What a placement decision sees.
pub struct PlacementCtx<'a> {
    pub job: &'a Job,
    /// Idle devices, ascending id order.
    pub free: &'a [Device],
    /// Devices present in the pool (busy + free).
    pub present: usize,
    /// Jobs currently running.
    pub running: usize,
    pub oracle: &'a dyn PlanOracle,
}

/// A placement decision: the claimed devices and the service time the
/// oracle quoted for them.
#[derive(Debug, Clone)]
pub struct Placement {
    pub devices: Vec<Device>,
    pub service_time: f64,
}

/// How a policy reacts when churn removes or degrades a device assigned
/// to a running job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnResponse {
    /// Abort the attempt: progress is lost and the job re-queues at the
    /// head of the queue.
    Restart,
    /// Keep progress: replan on the surviving devices, paying a
    /// checkpoint/activation-cache migration cost.
    Replan,
}

/// A pluggable multi-tenant placement scheme.
///
/// Implementations must be stateless (or internally synchronized): the
/// registry hands out shared references and the fleet experiments call
/// policies from worker threads.
pub trait PlacementPolicy: Send + Sync {
    /// Canonical display name (stable: used in tables, JSON and the CLI).
    fn name(&self) -> &str;

    /// Lowercase lookup aliases accepted by [`PolicyRegistry::get`].
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `pacpp fleet` docs.
    fn description(&self) -> &str {
        ""
    }

    /// Claim devices for the queue-head job, or `None` to leave it
    /// queued (the simulator retries at the next state change and
    /// detects permanently unplaceable jobs itself).
    fn place(&self, ctx: &PlacementCtx) -> Option<Placement>;

    /// Reaction to churn hitting one of a running job's devices.
    fn on_churn(&self) -> ChurnResponse {
        ChurnResponse::Restart
    }
}

/// Smallest feasible device subset, slowest-first: conserves the fast
/// devices for the jobs that need them. Shared by [`BestFit`] and
/// [`PreemptReplan`].
fn best_fit_place(ctx: &PlacementCtx) -> Option<Placement> {
    let mut by_speed: Vec<Device> = ctx.free.to_vec();
    by_speed.sort_by(|a, b| {
        a.kind
            .effective_flops()
            .partial_cmp(&b.kind.effective_flops())
            .unwrap()
            .then(a.id.cmp(&b.id))
    });
    for k in 1..=by_speed.len() {
        let subset = &by_speed[..k];
        if let Some(t) = ctx.oracle.service_time(ctx.job, subset) {
            return Some(Placement { devices: subset.to_vec(), service_time: t });
        }
    }
    None
}

/// One job at a time, FIFO order, exclusive use of the whole pool —
/// the single-tenant baseline (the paper's own operating model).
#[derive(Debug, Clone, Copy, Default)]
pub struct FifoExclusive;

impl PlacementPolicy for FifoExclusive {
    fn name(&self) -> &str {
        "FIFO-exclusive"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fifo", "fifo-exclusive", "exclusive"]
    }

    fn description(&self) -> &str {
        "one job at a time takes every free device; churn restarts the job"
    }

    fn place(&self, ctx: &PlacementCtx) -> Option<Placement> {
        if ctx.running > 0 {
            return None;
        }
        let t = ctx.oracle.service_time(ctx.job, ctx.free)?;
        Some(Placement { devices: ctx.free.to_vec(), service_time: t })
    }
}

/// Multi-tenant best-fit partitioning: each job claims the smallest
/// (slowest-first) feasible subset, so several jobs share the pool.
#[derive(Debug, Clone, Copy, Default)]
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn name(&self) -> &str {
        "Best-fit"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["best-fit", "bestfit", "bf"]
    }

    fn description(&self) -> &str {
        "smallest feasible device subset per job (multi-tenant); churn restarts the job"
    }

    fn place(&self, ctx: &PlacementCtx) -> Option<Placement> {
        best_fit_place(ctx)
    }
}

/// Best-fit placement + churn-aware execution: when a device is lost or
/// degraded mid-job, replan on the survivors and keep the progress,
/// charging the checkpoint/activation-cache migration cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct PreemptReplan;

impl PlacementPolicy for PreemptReplan {
    fn name(&self) -> &str {
        "Preempt-replan"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["preempt", "replan", "preempt-replan"]
    }

    fn description(&self) -> &str {
        "best-fit placement; churn replans on survivors, migrating the cache"
    }

    fn place(&self, ctx: &PlacementCtx) -> Option<Placement> {
        best_fit_place(ctx)
    }

    fn on_churn(&self) -> ChurnResponse {
        ChurnResponse::Replan
    }
}

impl crate::util::registry::Registered for dyn PlacementPolicy {
    fn name(&self) -> &str {
        PlacementPolicy::name(self)
    }
    fn aliases(&self) -> &'static [&'static str] {
        PlacementPolicy::aliases(self)
    }
    fn describe(&self) -> &str {
        self.description()
    }
}

/// An ordered, name-addressed collection of placement policies — a
/// [`crate::util::registry::Registry`] instantiation (uniform
/// resolution semantics; see [`crate::util::registry`]).
///
/// Registration order is preserved (it is the row order of the fleet
/// experiment grids). Canonical names match case-insensitively; aliases
/// are lowercase.
pub type PolicyRegistry = crate::util::registry::Registry<dyn PlacementPolicy>;

impl PolicyRegistry {
    /// An empty registry (build-your-own line-ups).
    pub fn empty() -> PolicyRegistry {
        crate::util::registry::Registry::new("placement policy")
    }

    /// The three built-in policies: FIFO-exclusive, Best-fit,
    /// Preempt-replan.
    pub fn with_defaults() -> PolicyRegistry {
        let mut r = PolicyRegistry::empty();
        r.register(Arc::new(FifoExclusive));
        r.register(Arc::new(BestFit));
        r.register(Arc::new(PreemptReplan));
        r
    }
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        PolicyRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceKind;
    use crate::model::ModelSpec;

    /// Oracle pricing a subset feasible iff it has >= `need` devices.
    struct NeedK {
        need: usize,
    }

    impl PlanOracle for NeedK {
        fn service_time(&self, _job: &Job, devices: &[Device]) -> Option<f64> {
            if devices.len() >= self.need {
                Some(100.0 / devices.len() as f64)
            } else {
                None
            }
        }
    }

    fn devices(n: usize) -> Vec<Device> {
        (0..n)
            .map(|i| {
                Device::new(i, if i % 2 == 0 { DeviceKind::NanoH } else { DeviceKind::Tx2H })
            })
            .collect()
    }

    fn job() -> Job {
        Job::new(0, 0.0, ModelSpec::tiny(), 512, 2)
    }

    #[test]
    fn defaults_cover_the_lineup() {
        let r = PolicyRegistry::with_defaults();
        assert_eq!(r.names(), vec!["FIFO-exclusive", "Best-fit", "Preempt-replan"]);
        for (query, want) in [
            ("fifo", "FIFO-exclusive"),
            ("FIFO-EXCLUSIVE", "FIFO-exclusive"),
            ("best-fit", "Best-fit"),
            ("bf", "Best-fit"),
            ("preempt", "Preempt-replan"),
            ("replan", "Preempt-replan"),
        ] {
            assert_eq!(r.get(query).map(|p| p.name()), Some(want), "query {query:?}");
        }
        assert!(r.get("round-robin").is_none());
    }

    #[test]
    fn fifo_is_exclusive() {
        let free = devices(4);
        let oracle = NeedK { need: 1 };
        let j = job();
        let busy_ctx =
            PlacementCtx { job: &j, free: &free, present: 4, running: 1, oracle: &oracle };
        assert!(FifoExclusive.place(&busy_ctx).is_none(), "must wait while a job runs");
        let idle_ctx =
            PlacementCtx { job: &j, free: &free, present: 4, running: 0, oracle: &oracle };
        let p = FifoExclusive.place(&idle_ctx).expect("places when idle");
        assert_eq!(p.devices.len(), 4, "takes the whole pool");
    }

    #[test]
    fn best_fit_takes_smallest_slowest_subset() {
        let free = devices(4); // ids 0,2 Nano (slow); 1,3 TX2 (fast)
        let oracle = NeedK { need: 2 };
        let j = job();
        let ctx = PlacementCtx { job: &j, free: &free, present: 4, running: 1, oracle: &oracle };
        let p = BestFit.place(&ctx).expect("feasible at k=2");
        assert_eq!(p.devices.len(), 2);
        let ids: Vec<usize> = p.devices.iter().map(|d| d.id).collect();
        assert_eq!(ids, vec![0, 2], "slowest-first: conserve the fast devices");
    }

    #[test]
    fn best_fit_none_when_infeasible() {
        let free = devices(2);
        let oracle = NeedK { need: 3 };
        let j = job();
        let ctx = PlacementCtx { job: &j, free: &free, present: 2, running: 0, oracle: &oracle };
        assert!(BestFit.place(&ctx).is_none());
    }

    #[test]
    fn churn_responses() {
        assert_eq!(FifoExclusive.on_churn(), ChurnResponse::Restart);
        assert_eq!(BestFit.on_churn(), ChurnResponse::Restart);
        assert_eq!(PreemptReplan.on_churn(), ChurnResponse::Replan);
    }

    #[test]
    fn register_replaces_by_name() {
        struct Shadow;
        impl PlacementPolicy for Shadow {
            fn name(&self) -> &str {
                "Best-fit"
            }
            fn place(&self, _ctx: &PlacementCtx) -> Option<Placement> {
                None
            }
        }
        let mut r = PolicyRegistry::with_defaults();
        let n = r.len();
        r.register(Arc::new(Shadow));
        assert_eq!(r.len(), n, "replace, not append");
    }
}
