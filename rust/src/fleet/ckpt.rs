//! Checkpoint-interval modeling: bounded-loss restarts.
//!
//! Without checkpointing, a churn-forced restart discards the whole
//! placement chain. With a [`CheckpointSpec`], a job persists its
//! adapter/optimizer state every `k` epochs at a configurable cost, so
//! a restart resumes from the last *completed* checkpoint and can never
//! lose more than one checkpoint interval of work (plus the partial
//! checkpoint in flight) — the classic k-vs-overhead tradeoff surfaced
//! by the `fleet_checkpoint` experiment.
//!
//! [`AttemptTimeline`] is the pure arithmetic core: one attempt of a
//! job on one device slice is a migration prefix, then work segments
//! interleaved with checkpoint pauses at **absolute** epoch boundaries
//! (fractions of the whole job, so resumed attempts align with the
//! boundaries of earlier ones and never re-checkpoint progress that is
//! already durable). The simulator never duplicates this walk: attempt
//! durations, mid-attempt progress, completed-checkpoint lookups and
//! overhead accounting all go through [`AttemptTimeline::at`], and the
//! bounded-loss property is property-tested against this module
//! directly (`tests/prop_invariants.rs`).

/// Default per-checkpoint cost, seconds: serializing a few MB of
/// adapter + optimizer state to flash or a neighbor over the edge LAN.
pub const DEFAULT_CKPT_COST: f64 = 60.0;

/// Checkpoint policy of one fleet run: persist durable state every
/// `every_epochs` epochs, paying `cost` wall-clock seconds per
/// checkpoint (the job makes no progress during the pause).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointSpec {
    /// Checkpoint every k epochs (k >= 1).
    pub every_epochs: usize,
    /// Seconds per checkpoint.
    pub cost: f64,
}

impl CheckpointSpec {
    pub fn new(every_epochs: usize, cost: f64) -> CheckpointSpec {
        assert!(every_epochs >= 1, "checkpoint interval must be >= 1 epoch");
        CheckpointSpec { every_epochs, cost: cost.max(0.0) }
    }
}

/// Where an attempt stands after some elapsed active time: see
/// [`AttemptTimeline::at`].
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptPoint {
    /// Whole-job fraction completed (work only; checkpoint pauses are
    /// flat segments).
    pub progress: f64,
    /// Highest checkpoint boundary whose pause *completed* within this
    /// attempt (`None` if no checkpoint finished yet — a pause cut
    /// short by churn leaves nothing durable).
    pub last_ckpt: Option<f64>,
    /// Checkpoints completed within this attempt.
    pub ckpts: usize,
    /// Seconds spent checkpointing so far, partial pauses included.
    pub ckpt_time: f64,
}

/// The deterministic timeline of one attempt: a job that is `p0` done
/// (whole-job fraction) starts on a device slice where the *whole* job
/// takes `service_full` seconds of pure work, after a `migration`
/// prefix during which no progress is made. Checkpoint boundaries are
/// the absolute fractions `i·k/epochs < 1` strictly above `durable`
/// (the last *completed* checkpoint) and not below `p0`; no checkpoint
/// is taken at completion (the finished result supersedes it).
///
/// `durable` and `p0` are passed separately because a replan can cut
/// an attempt *mid-checkpoint-pause*: progress then sits exactly on a
/// boundary whose checkpoint never completed, and the next attempt
/// must retake it — keying boundaries off `p0` alone would silently
/// skip it and let a later restart lose two intervals instead of one
/// (the bounded-loss invariant).
#[derive(Debug, Clone)]
pub struct AttemptTimeline {
    p0: f64,
    migration: f64,
    service_full: f64,
    /// Future checkpoint boundaries, ascending, in [p0, 1) ∩ (durable, 1).
    boundaries: Vec<f64>,
    cost: f64,
}

impl AttemptTimeline {
    pub fn new(
        p0: f64,
        durable: f64,
        migration: f64,
        service_full: f64,
        epochs: usize,
        spec: Option<&CheckpointSpec>,
    ) -> AttemptTimeline {
        let p0 = p0.clamp(0.0, 1.0);
        let mut boundaries = Vec::new();
        let mut cost = 0.0;
        if let Some(s) = spec {
            cost = s.cost;
            let epochs = epochs.max(1);
            let mut i = 1;
            while i * s.every_epochs < epochs {
                let b = (i * s.every_epochs) as f64 / epochs as f64;
                // only boundaries whose checkpoint completed are skipped;
                // a boundary equal to p0 with no durable record is a
                // pause that churn interrupted — retake it first
                if b > durable + 1e-12 && b > p0 - 1e-12 {
                    boundaries.push(b);
                }
                i += 1;
            }
        }
        AttemptTimeline { p0, migration, service_full, boundaries, cost }
    }

    /// Wall-clock duration of the attempt run to completion: migration,
    /// the outstanding work, and every future checkpoint pause.
    pub fn duration(&self) -> f64 {
        self.migration
            + (1.0 - self.p0) * self.service_full
            + self.boundaries.len() as f64 * self.cost
    }

    /// Checkpoints a full run of this attempt will take.
    pub fn checkpoints_total(&self) -> usize {
        self.boundaries.len()
    }

    /// Walk the timeline for `active` seconds since the attempt began
    /// (migration prefix included) and report where the attempt stands.
    pub fn at(&self, active: f64) -> AttemptPoint {
        let mut point = AttemptPoint {
            progress: self.p0,
            last_ckpt: None,
            ckpts: 0,
            ckpt_time: 0.0,
        };
        let mut t = active - self.migration;
        if t <= 0.0 || self.service_full <= 0.0 {
            return point;
        }
        for &b in &self.boundaries {
            let work = (b - point.progress) * self.service_full;
            if t < work {
                point.progress += t / self.service_full;
                return point;
            }
            t -= work;
            point.progress = b;
            if t < self.cost {
                // mid-checkpoint: progress is flat and nothing new is
                // durable until the pause completes
                point.ckpt_time += t;
                return point;
            }
            t -= self.cost;
            point.ckpt_time += self.cost;
            point.last_ckpt = Some(b);
            point.ckpts += 1;
        }
        let tail = (1.0 - point.progress) * self.service_full;
        if t < tail {
            point.progress += t / self.service_full;
        } else {
            point.progress = 1.0;
        }
        point
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_spec_is_pure_work() {
        let tl = AttemptTimeline::new(0.0, 0.0, 0.0, 100.0, 3, None);
        assert_eq!(tl.checkpoints_total(), 0);
        assert_eq!(tl.duration(), 100.0);
        assert_eq!(tl.at(50.0).progress, 0.5);
        assert_eq!(tl.at(100.0).progress, 1.0);
        assert_eq!(tl.at(1e9).progress, 1.0);
    }

    #[test]
    fn boundaries_are_absolute_epoch_fractions() {
        let spec = CheckpointSpec::new(1, 10.0);
        // 4 epochs, k=1: boundaries 0.25/0.50/0.75, none at completion
        let tl = AttemptTimeline::new(0.0, 0.0, 0.0, 100.0, 4, Some(&spec));
        assert_eq!(tl.checkpoints_total(), 3);
        assert_eq!(tl.duration(), 130.0);
        // resuming exactly from a durable boundary re-checkpoints
        // nothing below it
        let resumed = AttemptTimeline::new(0.25, 0.25, 0.0, 100.0, 4, Some(&spec));
        assert_eq!(resumed.checkpoints_total(), 2);
        assert_eq!(resumed.duration(), 95.0);
        // a mid-interval start (post-replan) still uses the absolute
        // boundaries above it
        let replanned = AttemptTimeline::new(0.3, 0.25, 0.0, 80.0, 4, Some(&spec));
        assert_eq!(replanned.checkpoints_total(), 2);
    }

    #[test]
    fn walk_tracks_progress_pauses_and_durability() {
        let spec = CheckpointSpec::new(1, 10.0);
        let tl = AttemptTimeline::new(0.0, 0.0, 0.0, 100.0, 4, Some(&spec));
        // mid first work segment
        let p = tl.at(20.0);
        assert_eq!((p.progress, p.last_ckpt, p.ckpts), (0.2, None, 0));
        assert_eq!(p.ckpt_time, 0.0);
        // inside the first pause: flat progress, nothing durable yet
        let p = tl.at(30.0);
        assert_eq!((p.progress, p.last_ckpt, p.ckpts), (0.25, None, 0));
        assert_eq!(p.ckpt_time, 5.0);
        // just past the first pause: 0.25 is durable
        let p = tl.at(36.0);
        assert!((p.progress - 0.26).abs() < 1e-12, "{p:?}");
        assert_eq!((p.last_ckpt, p.ckpts), (Some(0.25), 1));
        assert_eq!(p.ckpt_time, 10.0);
        // completion: all three checkpoints paid
        let p = tl.at(tl.duration());
        assert_eq!(p.progress, 1.0);
        assert_eq!((p.last_ckpt, p.ckpts), (Some(0.75), 3));
        assert_eq!(p.ckpt_time, 30.0);
    }

    #[test]
    fn migration_prefix_makes_no_progress() {
        let spec = CheckpointSpec::new(2, 5.0);
        let tl = AttemptTimeline::new(0.5, 0.5, 40.0, 200.0, 4, Some(&spec));
        assert_eq!(tl.at(0.0).progress, 0.5);
        assert_eq!(tl.at(39.0).progress, 0.5);
        assert!((tl.at(60.0).progress - 0.6).abs() < 1e-12);
        // p0=0.5 sits exactly on the 2/4 boundary: no re-checkpoint
        assert_eq!(tl.checkpoints_total(), 0);
        assert_eq!(tl.duration(), 40.0 + 100.0);
    }

    /// Regression (moved here from the simulator when checkpointing
    /// subsumed `replan_frac_left`): progress is measured against the
    /// whole job, never against the attempt, so repeated replans cannot
    /// re-charge work an earlier replan already preserved.
    #[test]
    fn replan_progress_does_not_compound() {
        // attempt 1: no migration, whole job takes 100 s, churn at 50 s
        let p1 = AttemptTimeline::new(0.0, 0.0, 0.0, 100.0, 3, None).at(50.0).progress;
        assert!((p1 - 0.5).abs() < 1e-12);
        // attempt 2: 10 s migration, whole job now 80 s, churn 30 s in:
        // 20 s of work = 0.25 of the whole job -> 0.75 done
        let p2 = AttemptTimeline::new(p1, 0.0, 10.0, 80.0, 3, None).at(30.0).progress;
        assert!((p2 - 0.75).abs() < 1e-12, "got {p2}");
        // churn during the migration prefix makes no progress
        assert_eq!(AttemptTimeline::new(0.5, 0.0, 10.0, 80.0, 3, None).at(5.0).progress, 0.5);
        // and progress never exceeds the whole job
        assert_eq!(AttemptTimeline::new(0.9, 0.0, 0.0, 100.0, 3, None).at(500.0).progress, 1.0);
    }

    /// A replan that cut the previous attempt *mid-checkpoint-pause*
    /// leaves progress exactly on a boundary with no durable record:
    /// the next attempt must retake that checkpoint before moving on,
    /// or a later restart would lose two intervals instead of one.
    #[test]
    fn interrupted_checkpoint_is_retaken() {
        let spec = CheckpointSpec::new(1, 10.0);
        // progress stalled at 0.5, but only 0.25 ever became durable
        let tl = AttemptTimeline::new(0.5, 0.25, 0.0, 100.0, 4, Some(&spec));
        assert_eq!(tl.checkpoints_total(), 2, "retake 0.5, then 0.75");
        assert_eq!(tl.duration(), 50.0 + 20.0);
        // the retaken pause runs first: flat progress, nothing durable
        let p = tl.at(5.0);
        assert_eq!((p.progress, p.last_ckpt, p.ckpts), (0.5, None, 0));
        assert_eq!(p.ckpt_time, 5.0);
        // once it completes, 0.5 is durable and work resumes
        let p = tl.at(12.0);
        assert!((p.progress - 0.52).abs() < 1e-12, "{p:?}");
        assert_eq!((p.last_ckpt, p.ckpts), (Some(0.5), 1));
        // and the loss bound holds throughout: progress − durable ≤ k/E
        for active in [0.0, 5.0, 12.0, 30.0, 36.0, 60.0] {
            let p = tl.at(active);
            let resume = p.last_ckpt.unwrap_or(0.25);
            assert!(
                p.progress - resume <= 0.25 + 1e-12,
                "active {active}: {p:?} loses more than one interval"
            );
        }
    }

    #[test]
    fn zero_cost_checkpoints_complete_instantly() {
        let spec = CheckpointSpec::new(1, 0.0);
        let tl = AttemptTimeline::new(0.0, 0.0, 0.0, 100.0, 2, Some(&spec));
        assert_eq!(tl.duration(), 100.0);
        let p = tl.at(50.0);
        assert_eq!((p.progress, p.last_ckpt, p.ckpts), (0.5, Some(0.5), 1));
        assert_eq!(p.ckpt_time, 0.0);
    }

    #[test]
    #[should_panic(expected = "checkpoint interval")]
    fn zero_interval_is_rejected() {
        CheckpointSpec::new(0, 1.0);
    }
}
